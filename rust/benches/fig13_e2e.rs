//! Fig 13 reproduction: the headline table — traditional MLP accelerator vs
//! the optimized KAN1/KAN2 accelerators on the knot-theory task.
//!
//! Paper:
//!   metric    MLP        KAN1    KAN2
//!   area      0.585      0.014   0.063  mm2
//!   energy    20049.28   257.13  392.76 pJ
//!   latency   19632      664     832    ns
//!   #param    190214     279     2232
//!   accuracy  78%        81.03%  86.74%
//!
//! Headline: 41.78x area / 77.97x energy reduction, +3.03% accuracy.
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo bench --bench fig13_e2e
//! ```

use kan_edge::baseline::MlpModel;
use kan_edge::circuits::Tech;
use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::neurosim::{estimate_kan, estimate_mlp, KanArch, MlpArch};
use kan_edge::util::bench::{bench, black_box, header, report};

fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("KAN_EDGE_ARTIFACTS") {
        return d;
    }
    // cargo bench runs with CWD = the package dir (rust/); the artifacts
    // live at the workspace root
    for cand in ["artifacts", "../artifacts"] {
        if std::path::Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

fn main() {
    let dir = artifacts_dir();
    let t = Tech::default();
    let (ds, manifest) = match (Dataset::load(&dir), Manifest::load(&dir)) {
        (Ok(d), Ok(m)) => (d, m),
        (e1, e2) => {
            eprintln!("skipping fig13_e2e: {:?} {:?}", e1.err(), e2.err());
            return;
        }
    };

    // measured accuracies (rust digital reference on the artifact test set)
    let mlp = MlpModel::load(format!("{dir}/mlp.weights.json")).unwrap();
    let kan1 = QuantKanModel::load(format!("{dir}/kan1.weights.json")).unwrap();
    let kan2 = QuantKanModel::load(format!("{dir}/kan2.weights.json")).unwrap();
    let acc_mlp = mlp.accuracy(&ds);
    let acc_k1 = kan1.accuracy(&ds);
    let acc_k2 = kan2.accuracy(&ds);

    // hardware cost estimates (KAN-NeuroSim engine)
    let r_mlp = estimate_mlp(&MlpArch::new(vec![17, 420, 420, 14]), &t).unwrap();
    let r_k1 = estimate_kan(&KanArch::new(vec![17, 1, 14], 5), &t).unwrap();
    let r_k2 = estimate_kan(&KanArch::new(vec![17, 2, 14], 32), &t).unwrap();

    println!("=== Fig 13: knot-theory accelerators (paper values in parens) ===");
    println!(
        "{:<14} {:>22} {:>22} {:>22}",
        "metric", "MLP", "KAN1", "KAN2"
    );
    println!(
        "{:<14} {:>14.4} (0.585) {:>14.4} (0.014) {:>14.4} (0.063)",
        "area (mm2)", r_mlp.area_mm2, r_k1.area_mm2, r_k2.area_mm2
    );
    println!(
        "{:<14} {:>12.1} (20049.3) {:>13.1} (257.1) {:>13.1} (392.8)",
        "energy (pJ)", r_mlp.energy_pj, r_k1.energy_pj, r_k2.energy_pj
    );
    println!(
        "{:<14} {:>13.0} (19632) {:>15.0} (664) {:>15.0} (832)",
        "latency (ns)", r_mlp.latency_ns, r_k1.latency_ns, r_k2.latency_ns
    );
    println!(
        "{:<14} {:>13} (190214) {:>15} (279) {:>14} (2232)",
        "#param", r_mlp.num_params, r_k1.num_params, r_k2.num_params
    );
    println!(
        "{:<14} {:>15.2}% (78%) {:>13.2}% (81.03%) {:>10.2}% (86.74%)",
        "accuracy",
        100.0 * acc_mlp,
        100.0 * acc_k1,
        100.0 * acc_k2
    );

    println!("\n=== headline reductions (KAN1 vs MLP) ===");
    println!(
        "paper:    41.78x area, 77.97x energy, 29.56x latency, +3.03% accuracy"
    );
    println!(
        "measured: {:.2}x area, {:.2}x energy, {:.2}x latency, {:+.2}% accuracy",
        r_mlp.area_mm2 / r_k1.area_mm2,
        r_mlp.energy_pj / r_k1.energy_pj,
        r_mlp.latency_ns / r_k1.latency_ns,
        100.0 * (acc_k1 - acc_mlp)
    );
    println!("=== KAN2 vs MLP ===");
    println!("paper:    9.28x area, 51.04x energy, 23.59x latency");
    println!(
        "measured: {:.2}x area, {:.2}x energy, {:.2}x latency, {:+.2}% accuracy",
        r_mlp.area_mm2 / r_k2.area_mm2,
        r_mlp.energy_pj / r_k2.energy_pj,
        r_mlp.latency_ns / r_k2.latency_ns,
        100.0 * (acc_k2 - acc_mlp)
    );
    let _ = manifest;

    // end-to-end inference timing on this host (the serving reality check)
    header("host inference timing");
    let row: Vec<f32> = ds.test_rows().next().unwrap().0.to_vec();
    let r = bench("kan1 digital forward (1 sample)", 300, || {
        black_box(kan1.forward(&row));
    });
    report(&r);
    let r = bench("kan2 digital forward (1 sample)", 300, || {
        black_box(kan2.forward(&row));
    });
    report(&r);
    let r = bench("mlp float forward (1 sample)", 300, || {
        black_box(mlp.forward(&row));
    });
    report(&r);
}
