//! Fig 12 reproduction: KAN-SAM vs uniform mapping under IR-drop.
//!
//! Paper: four KAN 17x1x14 models with G = 7/15/30/60 mapped onto arrays of
//! 128/256/512/1024 rows; accuracy-degradation reduction grows from 3.9x
//! to 4.63x with array size. Requires `make artifacts`.
//!
//! ```sh
//! cargo bench --bench fig12_sam
//! ```

use kan_edge::acim::{mac_with_irdrop, AcimOptions, ArrayConfig, NoiseModel};
use kan_edge::coordinator::build_acim_with_calib;
use kan_edge::kan::checkpoint::Dataset;
use kan_edge::kan::QuantKanModel;
use kan_edge::mapping::MappingStrategy;
use kan_edge::util::bench::{bench, black_box, header, report};

/// Fig 12 isolates IR-drop (the paper injects MAC error rates *caused by
/// IR-drop* measured from silicon): read noise and ADC limits are disabled
/// so the mapping comparison is deterministic and position-driven.
fn fig12_options(array: usize) -> AcimOptions {
    AcimOptions {
        array: ArrayConfig { rows: array, r_wire_ohm: 6.0, ..ArrayConfig::default() },
        adc_bits: 12,
        adc_fs_factor: 0.5,
        irdrop: true,
        noise: false,
        seed: 0x5eed,
    }
}

fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("KAN_EDGE_ARTIFACTS") {
        return d;
    }
    // cargo bench runs with CWD = the package dir (rust/); the artifacts
    // live at the workspace root
    for cand in ["artifacts", "../artifacts"] {
        if std::path::Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

fn main() {
    let dir = artifacts_dir();
    let ds = match Dataset::load(&dir) {
        Ok(ds) => ds,
        Err(e) => {
            eprintln!("skipping fig12_sam: {e}");
            return;
        }
    };

    println!("=== Fig 12: KAN-SAM vs uniform mapping under IR-drop ===");
    println!(
        "{:>4} {:>6} {:>9} {:>15} {:>15} {:>12}",
        "G", "array", "sw acc", "uniform (deg)", "sam (deg)", "deg-red(x)"
    );
    let pairs = [(7u32, 128usize), (15, 256), (30, 512), (60, 1024)];
    let mut reductions = Vec::new();
    for (g, array) in pairs {
        let qk = QuantKanModel::load(format!("{dir}/sweep/kan_g{g}.weights.json"))
            .expect("sweep checkpoint (run `make artifacts`)");
        let sw = qk.accuracy(&ds);
        let opts = fig12_options(array);
        let uni = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Uniform)
            .unwrap()
            .accuracy(&ds);
        let sam = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Sam)
            .unwrap()
            .accuracy(&ds);
        // one test sample = 0.001 accuracy: bound both degradations away
        // from zero so the ratio is meaningful at small effect sizes
        let quantum = 1.0 / ds.test_y.len() as f64;
        let red = (sw - uni).max(0.0) / (sw - sam).max(quantum);
        reductions.push(red);
        println!(
            "{:>4} {:>6} {:>9.4} {:>8.4} ({:>5.4}) {:>8.4} ({:>5.4}) {:>12.2}",
            g,
            array,
            sw,
            uni,
            sw - uni,
            sam,
            sw - sam,
            red
        );
    }
    println!("\npaper:    degradation reduction 3.9x (128) -> 4.63x (1024)");
    println!(
        "measured: {:.2}x (128) -> {:.2}x (1024)",
        reductions.first().unwrap(),
        reductions.last().unwrap()
    );

    // MAC-level view (stable companion metric): mean |I_real - I_ideal| on
    // a single bit line with the hot rows near vs far from the clamp
    println!("\n=== MAC-level IR-drop error: hot-rows-near vs hot-rows-far ===");
    println!("{:>6} {:>14} {:>14} {:>10}", "rows", "near (SAM-like)", "far (worst)", "ratio(x)");
    for rows in [128usize, 256, 512, 1024] {
        let cfg = ArrayConfig { rows, r_wire_ohm: 6.0, ..ArrayConfig::default() };
        let w = vec![100i32; rows];
        let xb = kan_edge::acim::Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        let active = rows / 5;
        let mut near = vec![0.0; rows];
        for d in near.iter_mut().take(active) { *d = 0.5; }
        let mut far = vec![0.0; rows];
        for d in far.iter_mut().rev().take(active) { *d = 0.5; }
        let ideal_n = xb.mac_ideal(&near)[0];
        let ideal_f = xb.mac_ideal(&far)[0];
        let err_near = (ideal_n - mac_with_irdrop(&xb, &near)[0]).abs() / ideal_n;
        let err_far = (ideal_f - mac_with_irdrop(&xb, &far)[0]).abs() / ideal_f;
        println!("{:>6} {:>14.4} {:>14.4} {:>10.2}", rows, err_near, err_far, err_far / err_near.max(1e-12));
    }

    // ablation: adversarial (worst-case) mapping bounds the effect size
    println!("\n=== ablation: mapping strategies at G=30 / 512 rows ===");
    let qk = QuantKanModel::load(format!("{dir}/sweep/kan_g30.weights.json")).unwrap();
    let opts = fig12_options(512);
    for strat in [
        MappingStrategy::Sam,
        MappingStrategy::Uniform,
        MappingStrategy::WorstCase,
    ] {
        let acc = build_acim_with_calib(&qk, opts, &ds, strat)
            .unwrap()
            .accuracy(&ds);
        println!("  {strat:?}: {acc:.4}");
    }

    // ablation: sensitivity to the other non-idealities (noise + ADC),
    // complementing the IR-drop isolation above — shows why the paper's
    // TD-A mode and partial-sum precision matter
    println!("\n=== ablation: non-ideality sensitivity (G=30, 512 rows, SAM) ===");
    println!("{:>10} {:>8} {:>8} {:>10}", "adc bits", "noise", "irdrop", "accuracy");
    let qk30 = QuantKanModel::load(format!("{dir}/sweep/kan_g30.weights.json")).unwrap();
    for (adc_bits, noise, irdrop) in [
        (12u32, false, false),
        (12, false, true),
        (12, true, true),
        (8, true, true),
        (6, true, true),
    ] {
        let o = AcimOptions {
            array: ArrayConfig { rows: 512, r_wire_ohm: 6.0, ..ArrayConfig::default() },
            adc_bits,
            adc_fs_factor: 0.5,
            irdrop,
            noise,
            seed: 0x5eed,
        };
        let acc = build_acim_with_calib(&qk30, o, &ds, MappingStrategy::Sam)
            .unwrap()
            .accuracy(&ds);
        println!("{:>10} {:>8} {:>8} {:>10.4}", adc_bits, noise, irdrop, acc);
    }

    // timing: the analog forward is the experiment's inner loop
    header("acim forward timing (G=30, 512 rows)");
    let acim = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Sam).unwrap();
    let row: Vec<f32> = ds.test_rows().next().unwrap().0.to_vec();
    let mut noise = NoiseModel::from_config(1, &opts.array);
    let r = bench("acim model forward (1 sample)", 400, || {
        black_box(acim.forward(&row, &mut noise));
    });
    report(&r);
}
