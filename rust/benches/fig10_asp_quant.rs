//! Fig 10 reproduction: ASP-KAN-HAQ vs conventional (PACT) B(X) path.
//!
//! Paper: G = 8→64, average 40.14x area and 5.59x energy reduction.
//! Prints the same series and times the modelled lookup paths.
//!
//! ```sh
//! cargo bench --bench fig10_asp_quant
//! ```

use kan_edge::circuits::{cost_bx_path, fig10_sweep, BxPathDesign, Tech};
use kan_edge::quant::{AspSpec, PactSpec, ShLut};
use kan_edge::util::bench::{bench, black_box, header, report};

fn main() {
    let t = Tech::default();

    println!("=== Fig 10: B(X) path cost, ASP-KAN-HAQ vs conventional ===");
    println!(
        "{:>4} {:>14} {:>14} {:>12} {:>14} {:>12} {:>14}",
        "G", "conv area", "asp area", "area-red(x)", "conv energy", "asp energy", "energy-red(x)"
    );
    let rows = fig10_sweep(&[8, 16, 32, 64], 3, 8, &t).expect("sweep");
    for r in &rows {
        println!(
            "{:>4} {:>14.1} {:>14.1} {:>12.2} {:>14.2} {:>12.2} {:>14.2}",
            r.g,
            r.conventional.total.area_um2,
            r.asp.total.area_um2,
            r.area_reduction,
            r.conventional.total.energy_fj,
            r.asp.total.energy_fj,
            r.energy_reduction
        );
    }
    let n = rows.len() as f64;
    let avg_a = rows.iter().map(|r| r.area_reduction).sum::<f64>() / n;
    let avg_e = rows.iter().map(|r| r.energy_reduction).sum::<f64>() / n;
    println!("\npaper:    avg 40.14x area, 5.59x energy");
    println!("measured: avg {avg_a:.2}x area, {avg_e:.2}x energy");

    // ablation: phase 1 alone vs phase 1+2 (what PowerGap adds)
    println!("\n=== ablation: Alignment-Symmetry only vs + PowerGap ===");
    println!("{:>4} {:>14} {:>14} {:>10}", "G", "phase1 area", "phase1+2 area", "gain(x)");
    for g in [8u32, 16, 32, 64] {
        let p1 = cost_bx_path(BxPathDesign::AlignmentOnly, g, 3, 8, &t).unwrap();
        let p2 = cost_bx_path(BxPathDesign::AspFull, g, 3, 8, &t).unwrap();
        println!(
            "{:>4} {:>14.1} {:>14.1} {:>10.2}",
            g,
            p1.total.area_um2,
            p2.total.area_um2,
            p1.total.area_um2 / p2.total.area_um2
        );
    }

    // timing: the modelled lookup math itself (runs on the serving path
    // of the digital reference, so its speed matters)
    header("lookup-path timing");
    let spec = AspSpec::build(8, 3, 8, 0.0, 1.0).unwrap();
    let lut = ShLut::build(&spec, 8);
    let codes: Vec<u32> = (0..spec.range()).collect();
    let r = bench("asp decompose+sh-lut lookup (256 codes)", 300, || {
        let mut acc = 0u64;
        for &q in &codes {
            let (j, l) = spec.decompose(q);
            for t in 0..=3u32 {
                acc = acc.wrapping_add(u64::from(lut.lookup(l, t)) + u64::from(j));
            }
        }
        black_box(acc);
    });
    report(&r);
    let pact = PactSpec::new(8, 3, 8, 0.0, 1.0);
    let luts = pact.build_per_basis_luts();
    let r = bench("conventional per-basis lut eval (256 codes)", 300, || {
        let mut acc = 0.0f64;
        for q in 0..256u32 {
            let x = pact.dequantize(q);
            let z = x * 8.0;
            let j = (z as usize).min(7);
            for tt in 0..=3usize {
                let idx = ((z - j as f64) * luts[j + tt].len() as f64 / 4.0) as usize;
                acc += luts[j + tt][idx.min(luts[j + tt].len() - 1)];
            }
        }
        black_box(acc);
    });
    report(&r);
}
