//! Fig 11 reproduction: WL input generator comparison at 6 bits.
//!
//! Paper: pure voltage = 1.96x area / 11.9x power vs TM-DV-IG; pure PWM =
//! 8x latency / 1.07x area; TM-DV-IG FOM 3x over voltage, 4.1x over PWM.
//!
//! ```sh
//! cargo bench --bench fig11_inputgen
//! ```

use kan_edge::circuits::inputgen::{InputGenerator, PurePwm, PureVoltage, TmDvIg};
use kan_edge::circuits::{fig11_comparison, Tech};
use kan_edge::util::bench::{bench, black_box, header, report};

fn main() {
    let t = Tech::default();
    let bits = 6u32;

    println!("=== Fig 11: WL input generators, {bits}-bit ===");
    println!(
        "{:<14} {:>11} {:>11} {:>10} {:>12} {:>9}",
        "generator", "area(um2)", "power(uW)", "lat(ns)", "margin(mV)", "FOM(rel)"
    );
    let reports = fig11_comparison(bits, &t);
    let tm = reports.last().unwrap().clone();
    for r in &reports {
        println!(
            "{:<14} {:>11.1} {:>11.1} {:>10.1} {:>12.1} {:>9.2}",
            r.name,
            r.area_um2,
            r.power_uw,
            r.latency_ns,
            r.noise_margin_v * 1e3,
            r.fom() / tm.fom()
        );
    }
    let v = &reports[0];
    let pwm = &reports[1];
    println!("\npaper:    voltage 1.96x area, 11.9x power; pwm 8x latency, 1.07x area");
    println!(
        "measured: voltage {:.2}x area, {:.1}x power; pwm {:.0}x latency, {:.2}x area",
        v.area_um2 / tm.area_um2,
        v.power_uw / tm.power_uw,
        pwm.latency_ns / tm.latency_ns,
        pwm.area_um2 / tm.area_um2
    );
    println!(
        "paper:    TM-DV FOM 3x over voltage, 4.1x over PWM\nmeasured: {:.2}x over voltage, {:.2}x over PWM",
        tm.fom() / v.fom(),
        tm.fom() / pwm.fom()
    );

    // TD-A vs TD-P operating points (the co-design knob of section 3.2)
    println!("\n=== TM-DV-IG operating modes ===");
    println!("{:<18} {:>8} {:>10} {:>12}", "mode", "N", "lat(ns)", "margin(mV)");
    for (name, ig) in [
        ("TD-A (accuracy)", TmDvIg::high_accuracy()),
        ("default", TmDvIg::default_6bit()),
        ("TD-P (performance)", TmDvIg::high_performance()),
    ] {
        let r = ig.report(bits, &t);
        println!(
            "{:<18} {:>8} {:>10.1} {:>12.1}",
            name,
            ig.n_voltage_bits,
            r.latency_ns,
            r.noise_margin_v * 1e3
        );
    }

    // timing of the encode path (runs per WL per inference in the sim)
    header("encode timing");
    let gens: Vec<(&str, Box<dyn InputGenerator>)> = vec![
        ("pure-voltage encode (64 codes)", Box::new(PureVoltage)),
        ("pure-pwm encode (64 codes)", Box::new(PurePwm)),
        ("tm-dv-ig encode (64 codes)", Box::new(TmDvIg::default_6bit())),
    ];
    for (name, gen) in &gens {
        let r = bench(name, 200, || {
            let mut acc = 0.0f64;
            for code in 0..64u32 {
                let (v, p) = gen.encode(code, bits);
                acc += v * p as f64;
            }
            black_box(acc);
        });
        report(&r);
    }
}
