//! Hot-path micro benchmarks: the inner loops profiled and optimized in
//! EXPERIMENTS.md §Perf.
//!
//! * digital KAN layer forward (serving digital backend inner loop)
//! * IR-drop ladder solve (ACIM simulation inner loop)
//! * batcher + service round trip (serving overhead floor)
//! * PJRT executable round trip (AOT graph dispatch cost)
//!
//! ```sh
//! cargo bench --bench hotpath
//! ```

use std::sync::Arc;

use kan_edge::acim::{mac_with_irdrop, ArrayConfig, Crossbar};
use kan_edge::coordinator::batcher::BatchPolicy;
use kan_edge::coordinator::{InferenceService, ServeOptions};
use kan_edge::data::LoadGen;
use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::util::bench::{bench, black_box, header, report};

struct Echo;

impl kan_edge::coordinator::InferBackend for Echo {
    fn name(&self) -> &str {
        "echo"
    }

    fn output_dim(&self) -> usize {
        1
    }

    fn infer_batch(
        &self,
        rows: Vec<Vec<f32>>,
    ) -> kan_edge::Result<Vec<Vec<f32>>> {
        Ok(rows.iter().map(|r| vec![r[0]]).collect())
    }
}

fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("KAN_EDGE_ARTIFACTS") {
        return d;
    }
    // cargo bench runs with CWD = the package dir (rust/); the artifacts
    // live at the workspace root
    for cand in ["artifacts", "../artifacts"] {
        if std::path::Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

fn main() {
    let dir = artifacts_dir();

    header("digital KAN forward");
    if let Ok(model) = QuantKanModel::load(format!("{dir}/kan2.weights.json")) {
        let mut lg = LoadGen::new(7, model.input_dim());
        let one = lg.next_vec();
        let r = bench("kan2 forward (1 sample)", 400, || {
            black_box(model.forward(&one));
        });
        report(&r);
        let batch: Vec<f32> = lg.batch(64).into_iter().flatten().collect();
        let r = bench("kan2 forward_batch (64 samples)", 500, || {
            black_box(model.forward_batch(&batch, 64));
        });
        report(&r);
    } else {
        println!("  (artifacts missing; run `make artifacts`)");
    }

    header("IR-drop ladder solve");
    for rows in [128usize, 512, 1024] {
        let cfg = ArrayConfig::with_rows(rows);
        let w: Vec<i32> = (0..rows).map(|i| ((i * 37) % 255) as i32 - 127).collect();
        let xb = Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        let drives: Vec<f64> = (0..rows)
            .map(|i| if i % 5 == 0 { 0.5 } else { 0.0 })
            .collect();
        let r = bench(&format!("ladder solve ({rows} rows, 1 col)"), 300, || {
            black_box(mac_with_irdrop(&xb, &drives));
        });
        report(&r);
    }

    header("serving round trip (echo backend)");
    let opts = ServeOptions {
        policy: BatchPolicy {
            max_batch: 32,
            deadline: std::time::Duration::from_micros(100),
        },
        queue_depth: 1024,
        workers: 2,
        ..ServeOptions::default()
    };
    let svc = InferenceService::start(Arc::new(Echo), opts);
    let r = bench("single blocking infer", 400, || {
        black_box(svc.infer(vec![1.0]).unwrap());
    });
    report(&r);

    header("PJRT dispatch");
    match Manifest::load(&dir) {
        Ok(manifest) => {
            let entry = &manifest.models["kan1"];
            let file = entry.hlo.get(&32).expect("batch-32 hlo");
            let engine = kan_edge::runtime::PjrtEngine::cpu().unwrap();
            let exe = engine
                .load_hlo(format!("{dir}/{file}"), 32, 17, 14)
                .unwrap();
            let ds = Dataset::load(&dir).unwrap();
            let mut flat = vec![0.0f32; 32 * 17];
            for (i, (row, _)) in ds.test_rows().take(32).enumerate() {
                flat[i * 17..(i + 1) * 17].copy_from_slice(row);
            }
            let r = bench("kan1 b32 execute (AOT HLO)", 500, || {
                black_box(exe.run(&flat).unwrap());
            });
            report(&r);
        }
        Err(e) => println!("  (skipping: {e})"),
    }
}
