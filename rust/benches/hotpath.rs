//! Hot-path micro benchmarks: the inner loops profiled and optimized in
//! EXPERIMENTS.md §Perf.
//!
//! * digital KAN forward — the scalar golden reference vs the planned
//!   batch-major execution engine (`docs/ENGINE.md`), single-sample and
//!   batch-64, plus the engine autotune sweep (`docs/PERFORMANCE.md`)
//! * IR-drop ladder solve (ACIM simulation inner loop)
//! * batcher + service round trip (serving overhead floor)
//! * PJRT executable round trip (AOT graph dispatch cost)
//!
//! When the artifacts are missing, a deterministic synthetic
//! kan2-shaped checkpoint (dims [17, 8, 14], G=5, K=3) stands in so the
//! bench trajectory never goes empty. Alongside the human-readable
//! table the run emits `BENCH_hotpath.json` (override the path with
//! `KAN_EDGE_BENCH_JSON`) holding per-bench ns/op and the
//! reference-vs-engine batch-64 speedup — CI archives it next to the
//! bench-net report.
//!
//! ```sh
//! cargo bench --bench hotpath
//! ```

use std::sync::Arc;

use kan_edge::acim::{mac_with_irdrop, ArrayConfig, Crossbar};
use kan_edge::coordinator::batcher::BatchPolicy;
use kan_edge::coordinator::{InferenceService, ServeOptions};
use kan_edge::data::LoadGen;
use kan_edge::kan::checkpoint::{synthetic_kan_checkpoint, Dataset, Manifest};
use kan_edge::kan::{argmax, EngineOptions, EngineScratch, KanEngine, QuantKanModel};
use kan_edge::util::bench::{bench, black_box, header, report, BenchResult};
use kan_edge::util::json::{arr, obj, Value};

struct Echo;

impl kan_edge::coordinator::ExecutionSession for Echo {
    fn name(&self) -> &str {
        "echo"
    }

    fn spec(&self) -> kan_edge::coordinator::BackendSpec {
        kan_edge::coordinator::BackendSpec::synthetic(1)
    }

    fn run(
        &self,
        rows: Vec<Vec<f32>>,
        _opts: &[kan_edge::coordinator::ExecOptions],
    ) -> kan_edge::Result<Vec<kan_edge::coordinator::RowOutput>> {
        Ok(rows.iter().map(|r| vec![r[0]].into()).collect())
    }
}

fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("KAN_EDGE_ARTIFACTS") {
        return d;
    }
    // cargo bench runs with CWD = the package dir (rust/); the artifacts
    // live at the workspace root
    for cand in ["artifacts", "../artifacts"] {
        if std::path::Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

/// Run one case, print the human row, and collect it for the JSON report.
fn run<F: FnMut()>(
    results: &mut Vec<BenchResult>,
    name: &str,
    target_ms: u64,
    f: F,
) {
    let r = bench(name, target_ms, f);
    report(&r);
    results.push(r);
}

fn ns_of(results: &[BenchResult], name: &str) -> Option<f64> {
    results.iter().find(|r| r.name == name).map(|r| r.per_iter_ns())
}

fn main() {
    let dir = artifacts_dir();
    let mut results: Vec<BenchResult> = Vec::new();

    header("digital KAN forward");
    // which checkpoint produced the numbers (artifact weights vs the
    // synthetic fallback) goes into the JSON verbatim, so trajectory
    // comparisons across CI runs are apples-to-apples
    let weights_path = format!("{dir}/kan2.weights.json");
    let (model, model_source, checkpoint_detail) =
        match QuantKanModel::load(&weights_path) {
            Ok(m) => {
                let detail = ("weights", Value::Str(weights_path.clone()));
                (m, "artifact", detail)
            }
            Err(_) => {
                println!("  (artifacts missing; using a synthetic kan2-shaped checkpoint)");
                let ckpt = synthetic_kan_checkpoint("kan2", &[17, 8, 14], 5, 3, 0xCAFE);
                let detail = ("seed", Value::Str("0xCAFE".to_string()));
                (QuantKanModel::from_checkpoint(&ckpt), "synthetic", detail)
            }
        };
    let checkpoint = obj(vec![
        ("source", Value::Str(model_source.to_string())),
        ("model", Value::Str(model.name.clone())),
        (
            "dims",
            arr(model.dims.iter().map(|&d| Value::Int(d as i64)).collect()),
        ),
        ("g", Value::Int(model.g as i64)),
        ("k", Value::Int(model.k as i64)),
        checkpoint_detail,
    ]);
    let mut lg = LoadGen::new(7, model.input_dim());
    let one = lg.next_vec();
    // the pre-PR scalar reference numbers, measured in the same run the
    // engine is (CI compares the two for the perf trajectory)
    run(&mut results, "kan2 forward (1 sample)", 400, || {
        black_box(model.forward(&one));
    });
    let batch: Vec<f32> = lg.batch(64).into_iter().flatten().collect();
    run(&mut results, "kan2 forward_batch (64 samples)", 500, || {
        black_box(model.forward_batch(&batch, 64));
    });

    let engine = KanEngine::compile(&model, EngineOptions::default())
        .expect("engine compile");
    let mut scratch = engine.new_scratch();
    let mut out1 = vec![0.0f64; engine.output_dim()];
    run(&mut results, "kan2 engine forward (1 sample)", 400, || {
        engine.forward_into(&one, &mut out1, &mut scratch);
        black_box(&out1);
    });
    let mut out64 = vec![0.0f64; 64 * engine.output_dim()];
    let mut s1 = vec![engine.new_scratch()];
    run(&mut results, "kan2 engine forward_batch (64 samples)", 500, || {
        engine.forward_batch_with(&batch, 64, &mut out64, &mut s1);
        black_box(&out64);
    });
    let mut s4: Vec<EngineScratch> = (0..4).map(|_| engine.new_scratch()).collect();
    run(
        &mut results,
        "kan2 engine forward_batch (64 samples, 4 workers)",
        500,
        || {
            engine.forward_batch_with(&batch, 64, &mut out64, &mut s4);
            black_box(&out64);
        },
    );

    // argmax parity engine vs reference on random inputs (the test suite
    // enforces this; the bench just surfaces it next to the numbers)
    let mut lg2 = LoadGen::new(99, model.input_dim());
    let samples = 256usize;
    let agree = (0..samples)
        .filter(|_| {
            let x = lg2.next_vec();
            argmax(&model.forward(&x)) == engine.predict(&x)
        })
        .count();
    println!("  engine/reference argmax agreement: {agree}/{samples}");

    // autotune sweep: block / grouping-threshold / fusion-budget grid on
    // the same checkpoint and batch size as the headline bench; the full
    // report lands in the JSON (docs/PERFORMANCE.md explains the schema)
    header("engine autotune (batch 64)");
    let tune = kan_edge::kan::autotune(&model, 64, 40, &[])
        .expect("autotune sweep");
    for o in &tune.outcomes {
        let c = o.candidate;
        let mode = if c.group_threshold > kan_edge::kan::engine::MAX_BLOCK {
            "row-major"
        } else {
            "grouped"
        };
        println!(
            "  block {:>4}  {:<9}  budget {:>8}  {:>10.0} ns/op",
            c.block, mode, c.fused_budget, o.ns_per_op
        );
    }
    println!(
        "  best: block {} threshold {} budget {} — {:.2}x vs reference, {:.2}x vs default engine",
        tune.best.candidate.block,
        tune.best.candidate.group_threshold,
        tune.best.candidate.fused_budget,
        tune.speedup_vs_reference(),
        tune.speedup_vs_default()
    );

    header("IR-drop ladder solve");
    for rows in [128usize, 512, 1024] {
        let cfg = ArrayConfig::with_rows(rows);
        let w: Vec<i32> = (0..rows).map(|i| ((i * 37) % 255) as i32 - 127).collect();
        let xb = Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        let drives: Vec<f64> = (0..rows)
            .map(|i| if i % 5 == 0 { 0.5 } else { 0.0 })
            .collect();
        run(
            &mut results,
            &format!("ladder solve ({rows} rows, 1 col)"),
            300,
            || {
                black_box(mac_with_irdrop(&xb, &drives));
            },
        );
    }

    header("serving round trip (echo backend)");
    let opts = ServeOptions {
        policy: BatchPolicy {
            max_batch: 32,
            deadline: std::time::Duration::from_micros(100),
        },
        queue_depth: 1024,
        workers: 2,
        ..ServeOptions::default()
    };
    let svc = InferenceService::start(Arc::new(Echo), opts);
    run(&mut results, "single blocking infer", 400, || {
        black_box(svc.infer(vec![1.0]).unwrap());
    });

    header("PJRT dispatch");
    match Manifest::load(&dir) {
        Ok(manifest) => {
            let entry = &manifest.models["kan1"];
            let file = entry.hlo.get(&32).expect("batch-32 hlo");
            let engine = kan_edge::runtime::PjrtEngine::cpu().unwrap();
            let exe = engine
                .load_hlo(format!("{dir}/{file}"), 32, 17, 14)
                .unwrap();
            let ds = Dataset::load(&dir).unwrap();
            let mut flat = vec![0.0f32; 32 * 17];
            for (i, (row, _)) in ds.test_rows().take(32).enumerate() {
                flat[i * 17..(i + 1) * 17].copy_from_slice(row);
            }
            run(&mut results, "kan1 b32 execute (AOT HLO)", 500, || {
                black_box(exe.run(&flat).unwrap());
            });
        }
        Err(e) => println!("  (skipping: {e})"),
    }

    // machine-readable report: per-bench ns/op plus the headline
    // reference-vs-engine speedup on the batch-64 case
    let json_path = std::env::var("KAN_EDGE_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let bench_values: Vec<Value> = results
        .iter()
        .map(|r| {
            obj(vec![
                ("name", Value::Str(r.name.clone())),
                ("ns_per_op", Value::Float(r.per_iter_ns())),
                ("mean_ns", Value::Float(r.mean.as_nanos() as f64)),
                ("iters", Value::Int(r.iters as i64)),
            ])
        })
        .collect();
    // one speedup computation feeds both the JSON field and the console
    // line, so they can never drift apart
    let speedup = match (
        ns_of(&results, "kan2 forward_batch (64 samples)"),
        ns_of(&results, "kan2 engine forward_batch (64 samples)"),
    ) {
        (Some(r), Some(e)) if e > 0.0 => Some((r, e, r / e)),
        _ => None,
    };
    let mut fields = vec![
        ("schema", Value::Int(2)),
        ("model_source", Value::Str(model_source.to_string())),
        ("checkpoint", checkpoint),
        (
            "argmax_agreement",
            Value::Float(agree as f64 / samples as f64),
        ),
        ("benches", arr(bench_values)),
        ("autotune", tune.to_value(model_source)),
    ];
    if let Some((_, _, s)) = speedup {
        fields.push(("speedup_forward_batch_64", Value::Float(s)));
    }
    match std::fs::write(&json_path, obj(fields).to_string()) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => println!("\nfailed to write {json_path}: {e}"),
    }
    if let Some((r, e, s)) = speedup {
        println!(
            "engine speedup on forward_batch(64): {s:.2}x ({r:.0} ns -> {e:.0} ns)"
        );
    }
}
