//! The traditional-MLP baseline of Fig 13: float inference from the
//! exported checkpoint, plus its accelerator mapping (how many crossbar
//! tiles / input drivers / ADC columns a conventional RRAM-ACIM DNN
//! accelerator needs for it — consumed by `neurosim::cost`).

use crate::kan::checkpoint::{Dataset, MlpCheckpoint};
use crate::kan::model::argmax;

/// An MLP materialized from `mlp.weights.json`.
#[derive(Debug, Clone)]
pub struct MlpModel {
    pub name: String,
    pub dims: Vec<usize>,
    /// per layer: weights `[din][dout]` flattened + biases
    pub layers: Vec<(Vec<f64>, Vec<f64>)>,
}

impl MlpModel {
    pub fn from_checkpoint(ckpt: &MlpCheckpoint) -> Self {
        Self {
            name: ckpt.name.clone(),
            dims: ckpt.dims.clone(),
            layers: ckpt
                .layers
                .iter()
                .map(|l| (l.w.clone(), l.b.clone()))
                .collect(),
        }
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> crate::error::Result<Self> {
        Ok(Self::from_checkpoint(&MlpCheckpoint::load(path)?))
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f64> {
        let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for (li, (w, b)) in self.layers.iter().enumerate() {
            let din = self.dims[li];
            let dout = self.dims[li + 1];
            let mut out = b.clone();
            for i in 0..din {
                let hi = h[i];
                if hi == 0.0 {
                    continue;
                }
                let row = &w[i * dout..(i + 1) * dout];
                for (o, &wv) in row.iter().enumerate() {
                    out[o] += hi * wv;
                }
            }
            if li + 1 < self.layers.len() {
                for v in &mut out {
                    *v = v.max(0.0);
                }
            }
            h = out;
        }
        h
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            if self.predict(row) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }

    /// Total MAC count of one inference (the latency/energy driver in the
    /// conventional accelerator).
    pub fn macs(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1]).sum()
    }

    /// Weight count (paper's #Param row).
    pub fn num_params(&self) -> usize {
        self.dims.windows(2).map(|w| (w[0] + 1) * w[1]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::checkpoint::MlpLayerCheckpoint;

    fn tiny() -> MlpModel {
        MlpModel::from_checkpoint(&MlpCheckpoint {
            name: "t".into(),
            kind: "mlp".into(),
            dims: vec![2, 3, 2],
            num_params: 17,
            layers: vec![
                MlpLayerCheckpoint {
                    din: 2,
                    dout: 3,
                    w: vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.5],
                    b: vec![0.0, 0.1, 0.0],
                },
                MlpLayerCheckpoint {
                    din: 3,
                    dout: 2,
                    w: vec![1.0, -1.0, 0.0, 1.0, 1.0, 0.0],
                    b: vec![0.0, 0.0],
                },
            ],
            test_acc: None,
        })
    }

    #[test]
    fn forward_with_relu() {
        let m = tiny();
        let out = m.forward(&[1.0, 2.0]);
        // h1 = relu([1*1+2*0.5, 0+1, -1+1] + [0, .1, 0]) = [2, 1.1, 0]
        // out = [2*1 + 1.1*0, 2*-1 + 1.1*1] = [2, -0.9]
        assert!((out[0] - 2.0).abs() < 1e-12);
        assert!((out[1] + 0.9).abs() < 1e-12);
        assert_eq!(m.predict(&[1.0, 2.0]), 0);
    }

    #[test]
    fn macs_and_params() {
        let m = tiny();
        assert_eq!(m.macs(), 2 * 3 + 3 * 2);
        assert_eq!(m.num_params(), 3 * 3 + 4 * 2);
    }
}
