//! Typed Rust client for the kan-edge serving protocol.
//!
//! [`KanClient`] speaks protocol v2 (framed JSON with request ids; see
//! `docs/PROTOCOL.md` and [`crate::coordinator::protocol`]): it sends
//! the magic preamble, negotiates capabilities with `hello`, and then
//! offers three usage styles over one connection:
//!
//! * **Synchronous calls** — [`KanClient::infer`],
//!   [`KanClient::infer_batch`], and the control-plane queries
//!   ([`KanClient::list_models`], [`KanClient::model_info`],
//!   [`KanClient::metrics`], [`KanClient::health`], [`KanClient::ping`]).
//! * **Pipelining** — [`KanClient::submit`] fires a request and returns
//!   its id immediately; [`KanClient::poll`] yields completions in
//!   whatever order the server finishes them. Keeping several requests
//!   in flight is what lets the server's dynamic batcher see multi-row
//!   batches from a single connection.
//! * **Batch submit** — [`KanClient::infer_batch`] ships whole
//!   `rows: [[...], ...]` batches in one frame.
//!
//! ```no_run
//! use kan_edge::client::KanClient;
//!
//! let mut client = KanClient::connect("127.0.0.1:7777")?;
//! let out = client.infer(&[0.5, 0.5])?;
//! println!("class {} from {}", out.class, out.model);
//! # kan_edge::Result::Ok(())
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::coordinator::backend::{BackendKind, ExecOptions};
use crate::coordinator::protocol::{
    read_frame, write_frame, FrameRead, ModelSummary, Request, Response, WireRow, MAGIC,
};
use crate::error::{Error, Result};
use crate::util::json::Value;

/// Client-side sanity cap on response frames (guards against a corrupt
/// length header, not against legitimate large results).
const MAX_RESPONSE_BYTES: usize = 64 << 20;

/// Result of one inference: the resolved `name@version` that served it,
/// the logits, the argmax class, and — when the request asked a
/// stochastic backend for `trials > 1` — the per-logit standard
/// deviation across trials.
#[derive(Debug, Clone)]
pub struct Inference {
    pub model: String,
    pub logits: Vec<f32>,
    pub class: usize,
    pub std: Option<Vec<f32>>,
}

/// Per-call execution options: backend selection plus the ACIM
/// `seed`/`trials` fields (see `docs/BACKENDS.md`). `Default` is "the
/// model's primary backend, one unseeded trial" — identical to not
/// passing options at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CallOptions {
    /// Execute on this backend instead of the model's primary one.
    pub backend: Option<BackendKind>,
    /// Noise-stream seed for stochastic backends: a fixed `(row, seed)`
    /// is bit-identical across connections, concurrency, and server
    /// worker counts.
    pub seed: Option<u64>,
    /// Noisy trials to aggregate (server cap applies); `> 1` yields the
    /// per-logit trial spread in [`Inference::std`].
    pub trials: u32,
    /// When the server answers a structured `overloaded` rejection,
    /// sleep for its `retry_after_ms` hint and reissue the call once
    /// before surfacing [`Error::Overloaded`] to the caller.
    pub retry_overloaded: bool,
}

impl Default for CallOptions {
    fn default() -> Self {
        Self { backend: None, seed: None, trials: 1, retry_overloaded: false }
    }
}

impl CallOptions {
    fn exec(&self) -> ExecOptions {
        ExecOptions { seed: self.seed, trials: self.trials.max(1) }
    }
}

/// Capabilities the server announced in its `hello` response.
#[derive(Debug, Clone)]
pub struct ServerInfo {
    pub protocol: u32,
    pub server: String,
    /// Largest frame payload the server accepts.
    pub max_frame: usize,
    /// Pipelining depth per connection before the server applies
    /// backpressure.
    pub max_in_flight: usize,
    /// Stable cluster identity of the node, when it has one (serve
    /// endpoints started with node identity report it; older servers
    /// and plain endpoints leave it out).
    pub node_id: Option<String>,
    /// Seconds since the node process started, when reported.
    pub uptime_s: Option<u64>,
}

/// A connected v2 client (one TCP connection; not `Sync` — use one per
/// thread, the server batches across connections).
pub struct KanClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    info: ServerInfo,
    next_id: i64,
    /// Responses read while waiting for a different id (pipelining).
    completed: BTreeMap<i64, Response>,
    /// Ids submitted via [`KanClient::submit`] and not yet returned by
    /// [`KanClient::poll`] — lets a surplus poll fail fast instead of
    /// blocking forever on a response the server will never send.
    outstanding: BTreeSet<i64>,
}

impl KanClient {
    /// Connect, send the v2 preamble, and negotiate with `hello`.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<KanClient> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Like [`KanClient::connect`] over an already-open stream.
    pub fn from_stream(stream: TcpStream) -> Result<KanClient> {
        let writer = stream.try_clone()?;
        let mut client = KanClient {
            writer,
            reader: BufReader::new(stream),
            info: ServerInfo {
                protocol: 0,
                server: String::new(),
                max_frame: 1 << 20,
                max_in_flight: 1,
                node_id: None,
                uptime_s: None,
            },
            next_id: 1,
            completed: BTreeMap::new(),
            outstanding: BTreeSet::new(),
        };
        client.writer.write_all(&MAGIC)?;
        let id = client.fresh_id();
        let resp =
            client.call(Request::Hello { id, client: Some("kan-edge-client".into()) })?;
        match resp {
            Response::Hello {
                protocol,
                server,
                max_frame,
                max_in_flight,
                node_id,
                uptime_s,
                ..
            } => {
                client.info = ServerInfo {
                    protocol,
                    server,
                    max_frame,
                    max_in_flight,
                    node_id,
                    uptime_s,
                };
                Ok(client)
            }
            Response::Error { message, .. } => {
                Err(Error::Serving(format!("hello rejected: {message}")))
            }
            _ => Err(Error::Serving("unexpected hello response".into())),
        }
    }

    /// What the server announced during negotiation.
    pub fn server_info(&self) -> &ServerInfo {
        &self.info
    }

    /// Liveness round-trip.
    pub fn ping(&mut self) -> Result<()> {
        let id = self.fresh_id();
        match self.call(Request::Ping { id })? {
            Response::Pong { .. } => Ok(()),
            other => Err(unexpected(other)),
        }
    }

    /// Infer against the endpoint's default model.
    pub fn infer(&mut self, features: &[f32]) -> Result<Inference> {
        self.infer_model(None, features)
    }

    /// Infer against `model` (`"name"` or pinned `"name@version"`).
    pub fn infer_model(
        &mut self,
        model: Option<&str>,
        features: &[f32],
    ) -> Result<Inference> {
        self.infer_opts(model, features, &CallOptions::default())
    }

    /// Infer with explicit per-request execution options: backend
    /// selection and/or ACIM `seed`/`trials`. With
    /// [`CallOptions::retry_overloaded`] set, one `overloaded`
    /// rejection is absorbed by sleeping the server's `retry_after_ms`
    /// hint and reissuing.
    pub fn infer_opts(
        &mut self,
        model: Option<&str>,
        features: &[f32],
        opts: &CallOptions,
    ) -> Result<Inference> {
        match self.infer_once(model, features, opts) {
            Err(Error::Overloaded { retry_after_ms, .. }) if opts.retry_overloaded => {
                std::thread::sleep(std::time::Duration::from_millis(
                    retry_after_ms.max(1),
                ));
                self.infer_once(model, features, opts)
            }
            other => other,
        }
    }

    fn infer_once(
        &mut self,
        model: Option<&str>,
        features: &[f32],
        opts: &CallOptions,
    ) -> Result<Inference> {
        let id = self.fresh_id();
        let resp = self.call(Request::Infer {
            id,
            model: model.map(str::to_string),
            backend: opts.backend,
            exec: opts.exec(),
            features: features.to_vec(),
        })?;
        into_inference(resp)
    }

    /// Submit a whole batch in one frame; returns the resolved model id
    /// and one result per row, in row order. The server feeds the rows
    /// to the selected backend's dynamic batcher back-to-back. Takes
    /// the rows by value — batches can be large and are only
    /// serialized, never kept.
    pub fn infer_batch(
        &mut self,
        model: Option<&str>,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<WireRow>)> {
        self.infer_batch_opts(model, rows, &CallOptions::default())
    }

    /// Batch submit with explicit per-request execution options. Row
    /// `i` derives its noise stream as `mix(seed, i)` server-side, so a
    /// seeded batch reproduces bit-identically row by row. With
    /// [`CallOptions::retry_overloaded`] set, one `overloaded`
    /// rejection is retried after the server's backoff hint (the rows
    /// are cloned up front to make the reissue possible).
    pub fn infer_batch_opts(
        &mut self,
        model: Option<&str>,
        rows: Vec<Vec<f32>>,
        opts: &CallOptions,
    ) -> Result<(String, Vec<WireRow>)> {
        if !opts.retry_overloaded {
            return self.infer_batch_once(model, rows, opts);
        }
        match self.infer_batch_once(model, rows.clone(), opts) {
            Err(Error::Overloaded { retry_after_ms, .. }) => {
                std::thread::sleep(std::time::Duration::from_millis(
                    retry_after_ms.max(1),
                ));
                self.infer_batch_once(model, rows, opts)
            }
            other => other,
        }
    }

    fn infer_batch_once(
        &mut self,
        model: Option<&str>,
        rows: Vec<Vec<f32>>,
        opts: &CallOptions,
    ) -> Result<(String, Vec<WireRow>)> {
        let id = self.fresh_id();
        let resp = self.call(Request::InferBatch {
            id,
            model: model.map(str::to_string),
            backend: opts.backend,
            exec: opts.exec(),
            rows,
        })?;
        match resp {
            Response::InferBatch { model, results, .. } => Ok((model, results)),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Pipelined submit: send an `infer` request and return its id
    /// without waiting. Pair with [`KanClient::poll`]; respect
    /// [`ServerInfo::max_in_flight`] or the server will backpressure
    /// the connection.
    pub fn submit(&mut self, model: Option<&str>, features: &[f32]) -> Result<i64> {
        self.submit_opts(model, features, &CallOptions::default())
    }

    /// Pipelined submit with explicit per-request execution options.
    pub fn submit_opts(
        &mut self,
        model: Option<&str>,
        features: &[f32],
        opts: &CallOptions,
    ) -> Result<i64> {
        let id = self.fresh_id();
        self.send(&Request::Infer {
            id,
            model: model.map(str::to_string),
            backend: opts.backend,
            exec: opts.exec(),
            features: features.to_vec(),
        })?;
        self.outstanding.insert(id);
        Ok(id)
    }

    /// Next completed inference (not submission order). Returns the
    /// request id and its outcome. Responses are yielded as they are
    /// read off the wire — i.e. in server completion order — except
    /// that completions stashed while an interleaved synchronous call
    /// waited for its own id drain first, in ascending-id order.
    /// Polling with no submissions outstanding is an error (the server
    /// owes nothing; blocking would hang forever).
    pub fn poll(&mut self) -> Result<(i64, Result<Inference>)> {
        let stashed = self.completed.keys().next().copied();
        if let Some(id) = stashed {
            let resp = self.completed.remove(&id).expect("key just observed");
            self.outstanding.remove(&id);
            return Ok((id, into_inference(resp)));
        }
        if self.outstanding.is_empty() {
            // every submitted id has been returned: the server owes no
            // response, so a socket read would block forever
            return Err(Error::Serving("poll() with no requests in flight".into()));
        }
        let resp = self.read_response()?;
        match resp.id() {
            Some(id) => {
                self.outstanding.remove(&id);
                Ok((id, into_inference(resp)))
            }
            None => match resp {
                Response::Error { code, message, .. } => Err(Error::Serving(format!(
                    "connection error [{}]: {message}",
                    code.as_str()
                ))),
                other => Err(unexpected(other)),
            },
        }
    }

    /// Registered models behind the endpoint (control plane).
    pub fn list_models(&mut self) -> Result<Vec<ModelSummary>> {
        let id = self.fresh_id();
        match self.call(Request::ListModels { id })? {
            Response::ModelList { models, .. } => Ok(models),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Detail for one registered model.
    pub fn model_info(&mut self, name: &str) -> Result<ModelSummary> {
        let id = self.fresh_id();
        match self.call(Request::ModelInfo { id, model: name.to_string() })? {
            Response::ModelInfo { model, .. } => Ok(model),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Serving + wire metrics snapshot (free-form JSON report: a
    /// `"models"` object keyed by serving id and a `"wire"` section).
    pub fn metrics(&mut self) -> Result<Value> {
        let id = self.fresh_id();
        match self.call(Request::Metrics { id })? {
            Response::Metrics { body, .. } => Ok(body),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// The metrics snapshot rendered as Prometheus text exposition
    /// format (see `docs/OBSERVABILITY.md`).
    pub fn metrics_prom(&mut self) -> Result<String> {
        let id = self.fresh_id();
        match self.call(Request::MetricsProm { id })? {
            Response::MetricsProm { text, .. } => Ok(text),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Recent sampled request traces (free-form JSON report: a
    /// `"summary"` section and a `"spans"` array, newest first, capped
    /// at `limit` when given).
    pub fn trace(&mut self, limit: Option<usize>) -> Result<Value> {
        let id = self.fresh_id();
        match self.call(Request::Trace { id, limit })? {
            Response::Trace { body, .. } => Ok(body),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Endpoint health: `(status, live model count)`.
    pub fn health(&mut self) -> Result<(String, usize)> {
        let (status, models_live, _, _) = self.health_node()?;
        Ok((status, models_live))
    }

    /// Endpoint health with cluster identity: `(status, live model
    /// count, node_id, uptime_s)`. The identity fields are `None` when
    /// the endpoint was not started with one (see `docs/CLUSTER.md`).
    pub fn health_node(
        &mut self,
    ) -> Result<(String, usize, Option<String>, Option<u64>)> {
        let id = self.fresh_id();
        match self.call(Request::Health { id })? {
            Response::Health { status, models_live, node_id, uptime_s, .. } => {
                Ok((status, models_live, node_id, uptime_s))
            }
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Fetch one artifact by content digest: `(payload bytes, optional
    /// manifest metadata)`. The caller should re-hash and compare —
    /// [`crate::registry::digest::digest_bytes`] — before trusting the
    /// payload; the server verifies its copy before sending, but the
    /// bytes also crossed a network.
    pub fn pull_artifact(&mut self, digest: &str) -> Result<(Vec<u8>, Option<Value>)> {
        let id = self.fresh_id();
        match self.call(Request::PullArtifact { id, digest: digest.to_string() })? {
            Response::Artifact { data, meta, .. } => Ok((data, meta)),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Publish artifact bytes as model `model` on the remote endpoint
    /// (digest computed here and re-verified server-side). Returns the
    /// resolved `name@version` the server registered. Re-pushing bytes
    /// the server already serves under `model` is an idempotent no-op.
    pub fn push_artifact(
        &mut self,
        model: &str,
        version: Option<u32>,
        data: &[u8],
    ) -> Result<String> {
        let digest = crate::registry::digest::digest_bytes(data);
        let id = self.fresh_id();
        match self.call(Request::PushArtifact {
            id,
            model: model.to_string(),
            version,
            digest,
            data: data.to_vec(),
        })? {
            Response::Published { model, .. } => Ok(model),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    /// Start a staged canary rollout of `model` (the manifest-current
    /// version) against `baseline` (the retained previous version).
    /// Returns the initial rollout status body (see `docs/ROLLOUT.md`).
    pub fn rollout_start(&mut self, model: &str, baseline: &str) -> Result<Value> {
        let id = self.fresh_id();
        self.rollout_call(Request::RolloutStart {
            id,
            model: model.to_string(),
            baseline: baseline.to_string(),
        })
    }

    /// Rollout state machines, gate evaluations and decision history —
    /// every rollout on the endpoint, or just `model`'s.
    pub fn rollout_status(&mut self, model: Option<&str>) -> Result<Value> {
        let id = self.fresh_id();
        self.rollout_call(Request::RolloutStatus {
            id,
            model: model.map(str::to_string),
        })
    }

    /// Operator-initiated instant rollback of `model`'s rollout.
    pub fn rollout_abort(&mut self, model: &str) -> Result<Value> {
        let id = self.fresh_id();
        self.rollout_call(Request::RolloutAbort { id, model: model.to_string() })
    }

    /// Drop `model`'s terminal rollout record (and its routing
    /// override). Returns the final status body.
    pub fn rollout_clear(&mut self, model: &str) -> Result<Value> {
        let id = self.fresh_id();
        self.rollout_call(Request::RolloutClear { id, model: model.to_string() })
    }

    fn rollout_call(&mut self, req: Request) -> Result<Value> {
        match self.call(req)? {
            Response::Rollout { body, .. } => Ok(body),
            Response::Error { code, message, retry_after_ms, .. } => {
                Err(wire_error(code, &message, retry_after_ms))
            }
            other => Err(unexpected(other)),
        }
    }

    // ---- plumbing --------------------------------------------------------

    fn fresh_id(&mut self) -> i64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        let payload = req.to_value().to_string();
        // fail oversized requests client-side: the server would answer
        // too_large and drop the connection, losing every other request
        // pipelined on it
        if payload.len() > self.info.max_frame {
            return Err(Error::Serving(format!(
                "request of {} bytes exceeds the server's max_frame of {} bytes \
                 (split the batch)",
                payload.len(),
                self.info.max_frame
            )));
        }
        write_frame(&mut self.writer, payload.as_bytes())?;
        Ok(())
    }

    fn read_response(&mut self) -> Result<Response> {
        // the server's max_frame bounds *requests*; responses (e.g. a
        // large batch result) are not limited by it, so the client reads
        // with its own generous sanity cap against corrupt headers
        let cap = self.info.max_frame.max(MAX_RESPONSE_BYTES);
        match read_frame(&mut self.reader, cap)? {
            FrameRead::Frame(p) => Response::from_bytes(&p),
            FrameRead::Eof => Err(Error::Serving("connection closed by server".into())),
            FrameRead::TooLarge(n) => {
                // the payload was not consumed, so the frame stream can
                // never be resynced — poison the connection so later
                // calls fail fast instead of reading payload bytes as
                // frame headers
                let _ = self.writer.shutdown(std::net::Shutdown::Both);
                Err(Error::Serving(format!(
                    "server frame of {n} bytes exceeds the client cap; \
                     connection closed (stream cannot resync)"
                )))
            }
        }
    }

    /// Send and wait for the response with the same id, stashing any
    /// other completions for [`KanClient::poll`].
    fn call(&mut self, req: Request) -> Result<Response> {
        let id = req.id();
        self.send(&req)?;
        if let Some(resp) = self.completed.remove(&id) {
            return Ok(resp);
        }
        loop {
            let resp = self.read_response()?;
            match resp.id() {
                Some(rid) if rid == id => return Ok(resp),
                Some(rid) => {
                    self.completed.insert(rid, resp);
                }
                None => match resp {
                    Response::Error { code, message, .. } => {
                        return Err(Error::Serving(format!(
                            "connection error [{}]: {message}",
                            code.as_str()
                        )))
                    }
                    other => return Err(unexpected(other)),
                },
            }
        }
    }
}

fn into_inference(resp: Response) -> Result<Inference> {
    match resp {
        Response::Infer { model, row, .. } => Ok(Inference {
            model,
            logits: row.logits,
            class: row.class,
            std: row.std,
        }),
        Response::Error { code, message, retry_after_ms, .. } => {
            Err(wire_error(code, &message, retry_after_ms))
        }
        other => Err(unexpected(other)),
    }
}

/// Uniform client-side rendering of a wire error. Admission rejections
/// come back as the typed [`Error::Overloaded`] so callers can match on
/// it and honor the server's `retry_after_ms` backoff hint; everything
/// else keeps the machine-readable code in the message as `[code] ...`.
fn wire_error(
    code: crate::coordinator::protocol::ErrorCode,
    message: &str,
    retry_after_ms: Option<u64>,
) -> Error {
    if code == crate::coordinator::protocol::ErrorCode::Overloaded {
        return Error::Overloaded {
            message: message.to_string(),
            retry_after_ms: retry_after_ms.unwrap_or(0),
        };
    }
    Error::Serving(format!("[{}] {message}", code.as_str()))
}

fn unexpected(resp: Response) -> Error {
    Error::Serving(format!("unexpected response: {}", resp.to_value()))
}
