//! Dynamic batching: close a batch when it reaches `max_batch` or when the
//! oldest queued request has waited `deadline` — the standard
//! latency/throughput knob of serving systems (vLLM-style), sized here for
//! edge KAN inference where batches are small and deadlines tight.
//!
//! Requests arrive through the admission [`Scheduler`](super::scheduler)
//! (FIFO or deficit-round-robin — see `docs/SCHEDULING.md`); the batcher
//! runs on its own thread, pulling in the scheduler's fair order, and
//! emits closed batches to the worker pool over `std::sync::mpsc` (the
//! offline image has no tokio).

use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::backend::{ExecOptions, RowOutput};
use super::scheduler::{Recv, Scheduler};
use crate::error::{Error, Result};
use crate::obs::trace::TraceHandle;

/// One queued inference request. `respond` is a rendezvous channel the
/// worker pushes the result into (a one-shot). `opts` rides with the
/// row into the executed batch: per-request seeds/trials are resolved
/// at submission, so a dynamic batch can mix differently-seeded rows
/// without their outputs depending on batch composition.
pub struct Request {
    pub features: Vec<f32>,
    pub opts: ExecOptions,
    pub enqueued: Instant,
    pub respond: SyncSender<Result<RowOutput>>,
    /// Observability span for sampled requests (`None` for the
    /// unsampled majority): the batcher and workers stamp queue /
    /// batch / execute stage boundaries into it as the request moves
    /// through the pipeline (`docs/OBSERVABILITY.md`).
    pub trace: Option<TraceHandle>,
}

/// A closed batch ready for a backend.
pub struct Batch {
    pub requests: Vec<Request>,
    pub closed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Oldest queue wait in the batch (admission → close).
    pub fn max_queue_wait(&self) -> Duration {
        self.requests
            .iter()
            .map(|r| self.closed_at.duration_since(r.enqueued))
            .max()
            .unwrap_or_default()
    }
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, deadline: Duration::from_micros(500) }
    }
}

/// Pull requests from the admission scheduler and emit closed batches to
/// `tx`. Runs until the scheduler closes *and* drains; the partial batch
/// in flight at shutdown is flushed, never dropped. This is the leader
/// loop of the serving pipeline.
pub fn run_batcher(sched: Arc<Scheduler>, tx: SyncSender<Batch>, policy: BatchPolicy) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    loop {
        // wait for the first request of the batch
        let first = match sched.recv() {
            Some(r) => r,
            None => break, // closed and drained
        };
        let batch_deadline = Instant::now() + policy.deadline;
        pending.push(first);
        // fill until size or deadline
        while pending.len() < policy.max_batch {
            match sched.recv_deadline(batch_deadline) {
                Recv::Req(r) => pending.push(r),
                Recv::Timeout => break,
                // closed and drained: flush below, exit on the next recv
                Recv::Closed => break,
            }
        }
        let batch = Batch {
            requests: std::mem::take(&mut pending),
            closed_at: Instant::now(),
        };
        if tx.send(batch).is_err() {
            break; // executor side gone
        }
    }
}

/// Answer a request that was refused admission (or failed before
/// reaching a worker) with `err`.
pub fn reject(req: Request, err: Error) {
    let _ = req.respond.try_send(Err(err));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{ClientId, SchedulerOptions, Submit};
    use std::sync::mpsc::{sync_channel, Receiver as StdReceiver};
    use std::thread;

    fn mk_request(v: f32) -> (Request, StdReceiver<Result<RowOutput>>) {
        let (tx, rx) = sync_channel(1);
        (
            Request {
                features: vec![v],
                opts: ExecOptions::default(),
                enqueued: Instant::now(),
                respond: tx,
                trace: None,
            },
            rx,
        )
    }

    fn sched(capacity: usize) -> Arc<Scheduler> {
        Arc::new(Scheduler::new(capacity, SchedulerOptions::default()))
    }

    fn admit(s: &Scheduler, v: f32) -> StdReceiver<Result<RowOutput>> {
        let (req, rx) = mk_request(v);
        match s.try_submit(ClientId::fresh(), req) {
            Submit::Admitted => rx,
            _ => panic!("admission failed"),
        }
    }

    #[test]
    fn closes_on_max_batch() {
        let s = sched(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_secs(10) };
        let s2 = s.clone();
        thread::spawn(move || run_batcher(s2, batch_tx, policy));
        let mut keep = Vec::new();
        for i in 0..4 {
            keep.push(admit(&s, i as f32));
        }
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 4);
        s.close();
    }

    #[test]
    fn closes_on_deadline() {
        let s = sched(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy =
            BatchPolicy { max_batch: 100, deadline: Duration::from_millis(20) };
        let s2 = s.clone();
        thread::spawn(move || run_batcher(s2, batch_tx, policy));
        let t0 = Instant::now();
        let _rx = admit(&s, 1.0);
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        s.close();
    }

    #[test]
    fn flushes_on_shutdown() {
        let s = sched(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy = BatchPolicy { max_batch: 100, deadline: Duration::from_secs(10) };
        let s2 = s.clone();
        let handle = thread::spawn(move || run_batcher(s2, batch_tx, policy));
        let _rx = admit(&s, 1.0);
        thread::sleep(Duration::from_millis(20)); // batcher picked it up
        s.close(); // close while the batch is filling
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 1);
        handle.join().unwrap();
    }

    #[test]
    fn rejection_reply_reaches_the_waiter() {
        let (req, rx) = mk_request(2.0);
        reject(req, Error::Serving("queue full: admission rejected".into()));
        let resp = rx.recv().unwrap();
        assert!(resp.unwrap_err().to_string().contains("queue full"));
    }

    #[test]
    fn queue_wait_measured_from_enqueue() {
        let (tx, _rx) = sync_channel(1);
        let early = Request {
            features: vec![],
            opts: ExecOptions::default(),
            enqueued: Instant::now() - Duration::from_millis(50),
            respond: tx,
            trace: None,
        };
        let batch = Batch { requests: vec![early], closed_at: Instant::now() };
        assert!(batch.max_queue_wait() >= Duration::from_millis(50));
    }
}
