//! Dynamic batching: close a batch when it reaches `max_batch` or when the
//! oldest queued request has waited `deadline` — the standard
//! latency/throughput knob of serving systems (vLLM-style), sized here for
//! edge KAN inference where batches are small and deadlines tight.
//!
//! Built on `std::sync::mpsc` (the offline image has no tokio); the
//! batcher runs on its own thread and `recv_timeout` implements the
//! deadline.

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// One queued inference request. `respond` is a rendezvous channel the
/// worker pushes the result into (a one-shot).
pub struct Request {
    pub features: Vec<f32>,
    pub enqueued: Instant,
    pub respond: SyncSender<Result<Vec<f32>>>,
}

/// A closed batch ready for a backend.
pub struct Batch {
    pub requests: Vec<Request>,
    pub closed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Oldest queue wait in the batch (admission → close).
    pub fn max_queue_wait(&self) -> Duration {
        self.requests
            .iter()
            .map(|r| self.closed_at.duration_since(r.enqueued))
            .max()
            .unwrap_or_default()
    }
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, deadline: Duration::from_micros(500) }
    }
}

/// Pull requests from `rx` and emit closed batches to `tx`.
///
/// Runs until the request channel closes; flushes the partial batch on
/// shutdown. This is the leader loop of the serving pipeline.
pub fn run_batcher(rx: Receiver<Request>, tx: SyncSender<Batch>, policy: BatchPolicy) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    'outer: loop {
        // wait for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let batch_deadline = Instant::now() + policy.deadline;
        pending.push(first);
        // fill until size or deadline
        while pending.len() < policy.max_batch {
            let now = Instant::now();
            if now >= batch_deadline {
                break;
            }
            match rx.recv_timeout(batch_deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    // flush and stop
                    let batch = Batch {
                        requests: std::mem::take(&mut pending),
                        closed_at: Instant::now(),
                    };
                    let _ = tx.send(batch);
                    break 'outer;
                }
            }
        }
        let batch = Batch {
            requests: std::mem::take(&mut pending),
            closed_at: Instant::now(),
        };
        if tx.send(batch).is_err() {
            break; // executor side gone
        }
    }
}

/// Admit a request or hand it back. The error distinguishes a full
/// queue (admission control — retryable) from a disconnected channel
/// (service shut down — not), so callers report the right condition.
pub fn try_admit(
    tx: &SyncSender<Request>,
    req: Request,
) -> std::result::Result<(), TrySendError<Request>> {
    tx.try_send(req)
}

/// Standard rejection reply for a failed admission.
pub fn reject(req: Request) {
    let _ = req
        .respond
        .try_send(Err(Error::Serving("queue full: admission rejected".into())));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{sync_channel, Receiver as StdReceiver};
    use std::thread;

    fn mk_request(v: f32) -> (Request, StdReceiver<Result<Vec<f32>>>) {
        let (tx, rx) = sync_channel(1);
        (
            Request { features: vec![v], enqueued: Instant::now(), respond: tx },
            rx,
        )
    }

    #[test]
    fn closes_on_max_batch() {
        let (req_tx, req_rx) = sync_channel(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy = BatchPolicy { max_batch: 4, deadline: Duration::from_secs(10) };
        thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = mk_request(i as f32);
            keep.push(rx);
            req_tx.send(r).unwrap();
        }
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn closes_on_deadline() {
        let (req_tx, req_rx) = sync_channel(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy =
            BatchPolicy { max_batch: 100, deadline: Duration::from_millis(20) };
        thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        let (r, _rx) = mk_request(1.0);
        let t0 = Instant::now();
        req_tx.send(r).unwrap();
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn flushes_on_shutdown() {
        let (req_tx, req_rx) = sync_channel(64);
        let (batch_tx, batch_rx) = sync_channel(8);
        let policy = BatchPolicy { max_batch: 100, deadline: Duration::from_secs(10) };
        let handle = thread::spawn(move || run_batcher(req_rx, batch_tx, policy));
        let (r, _rx) = mk_request(1.0);
        req_tx.send(r).unwrap();
        thread::sleep(Duration::from_millis(20)); // batcher picked it up
        drop(req_tx); // close channel while batch is filling
        let batch = batch_rx.recv().unwrap();
        assert_eq!(batch.len(), 1);
        handle.join().unwrap();
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let (req_tx, _req_rx) = sync_channel(1);
        let (r1, _rx1) = mk_request(1.0);
        assert!(try_admit(&req_tx, r1).is_ok());
        let (r2, rx2) = mk_request(2.0);
        let rejected = match try_admit(&req_tx, r2) {
            Err(TrySendError::Full(r)) => r,
            other => panic!("expected Full, got {:?}", other.is_ok()),
        };
        reject(rejected);
        let resp = rx2.recv().unwrap();
        assert!(resp.is_err());
    }

    #[test]
    fn admission_distinguishes_shutdown_from_full() {
        let (req_tx, req_rx) = sync_channel(1);
        drop(req_rx);
        let (r, _rx) = mk_request(1.0);
        assert!(matches!(
            try_admit(&req_tx, r),
            Err(TrySendError::Disconnected(_))
        ));
    }

    #[test]
    fn queue_wait_measured_from_enqueue() {
        let (tx, _rx) = sync_channel(1);
        let early = Request {
            features: vec![],
            enqueued: Instant::now() - Duration::from_millis(50),
            respond: tx,
        };
        let batch = Batch { requests: vec![early], closed_at: Instant::now() };
        assert!(batch.max_queue_wait() >= Duration::from_millis(50));
    }
}
