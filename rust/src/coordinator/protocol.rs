//! Protocol v2: the framed, pipelined serving wire format.
//!
//! A v2 connection opens with the 4-byte [`MAGIC`] preamble (`KAN2`);
//! everything after it, in both directions, is a stream of frames: a
//! 4-byte big-endian payload length followed by that many bytes of UTF-8
//! JSON. Every request carries a client-chosen integer `id` and an `op`
//! verb; every response echoes the `id`, so responses may arrive out of
//! order relative to submission (the server dispatches inference
//! concurrently per connection). The full wire specification — v1
//! JSON-lines included — lives in `docs/PROTOCOL.md`.
//!
//! This module is the *typed* layer: the frame codec ([`read_frame`] /
//! [`write_frame`]) plus [`Request`] / [`Response`] enums with exact
//! JSON mappings, shared by the server ([`super::tcp`]) and the client
//! ([`crate::client::KanClient`]).

use std::collections::BTreeMap;
use std::io::{Read, Write};

use super::backend::{BackendKind, BackendSpec, ExecOptions, MAX_TRIALS};
use crate::error::Error;
use crate::util::json::{arr, obj, Value};

/// Connection preamble selecting protocol v2 (v1 lines start with `{`).
pub const MAGIC: [u8; 4] = *b"KAN2";

/// Protocol version announced in the `hello` response.
pub const PROTOCOL_VERSION: u32 = 2;

// ---- frame codec ----------------------------------------------------------

/// Outcome of reading one frame off the wire.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame payload.
    Frame(Vec<u8>),
    /// Clean end of stream (EOF before any header byte).
    Eof,
    /// Header declared a payload larger than the limit; the payload was
    /// *not* consumed, so the stream cannot be resynchronized — the
    /// caller must drop the connection after reporting the error.
    TooLarge(usize),
}

/// Read one length-prefixed frame. EOF mid-header or mid-payload is an
/// `UnexpectedEof` error (a truncated frame), unlike the clean
/// [`FrameRead::Eof`] before any byte.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> std::io::Result<FrameRead> {
    let mut header = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        // retry EINTR like read_exact does for the payload below; a
        // signal must not tear down a healthy connection mid-header
        // lint: allow(index, "got < 4 is the loop condition; header is [u8; 4]")
        let n = match r.read(&mut header[got..]) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if n == 0 {
            if got == 0 {
                return Ok(FrameRead::Eof);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated frame header",
            ));
        }
        got += n;
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max_frame {
        return Ok(FrameRead::TooLarge(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(FrameRead::Frame(payload))
}

/// Write one length-prefixed frame and flush. Header and payload are
/// assembled into a single buffer so each frame is one write syscall
/// (TcpStreams here are unbuffered, and a separate 4-byte header write
/// interacts badly with Nagle + delayed ACKs).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame payload too large")
    })?;
    let mut frame = Vec::with_capacity(payload.len() + 4);
    frame.extend_from_slice(&len.to_be_bytes());
    frame.extend_from_slice(payload);
    w.write_all(&frame)?;
    w.flush()
}

// ---- error codes ----------------------------------------------------------

/// Machine-readable wire error codes (the `code` field of an error
/// response).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed request (bad JSON, missing fields, wrong types).
    BadRequest,
    /// Unknown model / verb target.
    NotFound,
    /// Line or frame exceeded `server.max_request_bytes`.
    TooLarge,
    /// Admission control rejected the request (queue full).
    Overloaded,
    /// The request conflicts with current server state (e.g. starting a
    /// rollout while one is already in progress, or aborting one that
    /// already finished).
    Conflict,
    /// Unknown `op`.
    Unsupported,
    /// Anything else server-side.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::NotFound => "not_found",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Conflict => "conflict",
            ErrorCode::Unsupported => "unsupported",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "bad_request" => ErrorCode::BadRequest,
            "not_found" => ErrorCode::NotFound,
            "too_large" => ErrorCode::TooLarge,
            "overloaded" => ErrorCode::Overloaded,
            "conflict" => ErrorCode::Conflict,
            "unsupported" => ErrorCode::Unsupported,
            _ => ErrorCode::Internal,
        }
    }
}

/// Map a crate error onto a wire error code. Heuristic on the stable
/// message wording (asserted by the error-type tests), since the crate
/// error keeps transport-agnostic variants.
pub fn code_for(e: &Error) -> ErrorCode {
    match e {
        Error::Overloaded { .. } => ErrorCode::Overloaded,
        Error::Serving(m) if m.contains("queue full") => ErrorCode::Overloaded,
        Error::Serving(m) if m.contains("single model") => ErrorCode::NotFound,
        // backend-selection routing: the requested kind exists but this
        // endpoint/model cannot execute it
        Error::Serving(m) if m.contains("not served here") => ErrorCode::NotFound,
        // replication verbs hitting a dispatch target without a
        // registry behind it (single-model endpoints)
        Error::Serving(m) if m.contains("not supported on this endpoint") => {
            ErrorCode::Unsupported
        }
        // rollout lifecycle conflicts: the request is well-formed but
        // the state machine is not where it requires
        Error::Serving(m)
            if m.contains("already in progress") || m.contains("already finished") =>
        {
            ErrorCode::Conflict
        }
        Error::Serving(m) if m.contains("no rollout") => ErrorCode::NotFound,
        // the worker pool re-wraps backend errors as Serving with the
        // original message; a shape mismatch is the client's fault
        Error::Serving(m) if m.contains("shape mismatch") => ErrorCode::BadRequest,
        Error::Registry(m) if m.contains("digest mismatch") => ErrorCode::Internal,
        Error::Registry(_) => ErrorCode::NotFound,
        Error::Json(_) | Error::Shape(_) | Error::Config(_) => ErrorCode::BadRequest,
        _ => ErrorCode::Internal,
    }
}

/// Build the error response for a crate error: maps the code and, for
/// admission rejections, surfaces the structured `retry_after_ms` hint.
/// Overloaded errors ship the *bare* message — the `code` and
/// `retry_after_ms` fields carry the rest, and a client reconstructing
/// a typed error from the frame must not end up double-prefixed.
pub fn error_response(id: Option<i64>, e: &Error) -> Response {
    match e {
        Error::Overloaded { message, retry_after_ms } => Response::Error {
            id,
            code: ErrorCode::Overloaded,
            message: message.clone(),
            retry_after_ms: Some(*retry_after_ms),
        },
        _ => Response::Error {
            id,
            code: code_for(e),
            message: e.to_string(),
            retry_after_ms: None,
        },
    }
}

/// A request that could not be turned into a [`Request`]: carries the id
/// when one was extractable so the error response still correlates.
#[derive(Debug)]
pub struct WireError {
    pub id: Option<i64>,
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    fn bad(id: Option<i64>, message: impl Into<String>) -> Self {
        Self { id, code: ErrorCode::BadRequest, message: message.into() }
    }

    pub fn into_response(self) -> Response {
        Response::Error {
            id: self.id,
            code: self.code,
            message: self.message,
            retry_after_ms: None,
        }
    }
}

// ---- model summaries ------------------------------------------------------

/// Served-backend capabilities of a live model, as surfaced by the
/// control plane: the [`BackendSpec`] of the primary session plus the
/// shadow-mirror status. Clients discover what a model can do (is it
/// deterministic? reference-exact? what dims?) instead of inferring it
/// from the backend name.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendInfo {
    pub kind: String,
    pub deterministic: bool,
    pub reference_exact: bool,
    pub input_dim: Option<usize>,
    pub output_dim: usize,
    /// Mirrored backend kind + sampled traffic fraction, when a shadow
    /// runs alongside the primary.
    pub shadow: Option<(String, f64)>,
}

impl BackendInfo {
    /// Build from a session's capability descriptor and the optional
    /// shadow `(kind, fraction)`.
    pub fn from_spec(spec: &BackendSpec, shadow: Option<(BackendKind, f64)>) -> Self {
        Self {
            kind: spec.kind.as_str().to_string(),
            deterministic: spec.deterministic,
            reference_exact: spec.reference_exact,
            input_dim: spec.input_dim,
            output_dim: spec.output_dim,
            shadow: shadow.map(|(k, f)| (k.as_str().to_string(), f)),
        }
    }

    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("kind", Value::Str(self.kind.clone())),
            ("deterministic", Value::Bool(self.deterministic)),
            ("reference_exact", Value::Bool(self.reference_exact)),
            ("output_dim", Value::Int(self.output_dim as i64)),
        ];
        if let Some(d) = self.input_dim {
            fields.push(("input_dim", Value::Int(d as i64)));
        }
        if let Some((kind, fraction)) = &self.shadow {
            fields.push((
                "shadow",
                obj(vec![
                    ("backend", Value::Str(kind.clone())),
                    ("fraction", Value::Float(*fraction)),
                ]),
            ));
        }
        obj(fields)
    }

    pub fn from_value(v: &Value) -> crate::error::Result<BackendInfo> {
        Ok(BackendInfo {
            kind: v.req_str("kind")?.to_string(),
            deterministic: v.get("deterministic").and_then(|b| b.as_bool()).unwrap_or(true),
            reference_exact: v
                .get("reference_exact")
                .and_then(|b| b.as_bool())
                .unwrap_or(false),
            input_dim: v.get("input_dim").and_then(|d| d.as_usize()),
            output_dim: v.req_usize("output_dim")?,
            shadow: match v.get("shadow") {
                None => None,
                Some(s) => Some((
                    s.req_str("backend")?.to_string(),
                    s.get("fraction").and_then(|f| f.as_f64()).unwrap_or(0.0),
                )),
            },
        })
    }
}

/// Control-plane summary of one registered model, as exposed by the
/// `list_models` / `model_info` verbs (and
/// [`Dispatch::model_summaries`](super::server::Dispatch::model_summaries)).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSummary {
    pub name: String,
    pub version: u32,
    pub kind: String,
    pub dims: Vec<usize>,
    pub num_params: usize,
    /// Whether a serving pipeline is currently loaded for it.
    pub live: bool,
    pub accuracy: Option<f64>,
    pub digest: Option<String>,
    /// Served-backend capabilities; present only while a pipeline is
    /// live (a non-live model has no compiled session to describe).
    pub backend: Option<BackendInfo>,
}

impl ModelSummary {
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("name", Value::Str(self.name.clone())),
            ("version", Value::Int(self.version as i64)),
            ("kind", Value::Str(self.kind.clone())),
            ("dims", arr(self.dims.iter().map(|&d| Value::Int(d as i64)).collect())),
            ("num_params", Value::Int(self.num_params as i64)),
            ("live", Value::Bool(self.live)),
        ];
        if let Some(a) = self.accuracy {
            fields.push(("accuracy", Value::Float(a)));
        }
        if let Some(d) = &self.digest {
            fields.push(("digest", Value::Str(d.clone())));
        }
        if let Some(b) = &self.backend {
            fields.push(("backend", b.to_value()));
        }
        obj(fields)
    }

    pub fn from_value(v: &Value) -> crate::error::Result<ModelSummary> {
        let dims = v
            .req_array("dims")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| Error::Json("'dims': non-integer element".into()))
            })
            .collect::<crate::error::Result<Vec<usize>>>()?;
        Ok(ModelSummary {
            name: v.req_str("name")?.to_string(),
            version: v.req_usize("version")? as u32,
            kind: v.req_str("kind")?.to_string(),
            dims,
            num_params: v.req_usize("num_params")?,
            live: v.get("live").and_then(|b| b.as_bool()).unwrap_or(false),
            accuracy: v.get("accuracy").and_then(|a| a.as_f64()),
            digest: v.get("digest").and_then(|d| d.as_str()).map(str::to_string),
            backend: match v.get("backend") {
                None => None,
                Some(b) => Some(BackendInfo::from_value(b)?),
            },
        })
    }
}

// ---- requests -------------------------------------------------------------

/// A typed v2 request. Every variant carries the client-chosen `id` the
/// response must echo.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Capability / version negotiation (optional but recommended first
    /// request on a connection).
    Hello { id: i64, client: Option<String> },
    /// Liveness round-trip.
    Ping { id: i64 },
    /// One feature vector; `model` routes like v1's `"model"` field,
    /// `backend` selects an execution backend for this request only,
    /// and `exec` carries the ACIM `seed`/`trials` options.
    Infer {
        id: i64,
        model: Option<String>,
        backend: Option<BackendKind>,
        exec: ExecOptions,
        features: Vec<f32>,
    },
    /// A whole batch of rows, resolved once and fed to the model's
    /// dynamic batcher back-to-back. Batches are keyed by
    /// `(model, backend, options)` — mixed traffic batches correctly
    /// because each row carries its own derived options.
    InferBatch {
        id: i64,
        model: Option<String>,
        backend: Option<BackendKind>,
        exec: ExecOptions,
        rows: Vec<Vec<f32>>,
    },
    /// Registered models (control plane).
    ListModels { id: i64 },
    /// Detail for one registered model.
    ModelInfo { id: i64, model: String },
    /// Serving + wire metrics snapshot.
    Metrics { id: i64 },
    /// The same snapshot rendered as Prometheus text exposition format
    /// (see `docs/OBSERVABILITY.md`).
    MetricsProm { id: i64 },
    /// Recent sampled request traces (newest first), capped at `limit`
    /// spans when given.
    Trace { id: i64, limit: Option<usize> },
    /// Endpoint health.
    Health { id: i64 },
    /// Fetch a stored artifact (weights blob) by content digest — the
    /// read half of on-demand cluster replication. The payload rides
    /// the frame hex-encoded (the JSON layer has no binary type).
    PullArtifact { id: i64, digest: String },
    /// Publish an artifact payload as `model` (optionally at an exact
    /// `version`) — the write half of replication. The receiver
    /// re-hashes the payload and rejects on digest mismatch *before*
    /// anything is published.
    PushArtifact {
        id: i64,
        model: String,
        version: Option<u32>,
        digest: String,
        data: Vec<u8>,
    },
    /// Start a staged canary rollout: ramp `model` (which must resolve
    /// to the manifest-current version) against `baseline` (the warm
    /// previous version retained at hot-swap time). See
    /// `docs/ROLLOUT.md`.
    RolloutStart { id: i64, model: String, baseline: String },
    /// Rollout state machines, per-window gate evaluations and decision
    /// history — every rollout, or just `model`'s.
    RolloutStatus { id: i64, model: Option<String> },
    /// Operator-initiated instant rollback of `model`'s rollout.
    RolloutAbort { id: i64, model: String },
    /// Drop `model`'s terminal rollout record and its routing override.
    RolloutClear { id: i64, model: String },
}

impl Request {
    pub fn id(&self) -> i64 {
        match self {
            Request::Hello { id, .. }
            | Request::Ping { id }
            | Request::Infer { id, .. }
            | Request::InferBatch { id, .. }
            | Request::ListModels { id }
            | Request::ModelInfo { id, .. }
            | Request::Metrics { id }
            | Request::MetricsProm { id }
            | Request::Trace { id, .. }
            | Request::Health { id }
            | Request::PullArtifact { id, .. }
            | Request::PushArtifact { id, .. }
            | Request::RolloutStart { id, .. }
            | Request::RolloutStatus { id, .. }
            | Request::RolloutAbort { id, .. }
            | Request::RolloutClear { id, .. } => *id,
        }
    }

    pub fn to_value(&self) -> Value {
        fn base(id: i64, op: &str) -> Vec<(&str, Value)> {
            vec![("id", Value::Int(id)), ("op", Value::Str(op.to_string()))]
        }
        fn floats(xs: &[f32]) -> Value {
            arr(xs.iter().map(|&v| Value::Float(v as f64)).collect())
        }
        match self {
            Request::Hello { id, client } => {
                let mut fields = base(*id, "hello");
                if let Some(c) = client {
                    fields.push(("client", Value::Str(c.clone())));
                }
                obj(fields)
            }
            Request::Ping { id } => obj(base(*id, "ping")),
            Request::Infer { id, model, backend, exec, features } => {
                let mut fields = base(*id, "infer");
                if let Some(m) = model {
                    fields.push(("model", Value::Str(m.clone())));
                }
                push_exec_fields(&mut fields, *backend, exec);
                fields.push(("features", floats(features)));
                obj(fields)
            }
            Request::InferBatch { id, model, backend, exec, rows } => {
                let mut fields = base(*id, "infer_batch");
                if let Some(m) = model {
                    fields.push(("model", Value::Str(m.clone())));
                }
                push_exec_fields(&mut fields, *backend, exec);
                fields.push(("rows", arr(rows.iter().map(|r| floats(r)).collect())));
                obj(fields)
            }
            Request::ListModels { id } => obj(base(*id, "list_models")),
            Request::ModelInfo { id, model } => {
                let mut fields = base(*id, "model_info");
                fields.push(("model", Value::Str(model.clone())));
                obj(fields)
            }
            Request::Metrics { id } => obj(base(*id, "metrics")),
            Request::MetricsProm { id } => obj(base(*id, "metrics_prom")),
            Request::Trace { id, limit } => {
                let mut fields = base(*id, "trace");
                if let Some(n) = limit {
                    fields.push(("limit", Value::Int(*n as i64)));
                }
                obj(fields)
            }
            Request::Health { id } => obj(base(*id, "health")),
            Request::PullArtifact { id, digest } => {
                let mut fields = base(*id, "pull_artifact");
                fields.push(("digest", Value::Str(digest.clone())));
                obj(fields)
            }
            Request::PushArtifact { id, model, version, digest, data } => {
                let mut fields = base(*id, "push_artifact");
                fields.push(("model", Value::Str(model.clone())));
                if let Some(ver) = version {
                    fields.push(("version", Value::Int(*ver as i64)));
                }
                fields.push(("digest", Value::Str(digest.clone())));
                fields.push((
                    "data",
                    Value::Str(crate::registry::store::encode_hex(data)),
                ));
                obj(fields)
            }
            Request::RolloutStart { id, model, baseline } => {
                let mut fields = base(*id, "rollout_start");
                fields.push(("model", Value::Str(model.clone())));
                fields.push(("baseline", Value::Str(baseline.clone())));
                obj(fields)
            }
            Request::RolloutStatus { id, model } => {
                let mut fields = base(*id, "rollout_status");
                if let Some(m) = model {
                    fields.push(("model", Value::Str(m.clone())));
                }
                obj(fields)
            }
            Request::RolloutAbort { id, model } => {
                let mut fields = base(*id, "rollout_abort");
                fields.push(("model", Value::Str(model.clone())));
                obj(fields)
            }
            Request::RolloutClear { id, model } => {
                let mut fields = base(*id, "rollout_clear");
                fields.push(("model", Value::Str(model.clone())));
                obj(fields)
            }
        }
    }

    /// Parse a frame payload (UTF-8 JSON) into a typed request.
    pub fn from_bytes(payload: &[u8]) -> std::result::Result<Request, WireError> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| WireError::bad(None, "frame payload is not UTF-8"))?;
        let v = Value::parse(text)
            .map_err(|e| WireError::bad(None, format!("bad request: {e}")))?;
        Request::from_value(&v)
    }

    pub fn from_value(v: &Value) -> std::result::Result<Request, WireError> {
        let id = v.get("id").and_then(|x| x.as_i64());
        let op = match v.get("op").and_then(|x| x.as_str()) {
            Some(o) => o,
            None => return Err(WireError::bad(id, "missing string 'op'")),
        };
        let id = match id {
            Some(i) => i,
            None => {
                return Err(WireError::bad(
                    None,
                    format!("missing integer 'id' for op '{op}'"),
                ))
            }
        };
        let model = match v.get("model") {
            None | Some(Value::Null) => None,
            Some(Value::Str(s)) => Some(s.clone()),
            Some(_) => return Err(WireError::bad(Some(id), "'model' must be a string")),
        };
        match op {
            "hello" => Ok(Request::Hello {
                id,
                client: v.get("client").and_then(|c| c.as_str()).map(str::to_string),
            }),
            "ping" => Ok(Request::Ping { id }),
            "infer" => {
                let (backend, exec) = parse_exec_fields(v, id)?;
                let features = v
                    .f32_vec("features")
                    .map_err(|e| WireError::bad(Some(id), e.to_string()))?;
                Ok(Request::Infer { id, model, backend, exec, features })
            }
            "infer_batch" => {
                let (backend, exec) = parse_exec_fields(v, id)?;
                let rows = parse_rows(v, id)?;
                Ok(Request::InferBatch { id, model, backend, exec, rows })
            }
            "list_models" => Ok(Request::ListModels { id }),
            "model_info" => match model {
                Some(m) => Ok(Request::ModelInfo { id, model: m }),
                None => Err(WireError::bad(Some(id), "'model_info' requires 'model'")),
            },
            "metrics" => Ok(Request::Metrics { id }),
            "metrics_prom" => Ok(Request::MetricsProm { id }),
            "trace" => {
                let limit = match v.get("limit") {
                    None | Some(Value::Null) => None,
                    Some(l) => Some(l.as_usize().ok_or_else(|| {
                        WireError::bad(
                            Some(id),
                            "'limit' must be a non-negative integer",
                        )
                    })?),
                };
                Ok(Request::Trace { id, limit })
            }
            "health" => Ok(Request::Health { id }),
            "pull_artifact" => {
                let digest = v
                    .req_str("digest")
                    .map_err(|e| WireError::bad(Some(id), e.to_string()))?
                    .to_string();
                Ok(Request::PullArtifact { id, digest })
            }
            "push_artifact" => {
                let model = match model {
                    Some(m) => m,
                    None => {
                        return Err(WireError::bad(
                            Some(id),
                            "'push_artifact' requires 'model'",
                        ))
                    }
                };
                let version = match v.get("version") {
                    None | Some(Value::Null) => None,
                    Some(n) => Some(n.as_usize().ok_or_else(|| {
                        WireError::bad(
                            Some(id),
                            "'version' must be a non-negative integer",
                        )
                    })? as u32),
                };
                let digest = v
                    .req_str("digest")
                    .map_err(|e| WireError::bad(Some(id), e.to_string()))?
                    .to_string();
                let data = crate::registry::store::decode_hex(
                    v.req_str("data")
                        .map_err(|e| WireError::bad(Some(id), e.to_string()))?,
                )
                .map_err(|e| WireError::bad(Some(id), e.to_string()))?;
                Ok(Request::PushArtifact { id, model, version, digest, data })
            }
            "rollout_start" => {
                let model = match model {
                    Some(m) => m,
                    None => {
                        return Err(WireError::bad(
                            Some(id),
                            "'rollout_start' requires 'model'",
                        ))
                    }
                };
                let baseline = v
                    .req_str("baseline")
                    .map_err(|e| WireError::bad(Some(id), e.to_string()))?
                    .to_string();
                Ok(Request::RolloutStart { id, model, baseline })
            }
            "rollout_status" => Ok(Request::RolloutStatus { id, model }),
            "rollout_abort" => match model {
                Some(m) => Ok(Request::RolloutAbort { id, model: m }),
                None => Err(WireError::bad(Some(id), "'rollout_abort' requires 'model'")),
            },
            "rollout_clear" => match model {
                Some(m) => Ok(Request::RolloutClear { id, model: m }),
                None => Err(WireError::bad(Some(id), "'rollout_clear' requires 'model'")),
            },
            other => Err(WireError {
                id: Some(id),
                code: ErrorCode::Unsupported,
                message: format!("unknown op '{other}'"),
            }),
        }
    }
}

fn parse_rows(v: &Value, id: i64) -> std::result::Result<Vec<Vec<f32>>, WireError> {
    let rows_v = v
        .req_array("rows")
        .map_err(|e| WireError::bad(Some(id), e.to_string()))?;
    if rows_v.is_empty() {
        return Err(WireError::bad(Some(id), "'rows' must be non-empty"));
    }
    let mut rows = Vec::with_capacity(rows_v.len());
    for (i, rv) in rows_v.iter().enumerate() {
        let items = rv.as_array().ok_or_else(|| {
            WireError::bad(Some(id), format!("'rows[{i}]' is not an array"))
        })?;
        let mut row = Vec::with_capacity(items.len());
        for x in items {
            let f = x.as_f64().ok_or_else(|| {
                WireError::bad(Some(id), format!("'rows[{i}]' has a non-number element"))
            })?;
            row.push(f as f32);
        }
        rows.push(row);
    }
    Ok(rows)
}

/// Serialize the per-request execution fields, omitting defaults so
/// pre-existing clients' frames stay byte-identical.
fn push_exec_fields(
    fields: &mut Vec<(&str, Value)>,
    backend: Option<BackendKind>,
    exec: &ExecOptions,
) {
    if let Some(b) = backend {
        fields.push(("backend", Value::Str(b.as_str().to_string())));
    }
    if let Some(s) = exec.seed {
        fields.push(("seed", Value::Int(s as i64)));
    }
    if exec.trials != 1 {
        fields.push(("trials", Value::Int(exec.trials as i64)));
    }
}

/// Parse (and validate) the optional `backend` / `seed` / `trials`
/// request fields. An unknown backend name or an out-of-range trial
/// count is a typed `bad_request` — validated once here, at the wire
/// boundary, so nothing stringly-typed reaches the dispatch path.
fn parse_exec_fields(
    v: &Value,
    id: i64,
) -> std::result::Result<(Option<BackendKind>, ExecOptions), WireError> {
    let backend = match v.get("backend") {
        None | Some(Value::Null) => None,
        Some(Value::Str(s)) => Some(
            BackendKind::parse(s).map_err(|e| WireError::bad(Some(id), e.to_string()))?,
        ),
        Some(_) => return Err(WireError::bad(Some(id), "'backend' must be a string")),
    };
    let seed = match v.get("seed") {
        None | Some(Value::Null) => None,
        // i64 on the wire (JSON has no u64); the bit pattern is the seed
        Some(s) => Some(s.as_i64().ok_or_else(|| {
            WireError::bad(Some(id), "'seed' must be an integer")
        })? as u64),
    };
    let trials = match v.get("trials") {
        None | Some(Value::Null) => 1u32,
        Some(t) => {
            let t = t.as_i64().ok_or_else(|| {
                WireError::bad(Some(id), "'trials' must be an integer")
            })?;
            if t < 1 || t > MAX_TRIALS as i64 {
                return Err(WireError::bad(
                    Some(id),
                    format!("'trials' must be in 1..={MAX_TRIALS} (got {t})"),
                ));
            }
            t as u32
        }
    };
    Ok((backend, ExecOptions { seed, trials }))
}

// ---- responses ------------------------------------------------------------

/// One row's inference result on the wire: logits, argmax class, and —
/// for stochastic backends run with `trials > 1` — the per-logit
/// standard deviation across trials (the served uncertainty estimate).
#[derive(Debug, Clone, PartialEq)]
pub struct WireRow {
    pub logits: Vec<f32>,
    pub class: usize,
    pub std: Option<Vec<f32>>,
}

impl WireRow {
    fn to_fields(&self) -> Vec<(&'static str, Value)> {
        fn floats(xs: &[f32]) -> Value {
            arr(xs.iter().map(|&v| Value::Float(v as f64)).collect())
        }
        let mut fields = vec![
            ("logits", floats(&self.logits)),
            ("class", Value::Int(self.class as i64)),
        ];
        if let Some(s) = &self.std {
            fields.push(("std", floats(s)));
        }
        fields
    }

    fn from_value(v: &Value) -> crate::error::Result<WireRow> {
        Ok(WireRow {
            logits: v.f32_vec("logits")?,
            class: v.req_usize("class")?,
            std: match v.get("std") {
                None => None,
                Some(_) => Some(v.f32_vec("std")?),
            },
        })
    }
}

/// Which rollout control verb a [`Response::Rollout`] answers; its
/// `wire_op` is the wire `op` (mirrors the request verb).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutVerb {
    Start,
    Status,
    Abort,
    Clear,
}

impl RolloutVerb {
    pub fn wire_op(self) -> &'static str {
        match self {
            RolloutVerb::Start => "rollout_start",
            RolloutVerb::Status => "rollout_status",
            RolloutVerb::Abort => "rollout_abort",
            RolloutVerb::Clear => "rollout_clear",
        }
    }
}

/// A typed v2 response. `op` on the wire mirrors the request verb
/// (`"pong"` for ping, `"error"` for failures).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello {
        id: i64,
        protocol: u32,
        server: String,
        max_frame: usize,
        max_in_flight: usize,
        /// Stable cluster identity of the answering node; `None` on
        /// endpoints spawned without one (pre-cluster deployments).
        node_id: Option<String>,
        /// Seconds since the node's serving endpoint came up.
        uptime_s: Option<u64>,
    },
    Pong { id: i64 },
    Infer { id: i64, model: String, row: WireRow },
    /// One result per submitted row, in row order.
    InferBatch { id: i64, model: String, results: Vec<WireRow> },
    ModelList { id: i64, models: Vec<ModelSummary> },
    ModelInfo { id: i64, model: ModelSummary },
    /// Free-form report object (per-model serving metrics + wire
    /// counters); kept as JSON because its shape evolves with the
    /// metrics, not with the protocol.
    Metrics { id: i64, body: Value },
    /// The metrics snapshot rendered as Prometheus text exposition.
    MetricsProm { id: i64, text: String },
    /// Free-form trace report (sampler summary + recent spans); JSON
    /// for the same reason as `Metrics`.
    Trace { id: i64, body: Value },
    Health {
        id: i64,
        status: String,
        models_live: usize,
        /// Node identity + uptime, mirroring `hello` — the fields a
        /// router heartbeat keys on. `None` on pre-cluster endpoints.
        node_id: Option<String>,
        uptime_s: Option<u64>,
    },
    /// A stored artifact fetched by digest (`pull_artifact` reply):
    /// the raw payload plus the manifest metadata describing the model
    /// entry it backs (name/version/kind), so a replica can republish
    /// it under the same identity.
    Artifact {
        id: i64,
        digest: String,
        data: Vec<u8>,
        meta: Option<Value>,
    },
    /// Acknowledgement of `push_artifact`: the resolved `name@version`
    /// the payload was published as, plus its verified digest.
    Published { id: i64, model: String, digest: String },
    /// Reply to any `rollout_*` verb: a free-form status body (state
    /// machine, per-window gate evaluations, decision history) — JSON
    /// because its shape evolves with the controller, not the protocol.
    Rollout { id: i64, verb: RolloutVerb, body: Value },
    /// `id` is `None` for connection-level errors (unparseable frame,
    /// oversized payload) that cannot be correlated. `retry_after_ms` is
    /// present on `overloaded` admission rejections: a best-effort
    /// backoff hint derived from the observed queue-drain rate.
    Error {
        id: Option<i64>,
        code: ErrorCode,
        message: String,
        retry_after_ms: Option<u64>,
    },
}

/// Merge transport framing (`id`, `op`) into a free-form report body —
/// the serialization of body-carrying responses (`metrics`, `trace`).
/// A non-object body is wrapped under `"body"` so the framing fields
/// can never be clobbered.
fn merge_body(id: i64, op: &str, body: &Value) -> Value {
    let mut map = match body {
        Value::Object(m) => m.clone(),
        other => {
            let mut m = BTreeMap::new();
            m.insert("body".to_string(), other.clone());
            m
        }
    };
    map.insert("id".to_string(), Value::Int(id));
    map.insert("op".to_string(), Value::Str(op.to_string()));
    Value::Object(map)
}

/// Strip the transport framing back out of a body-carrying response so
/// the body round-trips symmetrically (`v` is an object — `op` was
/// just read from it).
fn strip_body(v: &Value) -> Value {
    let mut map = match v {
        Value::Object(m) => m.clone(),
        _ => BTreeMap::new(),
    };
    map.remove("id");
    map.remove("op");
    Value::Object(map)
}

impl Response {
    pub fn id(&self) -> Option<i64> {
        match self {
            Response::Hello { id, .. }
            | Response::Pong { id }
            | Response::Infer { id, .. }
            | Response::InferBatch { id, .. }
            | Response::ModelList { id, .. }
            | Response::ModelInfo { id, .. }
            | Response::Metrics { id, .. }
            | Response::MetricsProm { id, .. }
            | Response::Trace { id, .. }
            | Response::Health { id, .. }
            | Response::Artifact { id, .. }
            | Response::Published { id, .. }
            | Response::Rollout { id, .. } => Some(*id),
            Response::Error { id, .. } => *id,
        }
    }

    pub fn to_value(&self) -> Value {
        fn base(id: i64, op: &str) -> Vec<(&str, Value)> {
            vec![("id", Value::Int(id)), ("op", Value::Str(op.to_string()))]
        }
        match self {
            Response::Hello {
                id,
                protocol,
                server,
                max_frame,
                max_in_flight,
                node_id,
                uptime_s,
            } => {
                let mut fields = base(*id, "hello");
                fields.push(("protocol", Value::Int(*protocol as i64)));
                fields.push(("server", Value::Str(server.clone())));
                fields.push(("max_frame", Value::Int(*max_frame as i64)));
                fields.push(("max_in_flight", Value::Int(*max_in_flight as i64)));
                if let Some(n) = node_id {
                    fields.push(("node_id", Value::Str(n.clone())));
                }
                if let Some(u) = uptime_s {
                    fields.push(("uptime_s", Value::Int(*u as i64)));
                }
                obj(fields)
            }
            Response::Pong { id } => obj(base(*id, "pong")),
            Response::Infer { id, model, row } => {
                let mut fields = base(*id, "infer");
                fields.push(("model", Value::Str(model.clone())));
                fields.extend(row.to_fields());
                obj(fields)
            }
            Response::InferBatch { id, model, results } => {
                let items: Vec<Value> = results.iter().map(|r| obj(r.to_fields())).collect();
                let mut fields = base(*id, "infer_batch");
                fields.push(("model", Value::Str(model.clone())));
                fields.push(("results", arr(items)));
                obj(fields)
            }
            Response::ModelList { id, models } => {
                let mut fields = base(*id, "list_models");
                fields.push(("models", arr(models.iter().map(|m| m.to_value()).collect())));
                obj(fields)
            }
            Response::ModelInfo { id, model } => {
                let mut fields = base(*id, "model_info");
                fields.push(("model", model.to_value()));
                obj(fields)
            }
            Response::Metrics { id, body } => merge_body(*id, "metrics", body),
            Response::MetricsProm { id, text } => {
                let mut fields = base(*id, "metrics_prom");
                fields.push(("text", Value::Str(text.clone())));
                obj(fields)
            }
            Response::Trace { id, body } => merge_body(*id, "trace", body),
            Response::Health { id, status, models_live, node_id, uptime_s } => {
                let mut fields = base(*id, "health");
                fields.push(("status", Value::Str(status.clone())));
                fields.push(("models_live", Value::Int(*models_live as i64)));
                if let Some(n) = node_id {
                    fields.push(("node_id", Value::Str(n.clone())));
                }
                if let Some(u) = uptime_s {
                    fields.push(("uptime_s", Value::Int(*u as i64)));
                }
                obj(fields)
            }
            Response::Artifact { id, digest, data, meta } => {
                let mut fields = base(*id, "pull_artifact");
                fields.push(("digest", Value::Str(digest.clone())));
                fields.push((
                    "data",
                    Value::Str(crate::registry::store::encode_hex(data)),
                ));
                if let Some(m) = meta {
                    fields.push(("meta", m.clone()));
                }
                obj(fields)
            }
            Response::Published { id, model, digest } => {
                let mut fields = base(*id, "push_artifact");
                fields.push(("model", Value::Str(model.clone())));
                fields.push(("digest", Value::Str(digest.clone())));
                obj(fields)
            }
            Response::Rollout { id, verb, body } => merge_body(*id, verb.wire_op(), body),
            Response::Error { id, code, message, retry_after_ms } => {
                let mut fields = vec![
                    (
                        "id",
                        match id {
                            Some(i) => Value::Int(*i),
                            None => Value::Null,
                        },
                    ),
                    ("op", Value::Str("error".to_string())),
                    ("code", Value::Str(code.as_str().to_string())),
                    ("error", Value::Str(message.clone())),
                ];
                if let Some(ms) = retry_after_ms {
                    fields.push(("retry_after_ms", Value::Int(*ms as i64)));
                }
                obj(fields)
            }
        }
    }

    /// Parse a frame payload into a typed response (client side).
    pub fn from_bytes(payload: &[u8]) -> crate::error::Result<Response> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| Error::Json("response payload is not UTF-8".into()))?;
        Response::from_value(&Value::parse(text)?)
    }

    pub fn from_value(v: &Value) -> crate::error::Result<Response> {
        let op = v.req_str("op")?;
        if op == "error" {
            return Ok(Response::Error {
                id: v.get("id").and_then(|x| x.as_i64()),
                code: ErrorCode::parse(
                    v.get("code").and_then(|c| c.as_str()).unwrap_or("internal"),
                ),
                message: v
                    .get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown error")
                    .to_string(),
                retry_after_ms: v
                    .get("retry_after_ms")
                    .and_then(|x| x.as_i64())
                    .map(|x| x.max(0) as u64),
            });
        }
        let id = v
            .field("id")?
            .as_i64()
            .ok_or_else(|| Error::Json("response 'id' is not an integer".into()))?;
        match op {
            "hello" => Ok(Response::Hello {
                id,
                protocol: v.req_usize("protocol")? as u32,
                server: v.req_str("server")?.to_string(),
                max_frame: v.req_usize("max_frame")?,
                max_in_flight: v.req_usize("max_in_flight")?,
                node_id: v.get("node_id").and_then(|n| n.as_str()).map(str::to_string),
                uptime_s: v.get("uptime_s").and_then(|u| u.as_i64()).map(|u| u.max(0) as u64),
            }),
            "pong" => Ok(Response::Pong { id }),
            "infer" => Ok(Response::Infer {
                id,
                model: v.req_str("model")?.to_string(),
                row: WireRow::from_value(v)?,
            }),
            "infer_batch" => {
                let mut results = Vec::new();
                for item in v.req_array("results")? {
                    results.push(WireRow::from_value(item)?);
                }
                Ok(Response::InferBatch {
                    id,
                    model: v.req_str("model")?.to_string(),
                    results,
                })
            }
            "list_models" => {
                let models = v
                    .req_array("models")?
                    .iter()
                    .map(ModelSummary::from_value)
                    .collect::<crate::error::Result<Vec<_>>>()?;
                Ok(Response::ModelList { id, models })
            }
            "model_info" => Ok(Response::ModelInfo {
                id,
                model: ModelSummary::from_value(v.field("model")?)?,
            }),
            "metrics" => Ok(Response::Metrics { id, body: strip_body(v) }),
            "metrics_prom" => Ok(Response::MetricsProm {
                id,
                text: v.req_str("text")?.to_string(),
            }),
            "trace" => Ok(Response::Trace { id, body: strip_body(v) }),
            "health" => Ok(Response::Health {
                id,
                status: v.req_str("status")?.to_string(),
                models_live: v.req_usize("models_live")?,
                node_id: v.get("node_id").and_then(|n| n.as_str()).map(str::to_string),
                uptime_s: v.get("uptime_s").and_then(|u| u.as_i64()).map(|u| u.max(0) as u64),
            }),
            "pull_artifact" => Ok(Response::Artifact {
                id,
                digest: v.req_str("digest")?.to_string(),
                data: crate::registry::store::decode_hex(v.req_str("data")?)?,
                meta: v.get("meta").cloned(),
            }),
            "push_artifact" => Ok(Response::Published {
                id,
                model: v.req_str("model")?.to_string(),
                digest: v.req_str("digest")?.to_string(),
            }),
            "rollout_start" => {
                Ok(Response::Rollout { id, verb: RolloutVerb::Start, body: strip_body(v) })
            }
            "rollout_status" => {
                Ok(Response::Rollout { id, verb: RolloutVerb::Status, body: strip_body(v) })
            }
            "rollout_abort" => {
                Ok(Response::Rollout { id, verb: RolloutVerb::Abort, body: strip_body(v) })
            }
            "rollout_clear" => {
                Ok(Response::Rollout { id, verb: RolloutVerb::Clear, body: strip_body(v) })
            }
            other => Err(Error::Json(format!("unknown response op '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"id\":1}").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = Cursor::new(buf);
        match read_frame(&mut cur, 1024).unwrap() {
            FrameRead::Frame(p) => assert_eq!(p, b"{\"id\":1}"),
            other => panic!("{other:?}"),
        }
        match read_frame(&mut cur, 1024).unwrap() {
            FrameRead::Frame(p) => assert!(p.is_empty()),
            other => panic!("{other:?}"),
        }
        assert!(matches!(read_frame(&mut cur, 1024).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn truncated_frames_are_errors() {
        // header cut short
        let mut cur = Cursor::new(vec![0u8, 0, 0]);
        assert!(read_frame(&mut cur, 1024).is_err());
        // payload cut short
        let mut buf = vec![0u8, 0, 0, 10];
        buf.extend_from_slice(b"abc");
        let mut cur = Cursor::new(buf);
        assert!(read_frame(&mut cur, 1024).is_err());
    }

    #[test]
    fn oversized_frame_reported_not_consumed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[b'x'; 100]).unwrap();
        let mut cur = Cursor::new(buf);
        match read_frame(&mut cur, 50).unwrap() {
            FrameRead::TooLarge(n) => assert_eq!(n, 100),
            other => panic!("{other:?}"),
        }
    }

    fn roundtrip_request(req: Request) {
        let bytes = req.to_value().to_string().into_bytes();
        let back = Request::from_bytes(&bytes).unwrap();
        assert_eq!(req, back);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(Request::Hello { id: 1, client: Some("t".into()) });
        roundtrip_request(Request::Hello { id: 2, client: None });
        roundtrip_request(Request::Ping { id: 3 });
        roundtrip_request(Request::Infer {
            id: 4,
            model: Some("kan1@2".into()),
            backend: None,
            exec: ExecOptions::default(),
            features: vec![0.5, -1.25],
        });
        roundtrip_request(Request::Infer {
            id: 5,
            model: None,
            backend: Some(BackendKind::Acim),
            exec: ExecOptions { seed: Some(42), trials: 8 },
            features: vec![1.0],
        });
        roundtrip_request(Request::InferBatch {
            id: 6,
            model: None,
            backend: None,
            exec: ExecOptions::default(),
            rows: vec![vec![0.5, 0.5], vec![-1.0, 2.0]],
        });
        roundtrip_request(Request::InferBatch {
            id: 11,
            model: Some("kan2".into()),
            backend: Some(BackendKind::Digital),
            exec: ExecOptions { seed: Some(7), trials: 1 },
            rows: vec![vec![0.5]],
        });
        roundtrip_request(Request::ListModels { id: 7 });
        roundtrip_request(Request::ModelInfo { id: 8, model: "kan2".into() });
        roundtrip_request(Request::Metrics { id: 9 });
        roundtrip_request(Request::MetricsProm { id: 12 });
        roundtrip_request(Request::Trace { id: 13, limit: None });
        roundtrip_request(Request::Trace { id: 14, limit: Some(16) });
        roundtrip_request(Request::Health { id: 10 });
        roundtrip_request(Request::PullArtifact {
            id: 15,
            digest: "fnv64:00000000000000aa".into(),
        });
        roundtrip_request(Request::PushArtifact {
            id: 16,
            model: "kan2".into(),
            version: Some(3),
            digest: "fnv64:00000000000000bb".into(),
            data: vec![0x00, 0x7f, 0xff],
        });
        roundtrip_request(Request::PushArtifact {
            id: 17,
            model: "kan2".into(),
            version: None,
            digest: "fnv64:00000000000000cc".into(),
            data: vec![],
        });
        // push_artifact without a model is a typed bad_request
        let err = Request::from_bytes(
            br#"{"id":1,"op":"push_artifact","digest":"fnv64:aa","data":"00"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("model"), "{}", err.message);
        // and a non-hex payload is rejected at the wire boundary
        let err = Request::from_bytes(
            br#"{"id":1,"op":"push_artifact","model":"m","digest":"fnv64:aa","data":"zz"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // a non-integer trace limit is a typed bad_request
        let err = Request::from_bytes(br#"{"id":1,"op":"trace","limit":"x"}"#)
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("limit"), "{}", err.message);
        // rollout verbs
        roundtrip_request(Request::RolloutStart {
            id: 18,
            model: "kan2".into(),
            baseline: "kan2@1".into(),
        });
        roundtrip_request(Request::RolloutStatus { id: 19, model: None });
        roundtrip_request(Request::RolloutStatus { id: 20, model: Some("kan2".into()) });
        roundtrip_request(Request::RolloutAbort { id: 21, model: "kan2".into() });
        roundtrip_request(Request::RolloutClear { id: 22, model: "kan2".into() });
        // rollout_start/abort/clear without a model are typed bad_requests
        for op in ["rollout_start", "rollout_abort", "rollout_clear"] {
            let payload = format!("{{\"id\":1,\"op\":\"{op}\"}}");
            let err = Request::from_bytes(payload.as_bytes()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "op {op}");
        }
        // rollout_start also needs a baseline
        let err = Request::from_bytes(
            br#"{"id":1,"op":"rollout_start","model":"kan2"}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("baseline"), "{}", err.message);
    }

    #[test]
    fn exec_field_validation_is_typed() {
        // unknown backend name
        let err = Request::from_bytes(
            br#"{"id":1,"op":"infer","backend":"gpu","features":[1]}"#,
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("unknown backend 'gpu'"), "{}", err.message);
        // out-of-range trials
        for bad in ["0", "65", "-3"] {
            let payload =
                format!("{{\"id\":1,\"op\":\"infer\",\"trials\":{bad},\"features\":[1]}}");
            let err = Request::from_bytes(payload.as_bytes()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "trials={bad}");
            assert!(err.message.contains("trials"), "{}", err.message);
        }
        // non-integer seed
        let err = Request::from_bytes(
            br#"{"id":1,"op":"infer","seed":"abc","features":[1]}"#,
        )
        .unwrap_err();
        assert!(err.message.contains("seed"), "{}", err.message);
        // defaults omitted from serialization: a plain infer has none of
        // the exec fields on the wire
        let v = Request::Infer {
            id: 1,
            model: None,
            backend: None,
            exec: ExecOptions::default(),
            features: vec![1.0],
        }
        .to_value();
        assert!(v.get("backend").is_none());
        assert!(v.get("seed").is_none());
        assert!(v.get("trials").is_none());
    }

    fn roundtrip_response(resp: Response) {
        let bytes = resp.to_value().to_string().into_bytes();
        let back = Response::from_bytes(&bytes).unwrap();
        assert_eq!(resp, back);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_response(Response::Hello {
            id: 1,
            protocol: 2,
            server: "kan-edge/0.1.0".into(),
            max_frame: 1 << 20,
            max_in_flight: 64,
            node_id: None,
            uptime_s: None,
        });
        roundtrip_response(Response::Hello {
            id: 15,
            protocol: 2,
            server: "kan-edge/0.1.0".into(),
            max_frame: 1 << 20,
            max_in_flight: 64,
            node_id: Some("node-a".into()),
            uptime_s: Some(120),
        });
        roundtrip_response(Response::Pong { id: 2 });
        roundtrip_response(Response::Infer {
            id: 3,
            model: "a@1".into(),
            row: WireRow { logits: vec![1.5, -1.5], class: 0, std: None },
        });
        roundtrip_response(Response::Infer {
            id: 12,
            model: "a@1".into(),
            row: WireRow {
                logits: vec![1.5, -1.5],
                class: 0,
                std: Some(vec![0.25, 0.5]),
            },
        });
        roundtrip_response(Response::InferBatch {
            id: 4,
            model: "a@1".into(),
            results: vec![
                WireRow { logits: vec![1.0, 0.0], class: 0, std: None },
                WireRow { logits: vec![0.0, 1.0], class: 1, std: Some(vec![0.1, 0.1]) },
            ],
        });
        roundtrip_response(Response::ModelList {
            id: 5,
            models: vec![ModelSummary {
                name: "a".into(),
                version: 3,
                kind: "kan".into(),
                dims: vec![2, 2],
                num_params: 8,
                live: true,
                accuracy: Some(0.9),
                digest: Some("fnv1a:abc".into()),
                backend: Some(BackendInfo {
                    kind: "digital".into(),
                    deterministic: true,
                    reference_exact: true,
                    input_dim: Some(2),
                    output_dim: 2,
                    shadow: Some(("acim".into(), 0.25)),
                }),
            }],
        });
        roundtrip_response(Response::ModelInfo {
            id: 6,
            model: ModelSummary {
                name: "b".into(),
                version: 1,
                kind: "mlp".into(),
                dims: vec![],
                num_params: 0,
                live: false,
                accuracy: None,
                digest: None,
                backend: None,
            },
        });
        roundtrip_response(Response::Health {
            id: 7,
            status: "ok".into(),
            models_live: 2,
            node_id: None,
            uptime_s: None,
        });
        roundtrip_response(Response::Health {
            id: 16,
            status: "ok".into(),
            models_live: 1,
            node_id: Some("node-b".into()),
            uptime_s: Some(0),
        });
        roundtrip_response(Response::Artifact {
            id: 17,
            digest: "fnv64:00000000000000aa".into(),
            data: vec![1, 2, 3, 255],
            meta: Some(
                Value::parse(r#"{"name":"kan2","version":3,"kind":"kan"}"#).unwrap(),
            ),
        });
        roundtrip_response(Response::Artifact {
            id: 18,
            digest: "fnv64:00000000000000ab".into(),
            data: vec![],
            meta: None,
        });
        roundtrip_response(Response::Published {
            id: 19,
            model: "kan2@3".into(),
            digest: "fnv64:00000000000000aa".into(),
        });
        roundtrip_response(Response::MetricsProm {
            id: 13,
            text: "# TYPE kan_edge_wire_v2_requests gauge\n\
                   kan_edge_wire_v2_requests 4\n"
                .into(),
        });
        roundtrip_response(Response::Trace {
            id: 14,
            body: Value::parse(r#"{"spans":[],"summary":{"ring_len":0}}"#).unwrap(),
        });
        roundtrip_response(Response::Error {
            id: Some(8),
            code: ErrorCode::NotFound,
            message: "model 'x' not found".into(),
            retry_after_ms: None,
        });
        roundtrip_response(Response::Error {
            id: None,
            code: ErrorCode::TooLarge,
            message: "frame too big".into(),
            retry_after_ms: None,
        });
        roundtrip_response(Response::Error {
            id: Some(9),
            code: ErrorCode::Overloaded,
            message: "client quota exceeded (4/4 rows in queue)".into(),
            retry_after_ms: Some(12),
        });
        roundtrip_response(Response::Rollout {
            id: 20,
            verb: RolloutVerb::Status,
            body: Value::parse(
                r#"{"rollouts":{"kan2":{"phase":"ramping","fraction":0.25}}}"#,
            )
            .unwrap(),
        });
        roundtrip_response(Response::Rollout {
            id: 21,
            verb: RolloutVerb::Start,
            body: Value::parse(r#"{"rollouts":{}}"#).unwrap(),
        });
        roundtrip_response(Response::Error {
            id: Some(22),
            code: ErrorCode::Conflict,
            message: "rollout already in progress for 'kan2'".into(),
            retry_after_ms: None,
        });
    }

    #[test]
    fn overloaded_error_response_carries_retry_hint() {
        let e = Error::Overloaded {
            message: "client quota exceeded (2/2 rows in queue)".into(),
            retry_after_ms: 7,
        };
        let resp = error_response(Some(4), &e);
        let v = resp.to_value();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(v.get("retry_after_ms").unwrap().as_i64().unwrap(), 7);
        // non-admission errors carry no hint field at all
        let v = error_response(Some(5), &Error::Json("bad".into())).to_value();
        assert!(v.get("retry_after_ms").is_none());
    }

    #[test]
    fn metrics_response_carries_body() {
        let body = Value::parse(r#"{"models":{"a@1":{"requests":3}},"wire":{"v1_requests":1}}"#)
            .unwrap();
        let resp = Response::Metrics { id: 11, body };
        let v = resp.to_value();
        assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 11);
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "metrics");
        assert!(v.get("models").is_some());
        match Response::from_bytes(v.to_string().as_bytes()).unwrap() {
            Response::Metrics { id, body } => {
                assert_eq!(id, 11);
                assert!(body.get("wire").is_some());
                // transport framing is stripped back out of the body
                assert!(body.get("id").is_none());
                assert!(body.get("op").is_none());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_typed_errors() {
        for (payload, expect_id) in [
            (&b"\xff\xfe"[..], None),
            (&b"not json"[..], None),
            (&b"{\"op\":\"infer\"}"[..], None), // no id
            (&b"{\"id\":7}"[..], Some(7)),      // no op
            (&b"{\"id\":7,\"op\":\"infer\"}"[..], Some(7)), // no features
            (&b"{\"id\":7,\"op\":\"infer\",\"features\":\"x\"}"[..], Some(7)),
            (&b"{\"id\":7,\"op\":\"infer_batch\",\"rows\":[]}"[..], Some(7)),
            (&b"{\"id\":7,\"op\":\"infer_batch\",\"rows\":[[1],\"x\"]}"[..], Some(7)),
            (&b"{\"id\":7,\"op\":\"model_info\"}"[..], Some(7)),
            (&b"{\"id\":7,\"op\":\"infer\",\"model\":3,\"features\":[1]}"[..], Some(7)),
        ] {
            let err = Request::from_bytes(payload).unwrap_err();
            assert_eq!(err.id, expect_id, "payload {payload:?}");
            assert_eq!(err.code, ErrorCode::BadRequest, "payload {payload:?}");
        }
        let err = Request::from_bytes(b"{\"id\":7,\"op\":\"frobnicate\"}").unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert_eq!(err.id, Some(7));
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::NotFound,
            ErrorCode::TooLarge,
            ErrorCode::Overloaded,
            ErrorCode::Conflict,
            ErrorCode::Unsupported,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), code);
        }
        assert_eq!(ErrorCode::parse("???"), ErrorCode::Internal);
    }

    #[test]
    fn code_for_maps_crate_errors() {
        assert_eq!(
            code_for(&Error::Serving("queue full: admission rejected".into())),
            ErrorCode::Overloaded
        );
        assert_eq!(
            code_for(&Error::Overloaded { message: "quota".into(), retry_after_ms: 3 }),
            ErrorCode::Overloaded
        );
        assert_eq!(
            code_for(&Error::Registry("model 'x' not in manifest".into())),
            ErrorCode::NotFound
        );
        assert_eq!(code_for(&Error::Json("bad".into())), ErrorCode::BadRequest);
        assert_eq!(code_for(&Error::Runtime("pjrt".into())), ErrorCode::Internal);
        assert_eq!(
            code_for(&Error::Serving(
                "artifact replication is not supported on this endpoint".into()
            )),
            ErrorCode::Unsupported
        );
        assert_eq!(
            code_for(&Error::Serving(
                "rollout already in progress for 'kan2' (kan2@1 -> kan2@2)".into()
            )),
            ErrorCode::Conflict
        );
        assert_eq!(
            code_for(&Error::Serving(
                "rollout for 'kan2' already finished: promoted".into()
            )),
            ErrorCode::Conflict
        );
        assert_eq!(
            code_for(&Error::Serving("no rollout for model 'kan9'".into())),
            ErrorCode::NotFound
        );
        assert_eq!(
            code_for(&Error::Serving(
                "rollouts are not supported on this endpoint".into()
            )),
            ErrorCode::Unsupported
        );
    }
}
