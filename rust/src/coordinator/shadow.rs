//! Shadow execution: mirror a sampled fraction of served traffic onto a
//! second backend **off the response path** and record digital-vs-analog
//! divergence ([`ShadowMetrics`]).
//!
//! The paper's central claim is that the RRAM-ACIM analog path holds
//! accuracy under measured non-ideal effects; shadow serving measures
//! exactly that on live traffic: every mirrored row is re-executed by
//! the mirror backend (typically the ACIM simulator) and compared
//! against the logits the primary actually served — argmax flip rate,
//! logit MAE, and per-layer partial-sum error quantiles.
//!
//! Latency contract: [`ShadowState::observe`] never blocks and never
//! fails the caller. Jobs go through a bounded queue with `try_send`;
//! when the mirror falls behind, sampled rows are *dropped* (counted)
//! rather than delaying a primary response. The unit test below pins
//! this down with a mirror that blocks forever.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;

use super::backend::{BackendKind, ExecOptions};
use super::metrics::ShadowMetrics;
use crate::error::Result;

/// One sampled row queued for mirror execution: the features, the
/// logits the primary served, and the request's execution options (the
/// mirror derives its noise from the same seed, so a shadow comparison
/// is reproducible).
pub struct ShadowJob {
    pub features: Vec<f32>,
    pub primary: Vec<f32>,
    pub opts: ExecOptions,
}

/// What one mirror execution observed.
pub struct ShadowObservation {
    /// Mirror argmax differs from the served argmax.
    pub flip: bool,
    /// Mean absolute logit error between mirror and served logits.
    pub mae: f64,
    /// Per-layer mean absolute partial-sum error (empty when the mirror
    /// cannot attribute divergence per layer).
    pub layer_err: Vec<f64>,
}

/// Mirror executor: runs one sampled job and returns the comparison.
/// Boxed closure so the registry can capture whatever model pair the
/// mirror needs (ACIM simulator + digital golden reference) and tests
/// can inject controlled behavior.
pub type ShadowExec = Box<dyn FnMut(&ShadowJob) -> Result<ShadowObservation> + Send>;

/// A running shadow mirror for one served model.
pub struct ShadowState {
    /// Mirrored backend kind (control-plane visibility).
    pub kind: BackendKind,
    /// Fraction of primary rows sampled for mirroring, in (0, 1].
    pub fraction: f64,
    pub metrics: Arc<ShadowMetrics>,
    tx: SyncSender<ShadowJob>,
    seen: AtomicU64,
}

impl ShadowState {
    /// Spawn the mirror worker thread. `queue` bounds in-flight jobs;
    /// overflow drops (and counts) rather than blocking the caller.
    pub fn spawn(
        kind: BackendKind,
        fraction: f64,
        queue: usize,
        exec: ShadowExec,
    ) -> Arc<ShadowState> {
        Self::spawn_with_metrics(kind, fraction, queue, exec, Arc::new(ShadowMetrics::new()))
    }

    /// [`Self::spawn`] with caller-owned metrics. Divergence statistics
    /// are only meaningful per (primary, mirror) pair, so a caller that
    /// re-targets its mirror (the rollout plane replaces the candidate)
    /// must hand each pair its own [`ShadowMetrics`] — or
    /// [`ShadowMetrics::reset`] the old one — instead of letting a new
    /// comparison inherit a previous target's flip/MAE reservoirs.
    pub fn spawn_with_metrics(
        kind: BackendKind,
        fraction: f64,
        queue: usize,
        mut exec: ShadowExec,
        metrics: Arc<ShadowMetrics>,
    ) -> Arc<ShadowState> {
        let (tx, rx) = sync_channel::<ShadowJob>(queue.max(1));
        let worker_metrics = metrics.clone();
        let spawned = std::thread::Builder::new()
            .name("kan-edge-shadow".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    match exec(&job) {
                        Ok(obs) => worker_metrics.record_mirror(
                            obs.flip,
                            obs.mae,
                            &obs.layer_err,
                        ),
                        Err(_) => worker_metrics.record_error(),
                    }
                }
            });
        if let Err(e) = spawned {
            // no worker ⇒ the receiver is gone and every enqueue counts
            // as a drop; say so once instead of degrading silently
            crate::obs::log::warn(
                "shadow",
                &format!(
                    "cannot spawn shadow mirror worker ({e}); every \
                     sampled row will be counted as dropped"
                ),
            );
        }
        Arc::new(ShadowState {
            kind,
            fraction: fraction.clamp(0.0, 1.0),
            metrics,
            tx,
            seen: AtomicU64::new(0),
        })
    }

    /// Deterministic counter-based sampler: row `n` is mirrored when the
    /// cumulative target `floor((n+1)·f)` advances — exactly a fraction
    /// `f` of rows, evenly spread, with no RNG on the serving path.
    ///
    /// Public so dispatchers can decide *before* copying anything:
    /// consult `presample` per row and clone only the selected ones —
    /// the serving path must not pay a copy for the ~`1-f` of rows the
    /// sampler will discard. Metrics are recorded at
    /// [`Self::enqueue`], so a row presampled but never enqueued (its
    /// dispatch failed) leaves the counters consistent.
    pub fn presample(&self) -> bool {
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        let f = self.fraction;
        ((n + 1) as f64 * f).floor() > (n as f64 * f).floor()
    }

    /// Hand a presampled row to the mirror. Non-blocking by contract:
    /// enqueue or drop, never wait — the primary response is already on
    /// its way to the client and must not gain latency here.
    pub fn enqueue(&self, features: Vec<f32>, primary: Vec<f32>, opts: ExecOptions) {
        self.metrics.record_sampled();
        let job = ShadowJob { features, primary, opts };
        match self.tx.try_send(job) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.metrics.record_dropped();
            }
        }
    }

    /// Convenience `presample` + `enqueue` for single-row callers.
    pub fn observe(&self, features: &[f32], primary: &[f32], opts: ExecOptions) {
        if self.presample() {
            self.enqueue(features.to_vec(), primary.to_vec(), opts);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn counting_exec() -> ShadowExec {
        Box::new(|job| {
            Ok(ShadowObservation {
                flip: job.features[0] < 0.0,
                mae: 0.5,
                layer_err: vec![0.1, 0.2],
            })
        })
    }

    #[test]
    fn sampler_hits_the_configured_fraction() {
        let s = ShadowState::spawn(BackendKind::Acim, 0.25, 64, counting_exec());
        for i in 0..1000 {
            s.observe(&[i as f32], &[0.0], ExecOptions::default());
        }
        // deterministic sampler: exactly a quarter selected
        assert_eq!(s.metrics.report().sampled, 250);
        // fraction 1.0 mirrors everything
        let all = ShadowState::spawn(BackendKind::Acim, 1.0, 2000, counting_exec());
        for i in 0..100 {
            all.observe(&[i as f32], &[0.0], ExecOptions::default());
        }
        assert_eq!(all.metrics.report().sampled, 100);
    }

    #[test]
    fn mirror_records_divergence() {
        let s = ShadowState::spawn(BackendKind::Acim, 1.0, 64, counting_exec());
        for i in 0..8 {
            let x = if i % 2 == 0 { 1.0 } else { -1.0 };
            s.observe(&[x], &[0.0], ExecOptions::default());
        }
        // wait for the worker to drain (bounded)
        let t0 = Instant::now();
        while s.metrics.report().mirrored < 8 {
            assert!(t0.elapsed() < Duration::from_secs(5), "mirror never drained");
            std::thread::sleep(Duration::from_millis(5));
        }
        let r = s.metrics.report();
        assert_eq!(r.mirrored, 8);
        assert_eq!(r.argmax_flips, 4);
        assert_eq!(r.layer_err_quantiles.len(), 2);
    }

    #[test]
    fn observe_never_blocks_even_when_the_mirror_hangs() {
        // a mirror that never completes: the queue fills, and every
        // further observe must return immediately as a counted drop
        let blocked: ShadowExec = Box::new(|_job| {
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        });
        let s = ShadowState::spawn(BackendKind::Acim, 1.0, 2, blocked);
        let t0 = Instant::now();
        for i in 0..100 {
            s.observe(&[i as f32], &[0.0], ExecOptions::default());
        }
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "observe blocked on a wedged mirror: {:?}",
            t0.elapsed()
        );
        let r = s.metrics.report();
        assert_eq!(r.sampled, 100);
        // queue depth 2 (+1 in the worker's hands): nearly everything dropped
        assert!(r.dropped >= 96, "dropped {}", r.dropped);
        assert_eq!(r.mirrored, 0);
    }
}
