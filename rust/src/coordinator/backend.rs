//! The typed backend surface: execution sessions the serving pipeline
//! dispatches to.
//!
//! The surface is a two-stage API (see `docs/BACKENDS.md`):
//!
//! 1. A [`BackendKind`] names an execution strategy and is parsed
//!    exactly once — at config load or at the protocol boundary. No
//!    string comparison survives past those edges.
//! 2. A factory ([`super::router::BackendFactory`]) compiles a
//!    checkpoint into an [`ExecutionSession`]: a running, `Send + Sync`
//!    executor carrying a [`BackendSpec`] capability descriptor
//!    (dims, deterministic vs stochastic, reference-exact vs
//!    approximate, batch constraints).
//!
//! Sessions:
//!
//! * [`PjrtSession`] — the AOT-compiled HLO graph on the PJRT CPU client
//!   (batch-shaped; short batches are padded). The `xla` crate's client
//!   types are `!Send`, so the executable lives on a dedicated actor
//!   thread and batches cross a channel.
//! * [`DigitalSession`] — the rust integer-dataflow path
//!   ([`QuantKanModel`] / the planned [`KanEngine`]), bit-faithful to
//!   the hardware pipeline minus analog effects.
//! * [`AcimSession`] — the full analog simulator (IR-drop + noise +
//!   ADC). Stateless across requests: every row derives its own noise
//!   stream from its [`ExecOptions`] seed, so results are reproducible
//!   per request and parallelizable across workers (no shared noise
//!   mutex, no arrival-order dependence).
//! * [`MlpSession`] — the float MLP baseline.

use std::fmt;
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};

use crate::util::sync::LockExt;
use crate::acim::{AcimModel, NoiseModel};
use crate::baseline::MlpModel;
use crate::error::{Error, Result};
use crate::kan::{EngineOptions, EngineProfile, EngineScratch, KanEngine, QuantKanModel};
use crate::runtime::PjrtEngine;
use crate::util::json::Value;
use crate::util::rng::mix;

// ---- backend identity ------------------------------------------------------

/// Typed backend identity. Parsed once — at config load
/// (`server.backend`) or at the wire boundary (the v2 `backend` request
/// field) — and passed around as an enum from there on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BackendKind {
    /// AOT-compiled HLO on the PJRT CPU runtime.
    Pjrt,
    /// Rust integer dataflow (planned engine or scalar reference).
    Digital,
    /// Analog compute-in-memory simulator (IR-drop + noise + ADC).
    Acim,
    /// Float MLP baseline.
    Mlp,
}

impl BackendKind {
    /// Every kind a request can name, in display order.
    pub const ALL: [BackendKind; 4] = [
        BackendKind::Pjrt,
        BackendKind::Digital,
        BackendKind::Acim,
        BackendKind::Mlp,
    ];

    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "digital" => Ok(BackendKind::Digital),
            "acim" => Ok(BackendKind::Acim),
            "mlp" => Ok(BackendKind::Mlp),
            other => Err(Error::Config(format!(
                "unknown backend '{other}' (pjrt | digital | acim | mlp)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Digital => "digital",
            BackendKind::Acim => "acim",
            BackendKind::Mlp => "mlp",
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

// ---- capability descriptor -------------------------------------------------

/// What a compiled session can do — surfaced through the control plane
/// (`model_info`) so clients can discover capabilities instead of
/// guessing from names.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendSpec {
    pub kind: BackendKind,
    /// Expected features per row, when the session knows it (admission
    /// validates rows against this before they can poison a shared
    /// dynamic batch).
    pub input_dim: Option<usize>,
    /// Logits per row.
    pub output_dim: usize,
    /// Same `(row, options)` always yields the same output. A session
    /// with noise enabled is still *reproducible* for a fixed seed, but
    /// not deterministic across differently-seeded requests.
    pub deterministic: bool,
    /// Bit-faithful to the digital golden reference
    /// (`forward_digital`); `false` for approximate paths (analog
    /// simulation, padded f32 graphs).
    pub reference_exact: bool,
    /// Compiled batch-size constraint, when the executor has one
    /// (larger submitted batches are chunked to it).
    pub max_batch: Option<usize>,
}

impl BackendSpec {
    /// Deterministic, reference-exact spec — the common case for test
    /// doubles and digital paths.
    pub fn exact(kind: BackendKind, input_dim: Option<usize>, output_dim: usize) -> Self {
        Self {
            kind,
            input_dim,
            output_dim,
            deterministic: true,
            reference_exact: true,
            max_batch: None,
        }
    }

    /// Minimal synthetic spec for test backends: digital kind, no input
    /// constraint, `output_dim` logits.
    pub fn synthetic(output_dim: usize) -> Self {
        Self::exact(BackendKind::Digital, None, output_dim)
    }
}

// ---- per-request execution options -----------------------------------------

/// Per-request execution options, carried on the wire (`seed`/`trials`
/// v2 request fields) and down to the session with each row.
///
/// `seed` is the noise-stream base for stochastic sessions: a fixed
/// `(row, seed)` pair is bit-identical regardless of batching, arrival
/// order, or worker count. `None` means "no reproducibility asked":
/// the wire layers resolve a fresh server-side draw per request (shared
/// with the shadow mirror of that row), and a stochastic session draws
/// its own per-row stream for `None` rows that reach it directly — so
/// unseeded traffic always samples the noise *distribution*, never one
/// frozen realization. Batch submits derive per-row seeds as
/// `mix(seed, row_index)` ([`ExecOptions::for_row`]) so rows get
/// independent streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOptions {
    pub seed: Option<u64>,
    /// Noisy trials to run and aggregate (stochastic sessions): the
    /// served logits are the per-logit mean, and `trials > 1` also
    /// yields a per-logit standard deviation — the paper's partial-sum
    /// error statistics as a served uncertainty estimate.
    pub trials: u32,
}

impl Default for ExecOptions {
    fn default() -> Self {
        Self { seed: None, trials: 1 }
    }
}

impl ExecOptions {
    /// The options batch row `i` executes with: same trials, seed
    /// derived as `mix(seed, i)` from the *submitted* row order. This is
    /// THE per-row derivation — the batching service, the default
    /// [`Dispatch`](super::server::Dispatch) batch loop, and the shadow
    /// mirror must all use it, or seeded batches stop reproducing.
    pub fn for_row(self, i: usize) -> ExecOptions {
        ExecOptions { seed: self.seed.map(|s| mix(s, i as u64)), trials: self.trials }
    }
}

/// Upper bound on `trials` accepted from the wire (an ACIM forward is
/// ~10^3 ideal MACs per row; unbounded trials would be a trivial DoS).
pub const MAX_TRIALS: u32 = 64;

/// One row's execution result: the served logits plus, for stochastic
/// sessions run with `trials > 1`, the per-logit standard deviation
/// across trials.
#[derive(Debug, Clone, PartialEq)]
pub struct RowOutput {
    pub logits: Vec<f32>,
    pub trial_std: Option<Vec<f32>>,
}

impl From<Vec<f32>> for RowOutput {
    fn from(logits: Vec<f32>) -> Self {
        Self { logits, trial_std: None }
    }
}

/// Derive the noise seed for trial `t` of a row whose base seed is
/// `base` (stable across batching and worker counts by construction —
/// it depends on nothing but the request's own options).
pub fn trial_seed(base: u64, trial: u32) -> u64 {
    mix(base, 0x7214_15ED ^ trial as u64)
}

/// Index of the maximum logit (first on ties). The single argmax used
/// for served classes *and* shadow flip detection — one tie-breaking
/// semantics, so a mirror can never manufacture a phantom flip by
/// breaking ties differently from the response path.
pub fn argmax_f32(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate().skip(1) {
        if x > logits[best] {
            best = i;
        }
    }
    best
}

// ---- the session trait -----------------------------------------------------

/// A compiled, running execution backend. Called from blocking worker
/// tasks; implementations must be `Send + Sync` and **stateless across
/// calls** — any per-request randomness must derive from the row's
/// [`ExecOptions`], never from shared mutable state, so outputs cannot
/// depend on request arrival order.
pub trait ExecutionSession: Send + Sync {
    /// Serving name (model name for model-backed sessions).
    fn name(&self) -> &str;

    /// Capability descriptor (cheap; called at pipeline start and on
    /// the control plane).
    fn spec(&self) -> BackendSpec;

    /// Run a batch of feature rows; `opts[i]` are row `i`'s execution
    /// options (`opts.len() == rows.len()`). Takes ownership of the
    /// rows so actor-style sessions (PJRT) can move them across their
    /// thread boundary without copying.
    fn run(&self, rows: Vec<Vec<f32>>, opts: &[ExecOptions]) -> Result<Vec<RowOutput>>;

    /// Convenience: run with default options and return bare logits
    /// (evaluation helpers, tests).
    fn infer_logits(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let opts = vec![ExecOptions::default(); rows.len()];
        Ok(self.run(rows, &opts)?.into_iter().map(|o| o.logits).collect())
    }

    /// Live profiling counters rendered for the metrics plane, or `None`
    /// when this session does not profile (the default). Sessions that
    /// opt in (the engine-backed [`DigitalSession`] with
    /// `observability.engine_profiling = true`) report per-layer path
    /// counters and the live-vs-calibration occupancy drift
    /// (`docs/OBSERVABILITY.md`).
    fn profile(&self) -> Option<Value> {
        None
    }
}

// ---- PJRT ------------------------------------------------------------------

type PjrtJob = (Vec<Vec<f32>>, SyncSender<Result<Vec<Vec<f32>>>>);

/// PJRT executable session: an actor thread owning the (!Send) client.
pub struct PjrtSession {
    tx: Mutex<SyncSender<PjrtJob>>,
    model: String,
    input_dim: usize,
    output_dim: usize,
    batch: usize,
}

impl PjrtSession {
    /// Spawn the actor: it creates the PJRT client, compiles `hlo_path`,
    /// and then serves batches until the session is dropped.
    pub fn spawn(
        hlo_path: PathBuf,
        batch: usize,
        input_dim: usize,
        output_dim: usize,
        model: String,
    ) -> Result<Self> {
        let (job_tx, job_rx) = sync_channel::<PjrtJob>(16);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        std::thread::Builder::new()
            .name("kan-edge-pjrt".into())
            .spawn(move || {
                // keep the client (engine) alive for the executable's whole
                // lifetime — the loaded executable references it internally
                let (_engine, exe) = match PjrtEngine::cpu().and_then(|e| {
                    e.load_hlo(&hlo_path, batch, input_dim, output_dim)
                        .map(|exe| (e, exe))
                }) {
                    Ok(pair) => {
                        let _ = ready_tx.send(Ok(()));
                        pair
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok((rows, reply)) = job_rx.recv() {
                    let result = run_batches(&exe, &rows, batch, input_dim, output_dim);
                    let _ = reply.send(result);
                }
            })
            .map_err(|e| Error::Serving(format!("cannot spawn pjrt actor: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt actor died during startup".into()))??;
        Ok(Self { tx: Mutex::new(job_tx), model, input_dim, output_dim, batch })
    }
}

fn run_batches(
    exe: &crate::runtime::PjrtExecutable,
    rows: &[Vec<f32>],
    batch: usize,
    input_dim: usize,
    output_dim: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(batch) {
        let mut flat = vec![0.0f32; batch * input_dim];
        for (i, row) in chunk.iter().enumerate() {
            if row.len() != input_dim {
                return Err(Error::Shape(format!(
                    "row has {} features, expected {input_dim}",
                    row.len()
                )));
            }
            flat[i * input_dim..(i + 1) * input_dim].copy_from_slice(row);
        }
        let y = exe.run(&flat)?;
        for i in 0..chunk.len() {
            out.push(y[i * output_dim..(i + 1) * output_dim].to_vec());
        }
    }
    Ok(out)
}

impl ExecutionSession for PjrtSession {
    fn name(&self) -> &str {
        &self.model
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec {
            kind: BackendKind::Pjrt,
            input_dim: Some(self.input_dim),
            output_dim: self.output_dim,
            deterministic: true,
            // f32 graph accumulation + batch padding: numerically close
            // to, but not bit-identical with, the integer reference
            reference_exact: false,
            max_batch: Some(self.batch),
        }
    }

    fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        // ownership of the rows moves through the channel; no copy. The
        // sender is cloned out of the mutex so a full actor queue blocks
        // this caller on the channel, never while holding the lock.
        let tx = self.tx.lock_recover().clone();
        tx.send((rows, reply_tx))
            .map_err(|_| Error::Runtime("pjrt actor gone".into()))?;
        let outs = reply_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt actor dropped reply".into()))??;
        Ok(outs.into_iter().map(RowOutput::from).collect())
    }
}

// ---- digital ---------------------------------------------------------------

/// Rust digital session. By default it executes through the compiled
/// [`KanEngine`] plan (integer-exact hot path, zero steady-state
/// allocations inside the engine; see `docs/ENGINE.md`); the scalar
/// golden reference (`QuantKanModel::forward_batch`) remains available
/// via [`DigitalSession::with_engine`]`(.., false)` / the
/// `server.engine = false` config knob.
pub struct DigitalSession {
    pub model: Arc<QuantKanModel>,
    engine: Option<Arc<KanEngine>>,
    /// Reusable scratch arenas, one per concurrent in-flight batch:
    /// popped for the duration of a `run`, pushed back after —
    /// steady state allocates no new arenas.
    scratch: Mutex<Vec<EngineScratch>>,
    /// Engine profiling opt-in: scratches carry per-scratch counters
    /// (plain integers, no atomics in the engine loop) and each `run`
    /// folds them into `profile_acc` with one lock per batch.
    profiled: bool,
    profile_acc: Mutex<Option<EngineProfile>>,
}

impl DigitalSession {
    /// Engine-backed digital session (the default serving path).
    pub fn new(model: Arc<QuantKanModel>) -> Self {
        Self::with_engine(model, true)
    }

    /// Choose the execution path explicitly. A failed engine compile
    /// (exotic checkpoint outside the int8/int16 contract) degrades to
    /// the scalar reference with a warning rather than refusing to
    /// serve.
    pub fn with_engine(model: Arc<QuantKanModel>, use_engine: bool) -> Self {
        Self::with_engine_profiled(model, use_engine, false)
    }

    /// Like [`Self::with_engine`], additionally enabling engine
    /// profiling counters (`observability.engine_profiling`). Profiling
    /// requires the engine path; with `use_engine = false` the flag is
    /// inert and [`ExecutionSession::profile`] stays `None`.
    pub fn with_engine_profiled(
        model: Arc<QuantKanModel>,
        use_engine: bool,
        profiled: bool,
    ) -> Self {
        let engine = if use_engine {
            match KanEngine::compile(&model, EngineOptions::default()) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    crate::obs::log::log_kv(
                        crate::obs::log::Level::Warn,
                        "backend",
                        &format!(
                            "engine compile failed ({e}); serving the scalar \
                             reference path"
                        ),
                        vec![("model", Value::Str(model.name.clone()))],
                    );
                    None
                }
            }
        } else {
            None
        };
        let profiled = profiled && engine.is_some();
        Self {
            model,
            engine,
            scratch: Mutex::new(Vec::new()),
            profiled,
            profile_acc: Mutex::new(None),
        }
    }

    /// Whether the planned engine is the active execution path.
    pub fn engine_enabled(&self) -> bool {
        self.engine.is_some()
    }
}

impl ExecutionSession for DigitalSession {
    fn name(&self) -> &str {
        &self.model.name
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::exact(
            BackendKind::Digital,
            Some(self.model.input_dim()),
            self.model.output_dim(),
        )
    }

    fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        // flatten once and run the batch path: one allocation set per layer
        // instead of per row (EXPERIMENTS.md §Perf: +9% serving throughput)
        let din = self.model.input_dim();
        let dout = self.model.output_dim();
        let mut flat = Vec::with_capacity(rows.len() * din);
        for r in &rows {
            if r.len() != din {
                return Err(Error::Shape(format!(
                    "row has {} features, expected {din}",
                    r.len()
                )));
            }
            flat.extend_from_slice(r);
        }
        let batch = rows.len();
        let out = if let Some(engine) = &self.engine {
            // one scratch per call: the service's worker pool provides
            // the multi-core, each worker reuses an arena from the pool
            let mut s = self.scratch.lock_recover().pop().unwrap_or_else(|| {
                if self.profiled {
                    engine.new_scratch_profiled()
                } else {
                    engine.new_scratch()
                }
            });
            let mut out = vec![0.0f64; batch * dout];
            engine.forward_batch_with(&flat, batch, &mut out, std::slice::from_mut(&mut s));
            // fold the scratch's counters into the session accumulator:
            // one lock per batch, zero work when profiling is off
            if let Some(taken) = s.take_profile() {
                let mut acc = self.profile_acc.lock_recover();
                match acc.as_mut() {
                    Some(a) => a.merge(&taken),
                    None => *acc = Some(taken),
                }
            }
            self.scratch.lock_recover().push(s);
            out
        } else {
            self.model.forward_batch(&flat, batch)
        };
        Ok(out
            .chunks_exact(dout)
            .map(|c| RowOutput::from(c.iter().map(|&v| v as f32).collect::<Vec<f32>>()))
            .collect())
    }

    fn profile(&self) -> Option<Value> {
        if !self.profiled {
            return None;
        }
        let engine = self.engine.as_ref()?;
        let acc = self.profile_acc.lock_recover();
        // zeroed counters before any batch ran: the section exists as
        // soon as profiling is on, so scrapers see a stable schema
        match acc.as_ref() {
            Some(p) => Some(p.to_value(engine.plan())),
            None => Some(EngineProfile::new(engine.plan()).to_value(engine.plan())),
        }
    }
}

// ---- ACIM ------------------------------------------------------------------

/// Analog ACIM-simulator session with per-request noise derivation.
///
/// The pre-v2 design held one `Mutex<NoiseModel>` whose stream advanced
/// across requests: every batch serialized on the lock and outputs
/// depended on arrival order. Here each row builds its own
/// [`NoiseModel`] from [`trial_seed`]`(row seed, trial)`, so a fixed
/// `(row, seed)` is bit-identical for any worker count, batch
/// composition, or concurrency, and rows execute without shared state.
pub struct AcimSession {
    pub model: Arc<AcimModel>,
    name: String,
    /// Noise base for rows that carry no seed.
    default_seed: u64,
    /// Draw counter for unseeded rows: each gets `mix(default, n)` so
    /// unseeded traffic samples the noise *distribution* instead of
    /// replaying one fixed realization (the wire layers resolve a seed
    /// per request, so this only triggers for direct API callers; such
    /// draws are explicitly outside the reproducibility contract).
    unseeded: std::sync::atomic::AtomicU64,
}

impl AcimSession {
    pub fn new(model: Arc<AcimModel>, name: String) -> Self {
        let default_seed = model.opts.seed ^ 0x77;
        Self {
            model,
            name,
            default_seed,
            unseeded: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Run one row under `opts`: mean logits over `trials` noisy
    /// forwards plus the per-logit standard deviation when `trials > 1`.
    fn run_row(&self, row: &[f32], opts: &ExecOptions) -> RowOutput {
        let base = opts.seed.unwrap_or_else(|| {
            let n = self
                .unseeded
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            mix(self.default_seed, n)
        });
        let trials = opts.trials.max(1);
        let dout = self.model.layers.last().map(|l| l.dout).unwrap_or(0);
        let mut sum = vec![0.0f64; dout];
        let mut sumsq = vec![0.0f64; dout];
        for t in 0..trials {
            let mut noise = NoiseModel::from_config(
                trial_seed(base, t),
                &self.model.opts.array,
            );
            let y = self.model.forward(row, &mut noise);
            for (o, &v) in y.iter().enumerate() {
                sum[o] += v;
                sumsq[o] += v * v;
            }
        }
        let n = trials as f64;
        let logits: Vec<f32> = sum.iter().map(|&s| (s / n) as f32).collect();
        let trial_std = (trials > 1).then(|| {
            sumsq
                .iter()
                .zip(&sum)
                .map(|(&sq, &s)| {
                    let mean = s / n;
                    ((sq / n - mean * mean).max(0.0)).sqrt() as f32
                })
                .collect()
        });
        RowOutput { logits, trial_std }
    }
}

impl ExecutionSession for AcimSession {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec {
            kind: BackendKind::Acim,
            input_dim: self.model.layers.first().map(|l| l.din),
            output_dim: self.model.layers.last().map(|l| l.dout).unwrap_or(0),
            // with noise off the simulator is a pure function of the row
            deterministic: !self.model.opts.noise,
            reference_exact: false,
            max_batch: None,
        }
    }

    fn run(&self, rows: Vec<Vec<f32>>, opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        debug_assert_eq!(rows.len(), opts.len());
        Ok(rows
            .iter()
            .zip(opts)
            .map(|(row, opt)| self.run_row(row, opt))
            .collect())
    }
}

// ---- MLP -------------------------------------------------------------------

/// Float MLP baseline session.
pub struct MlpSession {
    pub model: Arc<MlpModel>,
}

impl ExecutionSession for MlpSession {
    fn name(&self) -> &str {
        &self.model.name
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::exact(
            BackendKind::Mlp,
            self.model.dims.first().copied(),
            self.model.dims.last().copied().unwrap_or(0),
        )
    }

    fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        Ok(rows
            .iter()
            .map(|r| {
                RowOutput::from(
                    self.model
                        .forward(r)
                        .iter()
                        .map(|&v| v as f32)
                        .collect::<Vec<f32>>(),
                )
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_and_roundtrips() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.as_str()).unwrap(), kind);
        }
        let err = BackendKind::parse("gpu").unwrap_err().to_string();
        assert!(err.contains("unknown backend 'gpu'"), "{err}");
        assert!(err.contains("pjrt | digital | acim | mlp"), "{err}");
    }

    #[test]
    fn exec_options_default_is_one_unseeded_trial() {
        let o = ExecOptions::default();
        assert_eq!(o.seed, None);
        assert_eq!(o.trials, 1);
    }

    #[test]
    fn trial_seed_is_stable_and_spreads() {
        assert_eq!(trial_seed(42, 0), trial_seed(42, 0));
        assert_ne!(trial_seed(42, 0), trial_seed(42, 1));
        assert_ne!(trial_seed(42, 0), trial_seed(43, 0));
    }

    #[test]
    fn digital_profiling_changes_no_output_bits_and_reports() {
        use crate::kan::checkpoint::synthetic_kan_checkpoint;

        let qk = Arc::new(QuantKanModel::from_checkpoint(&synthetic_kan_checkpoint(
            "p",
            &[3, 4, 2],
            5,
            3,
            0xC33,
        )));
        let plain = DigitalSession::with_engine(qk.clone(), true);
        let prof = DigitalSession::with_engine_profiled(qk.clone(), true, true);
        assert!(plain.profile().is_none(), "unprofiled sessions report None");
        let rows: Vec<Vec<f32>> = vec![vec![0.1, -0.2, 0.3], vec![0.9, 0.0, -0.9]];
        let opts = vec![ExecOptions::default(); rows.len()];
        let a = plain.run(rows.clone(), &opts).unwrap();
        let b = prof.run(rows, &opts).unwrap();
        for (ra, rb) in a.iter().zip(&b) {
            for (x, y) in ra.logits.iter().zip(&rb.logits) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let v = prof.profile().expect("profiled session reports");
        assert_eq!(v.get("samples").and_then(|s| s.as_i64()), Some(2));
        let layers = v.get("layers").and_then(|l| l.as_array()).unwrap();
        assert_eq!(layers.len(), 2);
        for l in layers {
            assert!(l.get("mapping_drift_rankcorr").and_then(|x| x.as_f64()).is_some());
        }
        // the flag is inert without the engine path
        let scalar = DigitalSession::with_engine_profiled(qk, false, true);
        assert!(scalar.profile().is_none());
    }

    #[test]
    fn acim_session_is_reproducible_per_seed_and_parallel_safe() {
        use crate::kan::checkpoint::synthetic_kan_checkpoint;
        use crate::mapping::{self, MappingStrategy};

        let qk = Arc::new(QuantKanModel::from_checkpoint(&synthetic_kan_checkpoint(
            "t",
            &[3, 4, 2],
            5,
            3,
            0xA11,
        )));
        // read noise well above the ADC LSB, so distinct seeds provably
        // draw distinct outputs (sub-LSB noise would quantize away)
        let mut opts = crate::acim::AcimOptions::default();
        opts.array.sigma_read = 0.5;
        let mappings: Vec<Vec<usize>> = qk
            .layers
            .iter()
            .map(|l| {
                let probs = mapping::gaussian(l, 0.0, 0.5);
                mapping::build_mapping(&probs, opts.array.rows, MappingStrategy::Sam)
            })
            .collect();
        let acim = Arc::new(AcimModel::program(&qk, opts, &mappings).unwrap());
        let session = Arc::new(AcimSession::new(acim, "t".into()));
        assert!(!session.spec().deterministic);

        let row = vec![0.25f32, -0.5, 0.75];
        let seeded = ExecOptions { seed: Some(99), trials: 1 };
        let a = session.run(vec![row.clone()], &[seeded]).unwrap();
        // same (row, seed) inside a different batch composition, and
        // concurrently from many threads: bit-identical
        let b = session
            .run(
                vec![vec![0.9, 0.9, 0.9], row.clone(), vec![-0.9, 0.0, 0.9]],
                &[ExecOptions { seed: Some(7), trials: 1 }, seeded, seeded],
            )
            .unwrap();
        assert_eq!(a[0].logits, b[1].logits);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = session.clone();
            let row = row.clone();
            handles.push(std::thread::spawn(move || {
                s.run(vec![row], &[ExecOptions { seed: Some(99), trials: 1 }])
                    .unwrap()[0]
                    .logits
                    .clone()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), a[0].logits);
        }
        // a different seed gives a different draw (noise is on)
        let c = session
            .run(vec![row], &[ExecOptions { seed: Some(100), trials: 1 }])
            .unwrap();
        assert_ne!(a[0].logits, c[0].logits);
    }

    #[test]
    fn acim_trials_yield_mean_and_std() {
        use crate::kan::checkpoint::synthetic_kan_checkpoint;
        use crate::mapping::{self, MappingStrategy};

        let qk = Arc::new(QuantKanModel::from_checkpoint(&synthetic_kan_checkpoint(
            "t",
            &[2, 3, 2],
            5,
            3,
            0xB22,
        )));
        let opts = crate::acim::AcimOptions::default();
        let mappings: Vec<Vec<usize>> = qk
            .layers
            .iter()
            .map(|l| {
                let probs = mapping::gaussian(l, 0.0, 0.5);
                mapping::build_mapping(&probs, opts.array.rows, MappingStrategy::Uniform)
            })
            .collect();
        let acim = Arc::new(AcimModel::program(&qk, opts, &mappings).unwrap());
        let session = AcimSession::new(acim, "t".into());
        let out = session
            .run(
                vec![vec![0.3, -0.3]],
                &[ExecOptions { seed: Some(5), trials: 8 }],
            )
            .unwrap();
        let std = out[0].trial_std.as_ref().expect("trials > 1 must yield std");
        assert_eq!(std.len(), out[0].logits.len());
        assert!(std.iter().all(|s| s.is_finite() && *s >= 0.0));
        // single-trial runs carry no std
        let single = session
            .run(vec![vec![0.3, -0.3]], &[ExecOptions { seed: Some(5), trials: 1 }])
            .unwrap();
        assert!(single[0].trial_std.is_none());
        // trials are reproducible too
        let again = session
            .run(
                vec![vec![0.3, -0.3]],
                &[ExecOptions { seed: Some(5), trials: 8 }],
            )
            .unwrap();
        assert_eq!(out, again);
    }
}
