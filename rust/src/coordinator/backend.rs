//! Inference backends the router can dispatch to.
//!
//! * [`PjrtBackend`] — the AOT-compiled HLO graph on the PJRT CPU client
//!   (digital reference, batch-shaped; short batches are padded). The
//!   `xla` crate's client types are `!Send` (`Rc` + raw pointers), so the
//!   executable lives on a dedicated actor thread and batches cross a
//!   channel — the PJRT runtime itself parallelizes the math internally.
//! * [`DigitalBackend`] — the rust integer-dataflow reference
//!   ([`QuantKanModel`]), bit-faithful to the hardware pipeline minus
//!   analog effects. No padding constraints.
//! * [`AcimBackend`] — the full analog simulator (IR-drop + noise + ADC).
//! * [`MlpBackend`] — the float MLP baseline.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};

use crate::acim::{AcimModel, NoiseModel};
use crate::baseline::MlpModel;
use crate::error::{Error, Result};
use crate::kan::{EngineOptions, EngineScratch, KanEngine, QuantKanModel};
use crate::runtime::PjrtEngine;

/// A synchronous batch-inference backend. Called from blocking worker
/// tasks; implementations must be `Send + Sync`.
pub trait InferBackend: Send + Sync {
    fn name(&self) -> &str;
    /// Number of output logits per row.
    fn output_dim(&self) -> usize;
    /// Expected features per row, when the backend knows it. Used for
    /// admission-time validation: one malformed row must be rejected at
    /// submit, before it can poison a shared dynamic batch that also
    /// carries other clients' requests.
    fn input_dim(&self) -> Option<usize> {
        None
    }
    /// Run a batch of feature rows; returns one logit vector per row.
    /// Takes ownership of the rows so actor-style backends (PJRT) can
    /// move them across their thread boundary without copying.
    fn infer_batch(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>>;
}

type PjrtJob = (Vec<Vec<f32>>, SyncSender<Result<Vec<Vec<f32>>>>);

/// PJRT executable backend: an actor thread owning the (!Send) client.
pub struct PjrtBackend {
    tx: Mutex<SyncSender<PjrtJob>>,
    model: String,
    input_dim: usize,
    output_dim: usize,
}

impl PjrtBackend {
    /// Spawn the actor: it creates the PJRT client, compiles `hlo_path`,
    /// and then serves batches until the backend is dropped.
    pub fn spawn(
        hlo_path: PathBuf,
        batch: usize,
        input_dim: usize,
        output_dim: usize,
        model: String,
    ) -> Result<Self> {
        let (job_tx, job_rx) = sync_channel::<PjrtJob>(16);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        std::thread::Builder::new()
            .name("kan-edge-pjrt".into())
            .spawn(move || {
                // keep the client (engine) alive for the executable's whole
                // lifetime — the loaded executable references it internally
                let (_engine, exe) = match PjrtEngine::cpu().and_then(|e| {
                    e.load_hlo(&hlo_path, batch, input_dim, output_dim)
                        .map(|exe| (e, exe))
                }) {
                    Ok(pair) => {
                        let _ = ready_tx.send(Ok(()));
                        pair
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok((rows, reply)) = job_rx.recv() {
                    let result = run_batches(&exe, &rows, batch, input_dim, output_dim);
                    let _ = reply.send(result);
                }
            })
            .map_err(|e| Error::Serving(format!("cannot spawn pjrt actor: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt actor died during startup".into()))??;
        Ok(Self { tx: Mutex::new(job_tx), model, input_dim, output_dim })
    }
}

fn run_batches(
    exe: &crate::runtime::PjrtExecutable,
    rows: &[Vec<f32>],
    batch: usize,
    input_dim: usize,
    output_dim: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(batch) {
        let mut flat = vec![0.0f32; batch * input_dim];
        for (i, row) in chunk.iter().enumerate() {
            if row.len() != input_dim {
                return Err(Error::Shape(format!(
                    "row has {} features, expected {input_dim}",
                    row.len()
                )));
            }
            flat[i * input_dim..(i + 1) * input_dim].copy_from_slice(row);
        }
        let y = exe.run(&flat)?;
        for i in 0..chunk.len() {
            out.push(y[i * output_dim..(i + 1) * output_dim].to_vec());
        }
    }
    Ok(out)
}

impl InferBackend for PjrtBackend {
    fn name(&self) -> &str {
        &self.model
    }

    fn output_dim(&self) -> usize {
        self.output_dim
    }

    fn input_dim(&self) -> Option<usize> {
        Some(self.input_dim)
    }

    fn infer_batch(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        {
            // ownership of the rows moves through the channel; no copy
            let tx = self.tx.lock().unwrap();
            tx.send((rows, reply_tx))
                .map_err(|_| Error::Runtime("pjrt actor gone".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt actor dropped reply".into()))?
    }
}

/// Rust digital backend. By default it executes through the compiled
/// [`KanEngine`] plan (integer-exact hot path, zero steady-state
/// allocations inside the engine; see `docs/ENGINE.md`); the scalar
/// golden reference (`QuantKanModel::forward_batch`) remains available
/// via [`DigitalBackend::with_engine`]`(.., false)` / the
/// `server.engine = false` config knob.
pub struct DigitalBackend {
    pub model: Arc<QuantKanModel>,
    engine: Option<Arc<KanEngine>>,
    /// Reusable scratch arenas, one per concurrent in-flight batch:
    /// popped for the duration of an `infer_batch`, pushed back after —
    /// steady state allocates no new arenas.
    scratch: Mutex<Vec<EngineScratch>>,
}

impl DigitalBackend {
    /// Engine-backed digital backend (the default serving path).
    pub fn new(model: Arc<QuantKanModel>) -> Self {
        Self::with_engine(model, true)
    }

    /// Choose the execution path explicitly. A failed engine compile
    /// (exotic checkpoint outside the int8/int16 contract) degrades to
    /// the scalar reference with a warning rather than refusing to
    /// serve.
    pub fn with_engine(model: Arc<QuantKanModel>, use_engine: bool) -> Self {
        let engine = if use_engine {
            match KanEngine::compile(&model, EngineOptions::default()) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    eprintln!(
                        "warning: engine compile failed for '{}' ({e}); \
                         serving the scalar reference path",
                        model.name
                    );
                    None
                }
            }
        } else {
            None
        };
        Self { model, engine, scratch: Mutex::new(Vec::new()) }
    }

    /// Whether the planned engine is the active execution path.
    pub fn engine_enabled(&self) -> bool {
        self.engine.is_some()
    }
}

impl InferBackend for DigitalBackend {
    fn name(&self) -> &str {
        &self.model.name
    }

    fn output_dim(&self) -> usize {
        self.model.output_dim()
    }

    fn input_dim(&self) -> Option<usize> {
        Some(self.model.input_dim())
    }

    fn infer_batch(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        // flatten once and run the batch path: one allocation set per layer
        // instead of per row (EXPERIMENTS.md §Perf: +9% serving throughput)
        let din = self.model.input_dim();
        let dout = self.model.output_dim();
        let mut flat = Vec::with_capacity(rows.len() * din);
        for r in &rows {
            if r.len() != din {
                return Err(crate::error::Error::Shape(format!(
                    "row has {} features, expected {din}",
                    r.len()
                )));
            }
            flat.extend_from_slice(r);
        }
        let batch = rows.len();
        let out = if let Some(engine) = &self.engine {
            // one scratch per call: the service's worker pool provides
            // the multi-core, each worker reuses an arena from the pool
            let mut s = self
                .scratch
                .lock()
                .unwrap()
                .pop()
                .unwrap_or_else(|| engine.new_scratch());
            let mut out = vec![0.0f64; batch * dout];
            engine.forward_batch_with(&flat, batch, &mut out, std::slice::from_mut(&mut s));
            self.scratch.lock().unwrap().push(s);
            out
        } else {
            self.model.forward_batch(&flat, batch)
        };
        Ok(out
            .chunks_exact(dout)
            .map(|c| c.iter().map(|&v| v as f32).collect())
            .collect())
    }
}

/// Analog ACIM-simulator backend (deterministic per-backend noise stream).
pub struct AcimBackend {
    pub model: Arc<AcimModel>,
    pub name: String,
    noise: Mutex<NoiseModel>,
}

impl AcimBackend {
    pub fn new(model: Arc<AcimModel>, name: String) -> Self {
        let noise = NoiseModel::from_config(model.opts.seed ^ 0x77, &model.opts.array);
        Self { model, name, noise: Mutex::new(noise) }
    }
}

impl InferBackend for AcimBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn output_dim(&self) -> usize {
        self.model.layers.last().map(|l| l.dout).unwrap_or(0)
    }

    fn input_dim(&self) -> Option<usize> {
        self.model.layers.first().map(|l| l.din)
    }

    fn infer_batch(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let mut noise = self.noise.lock().unwrap();
        Ok(rows
            .iter()
            .map(|r| {
                self.model
                    .forward(r, &mut noise)
                    .iter()
                    .map(|&v| v as f32)
                    .collect()
            })
            .collect())
    }
}

/// Float MLP baseline backend.
pub struct MlpBackend {
    pub model: Arc<MlpModel>,
}

impl InferBackend for MlpBackend {
    fn name(&self) -> &str {
        &self.model.name
    }

    fn output_dim(&self) -> usize {
        *self.model.dims.last().unwrap()
    }

    fn input_dim(&self) -> Option<usize> {
        self.model.dims.first().copied()
    }

    fn infer_batch(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        Ok(rows
            .iter()
            .map(|r| self.model.forward(r).iter().map(|&v| v as f32).collect())
            .collect())
    }
}
