//! The serving engine: admission ([`super::scheduler`]) → dynamic
//! batcher → worker pool → execution session, with metrics throughout.
//! The public handle is [`InferenceService`], a cheap-to-clone client;
//! `infer` blocks the calling thread (callers that need async fan-out
//! use one thread per in-flight request, which is plenty at edge rates).
//!
//! Fairness: every submission is attributed to a [`ClientId`]. The TCP
//! layer passes a per-connection id so one connection's burst cannot
//! starve another's singletons under the `drr` admission policy; direct
//! API callers that use the id-less convenience wrappers get a fresh id
//! per call (each call is its own fairness class).
//!
//! Per-request execution options ([`ExecOptions`]: ACIM noise seed,
//! trial count) are resolved at submission and ride with each row into
//! the dynamic batch, so a batch can mix differently-optioned rows and
//! stochastic outputs never depend on batch composition.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::{BackendKind, BackendSpec, ExecOptions, ExecutionSession, RowOutput};
use super::batcher::{run_batcher, Batch, BatchPolicy, Request};
use super::metrics::{Metrics, MetricsReport};
use super::protocol::ModelSummary;
use super::scheduler::{
    ClientId, QueueGauges, RejectReason, Rejection, SchedMode, Scheduler,
    SchedulerOptions, Submit,
};
use crate::util::sync::LockExt;
use crate::error::{Error, Result};
use crate::obs::trace::{Stage, TraceHandle};

/// Serving configuration (see `config::ServerConfig` and
/// `config::SchedulerConfig` for the file side).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    pub policy: BatchPolicy,
    pub queue_depth: usize,
    pub workers: usize,
    /// Admission policy (FIFO vs deficit-round-robin + quotas).
    pub scheduler: SchedulerOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            queue_depth: 1024,
            workers: 2,
            scheduler: SchedulerOptions::default(),
        }
    }
}

/// Per-request routing + execution selection, as carried by the wire
/// layers into [`Dispatch`]: the optional model spec (`None` = default
/// model), the optional backend kind (`None` = the model's primary
/// backend), and the execution options.
#[derive(Debug, Clone, Default)]
pub struct RouteSpec {
    pub model: Option<String>,
    pub backend: Option<BackendKind>,
    pub opts: ExecOptions,
    /// Observability span for sampled requests (`None` for the
    /// unsampled majority). Not part of routing identity — see the
    /// manual [`PartialEq`] below — it merely rides the same path so
    /// the admission and worker layers can stamp stage boundaries
    /// (`docs/OBSERVABILITY.md`).
    pub trace: Option<TraceHandle>,
}

/// Routing identity ignores the trace span: two routes that resolve to
/// the same model/backend/options are equal whether or not either
/// request happens to be sampled.
impl PartialEq for RouteSpec {
    fn eq(&self, other: &Self) -> bool {
        self.model == other.model
            && self.backend == other.backend
            && self.opts == other.opts
    }
}

impl RouteSpec {
    /// Route to `model` with default backend and options.
    pub fn to_model(model: Option<&str>) -> Self {
        Self { model: model.map(str::to_string), ..Self::default() }
    }
}

/// Closes the admission scheduler when the last [`InferenceService`]
/// clone drops: the batcher drains what is queued, sees end-of-stream,
/// exits, and the worker pool follows — channel teardown, no force-kill.
struct SchedulerCloser(Arc<Scheduler>);

impl Drop for SchedulerCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Cheap-to-clone handle for submitting inference requests.
#[derive(Clone)]
pub struct InferenceService {
    sched: Arc<Scheduler>,
    _closer: Arc<SchedulerCloser>,
    /// The served session's capability descriptor: admission validates
    /// row shapes against it, and the control plane surfaces it.
    spec: BackendSpec,
    /// The session the worker pool executes — kept so the control plane
    /// can read its live profile ([`ExecutionSession::profile`]).
    session: Arc<dyn ExecutionSession>,
    pub metrics: Arc<Metrics>,
}

impl InferenceService {
    /// Spin up the batcher + worker pool over `session`.
    pub fn start(session: Arc<dyn ExecutionSession>, opts: ServeOptions) -> Self {
        Self::start_with_metrics(session, opts, Arc::new(Metrics::new()))
    }

    /// Like [`InferenceService::start`] but recording into an externally
    /// owned [`Metrics`] — the model registry passes per-model metrics
    /// from its [`super::metrics::MetricsHub`] so reports survive
    /// hot-reload swaps.
    pub fn start_with_metrics(
        session: Arc<dyn ExecutionSession>,
        opts: ServeOptions,
        metrics: Arc<Metrics>,
    ) -> Self {
        let spec = session.spec();
        let sched = Arc::new(Scheduler::new(opts.queue_depth, opts.scheduler));
        let (batch_tx, batch_rx) = sync_channel::<Batch>(opts.workers.max(1) * 2);
        let batcher_sched = sched.clone();
        std::thread::Builder::new()
            .name("kan-edge-batcher".into())
            .spawn(move || run_batcher(batcher_sched, batch_tx, opts.policy))
            // lint: allow(panic, "server construction, before any request is accepted")
            .expect("spawn batcher");

        let shared_rx = Arc::new(Mutex::new(batch_rx));
        for i in 0..opts.workers.max(1) {
            let rx = shared_rx.clone();
            let se = session.clone();
            let m = metrics.clone();
            std::thread::Builder::new()
                .name(format!("kan-edge-worker-{i}"))
                .spawn(move || worker_loop(rx, se, m))
                // lint: allow(panic, "server construction, before any request is accepted")
                .expect("spawn worker");
        }
        let closer = Arc::new(SchedulerCloser(sched.clone()));
        Self { sched, _closer: closer, spec, session, metrics }
    }

    /// Capability descriptor of the session this service executes.
    pub fn backend_spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// The execution session behind this service (for control-plane
    /// reads such as [`ExecutionSession::profile`]).
    pub fn session(&self) -> &Arc<dyn ExecutionSession> {
        &self.session
    }

    /// Instantaneous admission-queue gauges (depth, distinct clients,
    /// deepest per-client backlog) — exported via
    /// [`MetricsReport`](super::metrics::MetricsReport).
    pub fn queue_gauges(&self) -> QueueGauges {
        self.sched.gauges()
    }

    /// Admission-time row validation: shape (when the session declares
    /// one) and finiteness. A NaN/∞ feature must be rejected here with a
    /// structured shape error — past admission it would quantize to an
    /// arbitrary-but-valid code and yield a confident prediction.
    fn check_shape(&self, features: &[f32]) -> Result<()> {
        if let Some(din) = self.spec.input_dim {
            if features.len() != din {
                return Err(Error::Shape(format!(
                    "row has {} features, expected {din}",
                    features.len()
                )));
            }
        }
        for (i, v) in features.iter().enumerate() {
            if !v.is_finite() {
                return Err(Error::Shape(format!(
                    "non-finite feature {v} at index {i}"
                )));
            }
        }
        Ok(())
    }

    /// Submit one feature vector and wait for the logits (fresh
    /// [`ClientId`]: this call is its own fairness class).
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_from(ClientId::fresh(), features)
    }

    /// Submit one feature vector on behalf of `client` and wait for the
    /// logits. Admission is subject to the scheduler policy: `fifo`
    /// rejects only on a full queue (seed behavior), `drr` also enforces
    /// the per-client quota and rejects with a retry hint.
    pub fn infer_from(&self, client: ClientId, features: Vec<f32>) -> Result<Vec<f32>> {
        Ok(self
            .infer_opts_from(client, features, ExecOptions::default())?
            .logits)
    }

    /// Like [`InferenceService::infer_from`] with explicit per-request
    /// execution options; returns the full [`RowOutput`] (logits plus
    /// the trial spread for stochastic sessions run with `trials > 1`).
    pub fn infer_opts_from(
        &self,
        client: ClientId,
        features: Vec<f32>,
        opts: ExecOptions,
    ) -> Result<RowOutput> {
        self.infer_traced_from(client, features, opts, None)
    }

    /// Like [`InferenceService::infer_opts_from`] with an optional
    /// observability span: the admission stage is stamped here on
    /// successful submit, and the handle rides the queued request so
    /// the batcher/worker layers stamp the remaining stages
    /// (`docs/OBSERVABILITY.md`).
    pub fn infer_traced_from(
        &self,
        client: ClientId,
        features: Vec<f32>,
        opts: ExecOptions,
        trace: Option<TraceHandle>,
    ) -> Result<RowOutput> {
        self.check_shape(&features)?;
        let (tx, rx) = sync_channel(1);
        let req = Request {
            features,
            opts,
            enqueued: Instant::now(),
            respond: tx,
            trace: trace.clone(),
        };
        match self.sched.try_submit(client, req) {
            Submit::Admitted => {
                if let Some(t) = &trace {
                    t.mark(Stage::Admission);
                }
            }
            Submit::Rejected(r) => {
                // the rejected request's respond channel pairs with `rx`
                // below, which we are about to drop — the error goes to
                // the caller directly, nobody else is listening
                self.metrics.record_rejection();
                return Err(self.admission_error(&r, false));
            }
            Submit::Closed(_) => {
                return Err(Error::Serving("service shut down".into()));
            }
        }
        rx.recv()
            .map_err(|_| Error::Serving("service shut down".into()))?
    }

    /// Submit many feature vectors back-to-back and wait for all logits
    /// (fresh [`ClientId`] — see [`InferenceService::infer_many_from`]).
    pub fn infer_many(&self, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        self.infer_many_from(ClientId::fresh(), rows)
    }

    /// Submit many feature vectors on behalf of `client` and wait for
    /// all logits (row order preserved).
    pub fn infer_many_from(
        &self,
        client: ClientId,
        rows: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        Ok(self
            .infer_many_opts_from(client, rows, ExecOptions::default())?
            .into_iter()
            .map(|o| o.logits)
            .collect())
    }

    /// Batch submit with per-request execution options. The rows hit the
    /// dynamic batcher as one burst, so a single caller produces
    /// multi-row batches — this is the engine behind the v2
    /// `infer_batch` verb. When `opts.seed` is set, row `i` derives its
    /// own independent noise stream as `mix(seed, i)` — a function of
    /// the *submitted* row order only, so results are identical for any
    /// batching, interleaving, or worker count.
    ///
    /// Admission control applies to the batch head only: if the scheduler
    /// cannot take the first row the whole batch is rejected up front.
    /// Once admitted, the remaining rows use a blocking submit — the
    /// deadline-based batcher always drains, so a batch larger than the
    /// queue (or, under `drr`, than the client quota) backpressures the
    /// caller instead of failing spuriously halfway through. Under `drr`
    /// the quota caps how many of this batch's rows can ever sit in the
    /// queue, so concurrent clients keep being admitted and the
    /// round-robin drain interleaves their rows with this batch.
    pub fn infer_many_opts_from(
        &self,
        client: ClientId,
        rows: Vec<Vec<f32>>,
        opts: ExecOptions,
    ) -> Result<Vec<RowOutput>> {
        if rows.is_empty() {
            return Err(Error::Serving("empty batch".into()));
        }
        // validate every row before admitting any: a malformed row must
        // fail the call, not a shared batch
        for row in &rows {
            self.check_shape(row)?;
        }
        let mut waiters = Vec::with_capacity(rows.len());
        let mut admitted_head = false;
        for (i, features) in rows.into_iter().enumerate() {
            let row_opts = opts.for_row(i);
            let (tx, rx) = sync_channel(1);
            let req = Request {
                features,
                opts: row_opts,
                enqueued: Instant::now(),
                respond: tx,
                // only single-row v2 requests are traced: a batch's rows
                // interleave arbitrarily under drr, so one span cannot
                // represent the batch's pipeline passage faithfully
                trace: None,
            };
            if !admitted_head {
                match self.sched.try_submit(client, req) {
                    Submit::Admitted => admitted_head = true,
                    Submit::Rejected(r) => {
                        self.metrics.record_rejection();
                        return Err(self.admission_error(&r, true));
                    }
                    Submit::Closed(_) => {
                        return Err(Error::Serving("service shut down".into()));
                    }
                }
            } else if self.sched.submit_blocking(client, req).is_err() {
                return Err(Error::Serving("service shut down".into()));
            }
            waiters.push(rx);
        }
        waiters
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| Error::Serving("service shut down".into()))?
            })
            .collect()
    }

    /// Map a scheduler rejection onto the crate error contract: `fifo`
    /// keeps the seed wording exactly (pre-scheduler clients match on
    /// it); `drr` rejections are structured [`Error::Overloaded`] with
    /// the retry hint.
    fn admission_error(&self, r: &Rejection, batch: bool) -> Error {
        let seed_msg = if batch {
            "queue full: batch admission rejected"
        } else {
            "queue full: admission rejected"
        };
        match (self.sched.options().mode, r.reason) {
            (SchedMode::Fifo, _) => Error::Serving(seed_msg.into()),
            (SchedMode::Drr, RejectReason::QueueFull) => Error::Overloaded {
                message: format!(
                    "queue full ({} rows queued across all clients)",
                    self.sched.capacity()
                ),
                retry_after_ms: r.retry_after_ms,
            },
            (SchedMode::Drr, RejectReason::ClientQuota { queued, quota }) => {
                Error::Overloaded {
                    message: format!(
                        "client quota exceeded ({queued}/{quota} rows in queue)"
                    ),
                    retry_after_ms: r.retry_after_ms,
                }
            }
        }
    }
}

/// Request routing surface the TCP layer serves: either a single
/// [`InferenceService`] or a multi-model
/// [`crate::registry::ModelRegistry`].
///
/// `dispatch` resolves the [`RouteSpec`] — optional model spec (`None`
/// = default model), optional [`BackendKind`] (`None` = the model's
/// primary backend), per-request [`ExecOptions`] — runs inference, and
/// returns the resolved model id alongside the output so clients can
/// observe which version served them (hot-reload visibility). `client`
/// attributes the submission for fair admission (the TCP layer passes a
/// per-connection id).
///
/// The remaining methods back the v2 control plane (`infer_batch`,
/// `list_models`, `model_info`, `metrics`, `health` verbs); the defaults
/// make any `dispatch`-only implementation a valid, if minimal, target.
pub trait Dispatch: Send + Sync {
    fn dispatch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)>;

    /// Batch dispatch: resolve the route once, run every row, return the
    /// resolved id with one output per row (row order preserved).
    /// Implementations with a dynamic batcher override this to feed it
    /// the whole batch back-to-back. The default honors the wire
    /// contract's per-row seed derivation (`mix(seed, i)`), so even a
    /// loop-based implementation gives batch rows independent noise
    /// streams.
    fn dispatch_batch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        if rows.is_empty() {
            return Err(Error::Serving("empty batch".into()));
        }
        let mut id = String::new();
        let mut out = Vec::with_capacity(rows.len());
        for (i, row) in rows.into_iter().enumerate() {
            let row_route = RouteSpec {
                model: route.model.clone(),
                backend: route.backend,
                opts: route.opts.for_row(i),
                trace: None,
            };
            let (mid, logits) = self.dispatch(client, &row_route, row)?;
            id = mid;
            out.push(logits);
        }
        Ok((id, out))
    }

    /// Control-plane summaries of the models behind this endpoint.
    fn model_summaries(&self) -> Vec<ModelSummary> {
        Vec::new()
    }

    /// Per-model serving metrics, keyed by serving id.
    fn metrics_reports(&self) -> Vec<(String, MetricsReport)> {
        Vec::new()
    }

    /// Number of models with a live serving pipeline.
    fn live_model_count(&self) -> usize {
        self.model_summaries().iter().filter(|m| m.live).count()
    }

    /// Fetch a stored artifact by content digest for replication: the
    /// manifest metadata of the model entry it backs (if any) plus the
    /// raw payload. Registry-backed endpoints override this; the
    /// default refuses — a single-model endpoint has no store.
    fn pull_artifact(
        &self,
        _digest: &str,
    ) -> Result<(Option<crate::util::json::Value>, Vec<u8>)> {
        Err(Error::Serving(
            "artifact replication is not supported on this endpoint".into(),
        ))
    }

    /// Publish a pushed artifact payload as `name` (optionally at an
    /// exact version). Returns the resolved `name@version`. The
    /// implementation must re-hash `data` against `digest` before
    /// publishing anything.
    fn push_artifact(
        &self,
        _name: &str,
        _version: Option<u32>,
        _digest: &str,
        _data: &[u8],
    ) -> Result<String> {
        Err(Error::Serving(
            "artifact replication is not supported on this endpoint".into(),
        ))
    }

    /// Extra top-level sections merged into the `metrics` body (the
    /// cluster router adds `cluster` / `nodes` rollups here). `None`
    /// adds nothing.
    fn metrics_overlay(&self) -> Option<crate::util::json::Value> {
        None
    }

    /// Start a staged canary rollout of `model` (the manifest-current
    /// version) against `baseline` (see [`crate::rollout`]). Registry
    /// endpoints override; the default refuses — a single-model endpoint
    /// has no versions to split between.
    fn rollout_start(
        &self,
        _model: &str,
        _baseline: &str,
    ) -> Result<crate::util::json::Value> {
        Err(Error::Serving(
            "rollouts are not supported on this endpoint".into(),
        ))
    }

    /// Rollout state machines, gate evaluations and decision history
    /// (all rollouts, or just `model`'s).
    fn rollout_status(&self, _model: Option<&str>) -> Result<crate::util::json::Value> {
        Err(Error::Serving(
            "rollouts are not supported on this endpoint".into(),
        ))
    }

    /// Operator-initiated instant rollback of `model`'s rollout.
    fn rollout_abort(&self, _model: &str) -> Result<crate::util::json::Value> {
        Err(Error::Serving(
            "rollouts are not supported on this endpoint".into(),
        ))
    }

    /// Drop `model`'s terminal rollout record (and its routing override).
    fn rollout_clear(&self, _model: &str) -> Result<crate::util::json::Value> {
        Err(Error::Serving(
            "rollouts are not supported on this endpoint".into(),
        ))
    }
}

impl Dispatch for InferenceService {
    fn dispatch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        if let Some(m) = &route.model {
            return Err(single_model_error(m));
        }
        self.check_backend(route.backend)?;
        let out =
            self.infer_traced_from(client, features, route.opts, route.trace.clone())?;
        Ok(("default".to_string(), out))
    }

    fn dispatch_batch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        if let Some(m) = &route.model {
            return Err(single_model_error(m));
        }
        self.check_backend(route.backend)?;
        let outs = self.infer_many_opts_from(client, rows, route.opts)?;
        Ok(("default".to_string(), outs))
    }

    fn model_summaries(&self) -> Vec<ModelSummary> {
        vec![ModelSummary {
            name: "default".to_string(),
            version: 0,
            kind: "single".to_string(),
            dims: Vec::new(),
            num_params: 0,
            live: true,
            accuracy: None,
            digest: None,
            backend: Some(super::protocol::BackendInfo::from_spec(&self.spec, None)),
        }]
    }

    fn metrics_reports(&self) -> Vec<(String, MetricsReport)> {
        let mut report = self.metrics.report();
        let g = self.queue_gauges();
        report.queue_depth = Some(g.depth);
        report.queue_clients = Some(g.clients);
        report.max_client_backlog = Some(g.max_client_backlog);
        report.engine_profile = self.session.profile();
        vec![("default".to_string(), report)]
    }
}

impl InferenceService {
    /// A single-session endpoint serves exactly one backend: an explicit
    /// request for a different one is a routing error, not silent
    /// fallback.
    fn check_backend(&self, requested: Option<BackendKind>) -> Result<()> {
        match requested {
            None => Ok(()),
            Some(k) if k == self.spec.kind => Ok(()),
            Some(k) => Err(backend_not_served(k, &[self.spec.kind])),
        }
    }
}

fn single_model_error(model: &str) -> Error {
    Error::Serving(format!(
        "this endpoint serves a single model; cannot route to '{model}' \
         (serve with a registry for multi-model routing)"
    ))
}

/// Structured error for a backend the endpoint cannot execute — mapped
/// to the `not_found` wire code (see [`super::protocol::code_for`]).
pub fn backend_not_served(requested: BackendKind, served: &[BackendKind]) -> Error {
    let served: Vec<&str> = served.iter().map(|k| k.as_str()).collect();
    Error::Serving(format!(
        "backend '{requested}' is not served here (serving: {})",
        served.join(", ")
    ))
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Batch>>>,
    session: Arc<dyn ExecutionSession>,
    m: Arc<Metrics>,
) {
    loop {
        let batch = {
            let guard = rx.lock_recover();
            // lint: allow(lock-blocking, "shared-receiver worker pool: the lock exists to multiplex recv")
            match guard.recv() {
                Ok(b) => b,
                Err(_) => break,
            }
        };
        m.record_batch(batch.len());
        let queue_wait = batch.max_queue_wait();
        let closed_at = batch.closed_at;
        // move the feature rows out of the requests: the session takes
        // ownership (no per-dispatch copy), the waiters keep only the
        // response channel, the enqueue timestamp, and the trace span
        let mut rows = Vec::with_capacity(batch.requests.len());
        let mut opts = Vec::with_capacity(batch.requests.len());
        let mut waiters = Vec::with_capacity(batch.requests.len());
        for req in batch.requests {
            if let Some(t) = &req.trace {
                // queue ends when the batcher closed the batch; the gap
                // from there to here (channel hop + worker pickup) is
                // the batch stage
                t.mark_at(Stage::Queue, closed_at);
                t.mark(Stage::Batch);
            }
            rows.push(req.features);
            opts.push(req.opts);
            waiters.push((req.enqueued, req.respond, req.trace));
        }
        match session.run(rows, &opts) {
            Ok(outputs) => {
                for ((enqueued, respond, trace), out) in
                    waiters.into_iter().zip(outputs)
                {
                    if let Some(t) = &trace {
                        t.mark(Stage::Execute);
                    }
                    let latency = enqueued.elapsed();
                    m.record_request(latency, queue_wait);
                    let _ = respond.try_send(Ok(out));
                }
            }
            Err(e) => {
                m.record_error();
                let msg = e.to_string();
                for (_, respond, trace) in waiters {
                    if let Some(t) = &trace {
                        t.mark(Stage::Execute);
                    }
                    let _ = respond.try_send(Err(Error::Serving(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Backend that doubles its input.
    struct Doubler;

    impl ExecutionSession for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn spec(&self) -> BackendSpec {
            BackendSpec::synthetic(1)
        }

        fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
            Ok(rows.iter().map(|r| vec![r[0] * 2.0].into()).collect())
        }
    }

    struct Exploder;

    impl ExecutionSession for Exploder {
        fn name(&self) -> &str {
            "exploder"
        }

        fn spec(&self) -> BackendSpec {
            BackendSpec::synthetic(1)
        }

        fn run(&self, _rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
            Err(Error::Serving("boom".into()))
        }
    }

    /// Backend that sleeps per batch so queues stay occupied.
    struct Sleepy(Duration);

    impl ExecutionSession for Sleepy {
        fn name(&self) -> &str {
            "sleepy"
        }

        fn spec(&self) -> BackendSpec {
            BackendSpec::synthetic(1)
        }

        fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
            std::thread::sleep(self.0);
            Ok(rows.iter().map(|r| vec![r[0]].into()).collect())
        }
    }

    /// Backend that echoes each row's resolved seed (or -1) — proves
    /// per-row option plumbing end to end.
    struct SeedEcho;

    impl ExecutionSession for SeedEcho {
        fn name(&self) -> &str {
            "seed-echo"
        }

        fn spec(&self) -> BackendSpec {
            BackendSpec { deterministic: false, ..BackendSpec::synthetic(1) }
        }

        fn run(&self, rows: Vec<Vec<f32>>, opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
            Ok(rows
                .iter()
                .zip(opts)
                .map(|(_, o)| {
                    vec![o.seed.map(|s| (s % 1024) as f32).unwrap_or(-1.0)].into()
                })
                .collect())
        }
    }

    #[test]
    fn end_to_end_inference() {
        let svc = InferenceService::start(Arc::new(Doubler), ServeOptions::default());
        let out = svc.infer(vec![21.0]).unwrap();
        assert_eq!(out, vec![42.0]);
        assert_eq!(svc.metrics.report().requests, 1);
    }

    #[test]
    fn traced_request_stamps_pipeline_stages() {
        use crate::obs::trace::SpanCell;
        let svc = InferenceService::start(Arc::new(Doubler), ServeOptions::default());
        let span = Arc::new(SpanCell::new(7));
        let out = svc
            .infer_traced_from(
                ClientId::fresh(),
                vec![3.0],
                ExecOptions::default(),
                Some(span.clone()),
            )
            .unwrap();
        assert_eq!(out.logits, vec![6.0]);
        let offs = span.offsets_us();
        for s in [Stage::Admission, Stage::Queue, Stage::Batch, Stage::Execute] {
            assert!(offs[s as usize].is_some(), "stage {} not stamped", s.as_str());
        }
        // the respond stage belongs to the wire layer, which this
        // direct-API call never touches
        assert!(offs[Stage::Respond as usize].is_none());
        // stamped offsets are monotone in stage order
        let stamped: Vec<u64> = offs.iter().flatten().copied().collect();
        for w in stamped.windows(2) {
            assert!(w[0] <= w[1], "offsets not monotone: {stamped:?}");
        }
    }

    #[test]
    fn concurrent_requests_are_batched() {
        let opts = ServeOptions {
            policy: BatchPolicy { max_batch: 16, deadline: Duration::from_millis(5) },
            ..Default::default()
        };
        let svc = InferenceService::start(Arc::new(Doubler), opts);
        let mut handles = Vec::new();
        for i in 0..64 {
            let s = svc.clone();
            handles.push(std::thread::spawn(move || s.infer(vec![i as f32])));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap().unwrap();
            assert_eq!(out[0], 2.0 * i as f32);
        }
        let report = svc.metrics.report();
        assert_eq!(report.requests, 64);
        assert!(report.mean_batch > 1.0, "no batching happened");
    }

    #[test]
    fn shape_checked_at_admission() {
        struct Fixed;

        impl ExecutionSession for Fixed {
            fn name(&self) -> &str {
                "fixed"
            }

            fn spec(&self) -> BackendSpec {
                BackendSpec::exact(BackendKind::Digital, Some(2), 1)
            }

            fn run(
                &self,
                rows: Vec<Vec<f32>>,
                _opts: &[ExecOptions],
            ) -> Result<Vec<RowOutput>> {
                Ok(rows.iter().map(|r| vec![r[0] + r[1]].into()).collect())
            }
        }

        let svc = InferenceService::start(Arc::new(Fixed), ServeOptions::default());
        let err = svc.infer(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err}");
        let err = svc.infer_many(vec![vec![1.0, 2.0], vec![3.0]]).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err}");
        // valid traffic is unaffected and no batch was poisoned
        assert_eq!(svc.infer(vec![1.0, 2.0]).unwrap(), vec![3.0]);
        assert_eq!(svc.metrics.report().errors, 0);
    }

    #[test]
    fn non_finite_features_rejected_at_admission() {
        let svc = InferenceService::start(Arc::new(Doubler), ServeOptions::default());
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let err = svc.infer(vec![bad]).unwrap_err();
            assert!(
                err.to_string().contains("non-finite feature"),
                "{bad}: {err}"
            );
        }
        // batch submit validates every row before admitting any
        let err = svc
            .infer_many(vec![vec![1.0], vec![f32::NAN]])
            .unwrap_err();
        assert!(err.to_string().contains("non-finite feature"), "{err}");
        // valid traffic unaffected, nothing reached the backend as an error
        assert_eq!(svc.infer(vec![21.0]).unwrap(), vec![42.0]);
        assert_eq!(svc.metrics.report().errors, 0);
    }

    #[test]
    fn infer_many_feeds_multi_row_batches() {
        let opts = ServeOptions {
            policy: BatchPolicy { max_batch: 16, deadline: Duration::from_millis(5) },
            ..Default::default()
        };
        let svc = InferenceService::start(Arc::new(Doubler), opts);
        let rows: Vec<Vec<f32>> = (0..48).map(|i| vec![i as f32]).collect();
        let outs = svc.infer_many(rows).unwrap();
        assert_eq!(outs.len(), 48);
        for (i, out) in outs.iter().enumerate() {
            assert_eq!(out[0], 2.0 * i as f32);
        }
        let report = svc.metrics.report();
        assert_eq!(report.requests, 48);
        assert!(
            report.mean_batch > 1.5,
            "batch submit produced singletons (mean {})",
            report.mean_batch
        );
    }

    #[test]
    fn batch_rows_get_independent_derived_seeds() {
        let svc = InferenceService::start(Arc::new(SeedEcho), ServeOptions::default());
        let opts = ExecOptions { seed: Some(42), trials: 1 };
        let outs = svc
            .infer_many_opts_from(
                ClientId::fresh(),
                vec![vec![0.0], vec![0.0], vec![0.0]],
                opts,
            )
            .unwrap();
        // every row saw a seed, derived deterministically per row index
        let seeds: Vec<f32> = outs.iter().map(|o| o.logits[0]).collect();
        assert!(seeds.iter().all(|&s| s >= 0.0), "row lost its seed: {seeds:?}");
        assert_ne!(seeds[0], seeds[1], "rows must not share a noise stream");
        // resubmitting the same batch derives the same per-row seeds
        let again = svc
            .infer_many_opts_from(
                ClientId::fresh(),
                vec![vec![0.0], vec![0.0], vec![0.0]],
                opts,
            )
            .unwrap();
        assert_eq!(outs, again);
        // unseeded rows stay unseeded
        let outs = svc
            .infer_many_opts_from(
                ClientId::fresh(),
                vec![vec![0.0]],
                ExecOptions::default(),
            )
            .unwrap();
        assert_eq!(outs[0].logits[0], -1.0);
    }

    #[test]
    fn single_session_dispatch_rejects_other_backends() {
        let svc = InferenceService::start(Arc::new(Doubler), ServeOptions::default());
        let route = RouteSpec { backend: Some(BackendKind::Acim), ..Default::default() };
        let err = svc.dispatch(ClientId::fresh(), &route, vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("not served here"), "{err}");
        // the served kind is accepted explicitly
        let route = RouteSpec { backend: Some(BackendKind::Digital), ..Default::default() };
        let (_, out) = svc.dispatch(ClientId::fresh(), &route, vec![2.0]).unwrap();
        assert_eq!(out.logits, vec![4.0]);
    }

    #[test]
    fn infer_many_preserves_row_order_under_drr() {
        let opts = ServeOptions {
            policy: BatchPolicy { max_batch: 8, deadline: Duration::from_millis(2) },
            queue_depth: 16,
            scheduler: SchedulerOptions {
                mode: SchedMode::Drr,
                client_quota: 4,
                fairness_window: 2,
            },
            ..Default::default()
        };
        let svc = InferenceService::start(Arc::new(Doubler), opts);
        // larger than the quota: the tail backpressures through
        // submit_blocking, results still come back in row order
        let rows: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32]).collect();
        let outs = svc.infer_many(rows).unwrap();
        for (i, out) in outs.iter().enumerate() {
            assert_eq!(out[0], 2.0 * i as f32);
        }
    }

    #[test]
    fn drr_quota_rejection_is_structured() {
        let opts = ServeOptions {
            policy: BatchPolicy { max_batch: 4, deadline: Duration::from_millis(1) },
            queue_depth: 64,
            workers: 1,
            scheduler: SchedulerOptions {
                mode: SchedMode::Drr,
                client_quota: 2,
                fairness_window: 2,
            },
        };
        // slow backend keeps the client's queue at quota long enough to
        // observe the rejection deterministically
        let svc =
            InferenceService::start(Arc::new(Sleepy(Duration::from_millis(50))), opts);
        let client = ClientId::fresh();
        let s2 = svc.clone();
        let batch = std::thread::spawn(move || {
            s2.infer_many_from(client, (0..12).map(|i| vec![i as f32]).collect())
        });
        // let the burst saturate its quota
        std::thread::sleep(Duration::from_millis(20));
        let mut saw_overloaded = false;
        for _ in 0..10 {
            match svc.infer_from(client, vec![99.0]) {
                Err(Error::Overloaded { message, retry_after_ms }) => {
                    assert!(message.contains("quota"), "{message}");
                    assert!(retry_after_ms >= 1);
                    saw_overloaded = true;
                    break;
                }
                Ok(_) | Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        assert!(saw_overloaded, "quota rejection never observed");
        assert!(svc.metrics.report().rejected >= 1);
        let outs = batch.join().unwrap().unwrap();
        assert_eq!(outs.len(), 12);
    }

    #[test]
    fn backend_errors_propagate() {
        let svc = InferenceService::start(Arc::new(Exploder), ServeOptions::default());
        let err = svc.infer(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert_eq!(svc.metrics.report().errors, 1);
    }
}
