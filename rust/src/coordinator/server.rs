//! The serving engine: admission → dynamic batcher → worker pool →
//! backend, with metrics throughout. The public handle is
//! [`InferenceService`], a cheap-to-clone client; `infer` blocks the
//! calling thread (callers that need async fan-out use one thread per
//! in-flight request, which is plenty at edge rates).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::InferBackend;
use super::batcher::{reject, run_batcher, try_admit, Batch, BatchPolicy, Request};
use super::metrics::Metrics;
use crate::error::{Error, Result};

/// Serving configuration (see `config::ServerConfig` for the file side).
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    pub policy: BatchPolicy,
    pub queue_depth: usize,
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), queue_depth: 1024, workers: 2 }
    }
}

/// Cheap-to-clone handle for submitting inference requests.
#[derive(Clone)]
pub struct InferenceService {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
}

impl InferenceService {
    /// Spin up the batcher + worker pool over `backend`.
    pub fn start(backend: Arc<dyn InferBackend>, opts: ServeOptions) -> Self {
        Self::start_with_metrics(backend, opts, Arc::new(Metrics::new()))
    }

    /// Like [`InferenceService::start`] but recording into an externally
    /// owned [`Metrics`] — the model registry passes per-model metrics
    /// from its [`super::metrics::MetricsHub`] so reports survive
    /// hot-reload swaps.
    pub fn start_with_metrics(
        backend: Arc<dyn InferBackend>,
        opts: ServeOptions,
        metrics: Arc<Metrics>,
    ) -> Self {
        let (req_tx, req_rx) = sync_channel::<Request>(opts.queue_depth);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(opts.workers.max(1) * 2);
        std::thread::Builder::new()
            .name("kan-edge-batcher".into())
            .spawn(move || run_batcher(req_rx, batch_tx, opts.policy))
            .expect("spawn batcher");

        let shared_rx = Arc::new(Mutex::new(batch_rx));
        for i in 0..opts.workers.max(1) {
            let rx = shared_rx.clone();
            let be = backend.clone();
            let m = metrics.clone();
            std::thread::Builder::new()
                .name(format!("kan-edge-worker-{i}"))
                .spawn(move || worker_loop(rx, be, m))
                .expect("spawn worker");
        }
        Self { tx: req_tx, metrics }
    }

    /// Submit one feature vector and wait for the logits.
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        let (tx, rx) = sync_channel(1);
        let req = Request { features, enqueued: Instant::now(), respond: tx };
        if let Err(r) = try_admit(&self.tx, req) {
            self.metrics.record_rejection();
            reject(r);
            return Err(Error::Serving("queue full: admission rejected".into()));
        }
        rx.recv()
            .map_err(|_| Error::Serving("service shut down".into()))?
    }
}

/// Request routing surface the TCP layer serves: either a single
/// [`InferenceService`] or a multi-model
/// [`crate::registry::ModelRegistry`].
///
/// `dispatch` resolves the optional model spec (`None` = default model,
/// `Some("name")` / `Some("name@version")` otherwise), runs inference,
/// and returns the resolved model id alongside the logits so clients can
/// observe which version served them (hot-reload visibility).
pub trait Dispatch: Send + Sync {
    fn dispatch(&self, model: Option<&str>, features: Vec<f32>) -> Result<(String, Vec<f32>)>;
}

impl Dispatch for InferenceService {
    fn dispatch(&self, model: Option<&str>, features: Vec<f32>) -> Result<(String, Vec<f32>)> {
        match model {
            Some(m) => Err(Error::Serving(format!(
                "this endpoint serves a single model; cannot route to '{m}' \
                 (serve with a registry for multi-model routing)"
            ))),
            None => Ok(("default".to_string(), self.infer(features)?)),
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Batch>>>,
    be: Arc<dyn InferBackend>,
    m: Arc<Metrics>,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => break,
            }
        };
        m.record_batch(batch.len());
        let queue_wait = batch.max_queue_wait();
        let rows: Vec<Vec<f32>> =
            batch.requests.iter().map(|r| r.features.clone()).collect();
        match be.infer_batch(&rows) {
            Ok(outputs) => {
                for (req, out) in batch.requests.into_iter().zip(outputs) {
                    let latency = req.enqueued.elapsed();
                    m.record_request(latency, queue_wait);
                    let _ = req.respond.try_send(Ok(out));
                }
            }
            Err(e) => {
                m.record_error();
                let msg = e.to_string();
                for req in batch.requests {
                    let _ = req.respond.try_send(Err(Error::Serving(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Backend that doubles its input.
    struct Doubler;

    impl InferBackend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn output_dim(&self) -> usize {
            1
        }

        fn infer_batch(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(rows.iter().map(|r| vec![r[0] * 2.0]).collect())
        }
    }

    struct Exploder;

    impl InferBackend for Exploder {
        fn name(&self) -> &str {
            "exploder"
        }

        fn output_dim(&self) -> usize {
            1
        }

        fn infer_batch(&self, _rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Err(Error::Serving("boom".into()))
        }
    }

    #[test]
    fn end_to_end_inference() {
        let svc = InferenceService::start(Arc::new(Doubler), ServeOptions::default());
        let out = svc.infer(vec![21.0]).unwrap();
        assert_eq!(out, vec![42.0]);
        assert_eq!(svc.metrics.report().requests, 1);
    }

    #[test]
    fn concurrent_requests_are_batched() {
        let opts = ServeOptions {
            policy: BatchPolicy { max_batch: 16, deadline: Duration::from_millis(5) },
            ..Default::default()
        };
        let svc = InferenceService::start(Arc::new(Doubler), opts);
        let mut handles = Vec::new();
        for i in 0..64 {
            let s = svc.clone();
            handles.push(std::thread::spawn(move || s.infer(vec![i as f32])));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap().unwrap();
            assert_eq!(out[0], 2.0 * i as f32);
        }
        let report = svc.metrics.report();
        assert_eq!(report.requests, 64);
        assert!(report.mean_batch > 1.0, "no batching happened");
    }

    #[test]
    fn backend_errors_propagate() {
        let svc = InferenceService::start(Arc::new(Exploder), ServeOptions::default());
        let err = svc.infer(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert_eq!(svc.metrics.report().errors, 1);
    }
}
