//! Fair admission scheduling in front of the dynamic batcher.
//!
//! The seed design admitted requests through one bounded FIFO channel, so
//! a large `infer_batch` burst could hold the queue at capacity while it
//! drained and every concurrent single-row client saw `overloaded` for
//! that whole window (head-of-line starvation across clients). The
//! [`Scheduler`] replaces that channel with per-client queues and two
//! admission policies:
//!
//! * **`fifo`** — one global bounded queue, byte-for-byte the seed
//!   behavior: admission fails only when the whole queue is full, and
//!   the batcher drains in arrival order.
//! * **`drr`** — deficit-round-robin: each submitting client (a TCP
//!   connection, or one direct API call) owns a private queue bounded by
//!   [`SchedulerOptions::client_quota`]; the batcher drains the active
//!   clients in a round-robin ring, taking at most
//!   [`SchedulerOptions::fairness_window`] rows from one client before
//!   moving to the next. A 64-row batch therefore occupies at most
//!   `client_quota` slots (the rest of the burst backpressures its own
//!   submitter) and its rows *interleave* with other clients' singletons
//!   instead of fencing them out.
//!
//! Every row is the same size here, so the classic DRR deficit counter
//! degenerates to a per-round row budget — `fairness_window` is that
//! quantum.
//!
//! Rejections carry a `retry_after_ms` hint derived from an EWMA of the
//! observed drain rate (time between batcher pops), so clients can back
//! off for roughly one queue-drain instead of hammering the endpoint.
//! The hint is best-effort: it assumes the recent drain rate holds.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::batcher::Request;
use crate::util::sync::{CondvarExt, LockExt};

/// Identity of a submitting client for fairness accounting. TCP
/// connections hold one for their lifetime; direct API callers get a
/// fresh one per call (each call is then its own fairness class).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClientId(u64);

impl ClientId {
    /// A process-unique id. Never reused, so a finished client's quota
    /// accounting can never leak onto a new one.
    pub fn fresh() -> ClientId {
        static NEXT: AtomicU64 = AtomicU64::new(1);
        ClientId(NEXT.fetch_add(1, Ordering::Relaxed))
    }

    pub fn raw(self) -> u64 {
        self.0
    }
}

/// Admission policy selector (`scheduler.policy` in config).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Single global FIFO queue — the seed behavior.
    Fifo,
    /// Per-client queues drained deficit-round-robin.
    Drr,
}

/// Scheduler knobs (file side: the `[scheduler]` config section).
#[derive(Debug, Clone, Copy)]
pub struct SchedulerOptions {
    pub mode: SchedMode,
    /// Max in-queue rows per client before admission rejects (`drr`).
    pub client_quota: usize,
    /// Rows drained from one client before rotating to the next (`drr`
    /// quantum).
    pub fairness_window: usize,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        Self { mode: SchedMode::Fifo, client_quota: 64, fairness_window: 8 }
    }
}

/// Why an admission was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Total queued rows reached the scheduler capacity (`queue_depth`).
    QueueFull,
    /// This client's in-queue rows reached its quota (`drr` only).
    ClientQuota { queued: usize, quota: usize },
}

/// A rejected admission: hands the request back so the caller can answer
/// its response channel, with a drain-rate-based retry hint.
pub struct Rejection {
    pub req: Request,
    pub reason: RejectReason,
    pub retry_after_ms: u64,
}

/// Outcome of a non-blocking admission attempt.
pub enum Submit {
    Admitted,
    Rejected(Rejection),
    /// The service shut down; the request is handed back.
    Closed(Request),
}

/// Outcome of a deadline-bounded dequeue (the batcher side).
pub enum Recv {
    Req(Request),
    Timeout,
    /// Closed *and* drained — nothing will ever arrive again.
    Closed,
}

/// Point-in-time scheduler occupancy, exported as gauges in
/// [`super::metrics::MetricsReport`] (the only queue visibility before
/// this was the indirect `retry_after_ms` drain-rate hint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueGauges {
    /// Rows queued across all clients.
    pub depth: usize,
    /// Clients with at least one queued row. Always 0 under `fifo`,
    /// which keeps no per-client accounting.
    pub clients: usize,
    /// Largest single-client backlog (`drr` only; 0 under `fifo`).
    pub max_client_backlog: usize,
}

#[derive(Default)]
struct Inner {
    /// `fifo` storage: one global arrival-order queue.
    fifo: VecDeque<Request>,
    /// `drr` storage: per-client queues. Invariant: a client key exists
    /// iff its queue is non-empty, and then it is in `ring` exactly once.
    queues: BTreeMap<u64, VecDeque<Request>>,
    /// Round-robin ring of clients with queued rows; front is current.
    ring: VecDeque<u64>,
    /// Rows the front client may still dequeue this round.
    window_left: usize,
    total: usize,
    closed: bool,
    /// EWMA of microseconds between consecutive pops (drain rate).
    ewma_pop_us: f64,
    last_pop: Option<Instant>,
}

/// Bounded, policy-driven admission queue between submitters and the
/// batcher (see module docs).
pub struct Scheduler {
    opts: SchedulerOptions,
    capacity: usize,
    inner: Mutex<Inner>,
    /// Signalled when a request is queued or the scheduler closes.
    readable: Condvar,
    /// Signalled when a slot frees (pop) or the scheduler closes.
    writable: Condvar,
}

impl Scheduler {
    pub fn new(capacity: usize, opts: SchedulerOptions) -> Scheduler {
        let opts = SchedulerOptions {
            mode: opts.mode,
            client_quota: opts.client_quota.max(1),
            fairness_window: opts.fairness_window.max(1),
        };
        Scheduler {
            opts,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner::default()),
            readable: Condvar::new(),
            writable: Condvar::new(),
        }
    }

    pub fn options(&self) -> SchedulerOptions {
        self.opts
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rows currently queued across all clients.
    pub fn queued(&self) -> usize {
        self.inner.lock_recover().total
    }

    /// Point-in-time queue gauges for the metrics plane (one lock
    /// acquisition; never taken on the admission or drain paths).
    pub fn gauges(&self) -> QueueGauges {
        let g = self.inner.lock_recover();
        match self.opts.mode {
            // fifo keeps no per-client accounting — one shared queue
            SchedMode::Fifo => QueueGauges {
                depth: g.total,
                clients: 0,
                max_client_backlog: 0,
            },
            SchedMode::Drr => QueueGauges {
                depth: g.total,
                clients: g.queues.len(),
                max_client_backlog: g.queues.values().map(VecDeque::len).max().unwrap_or(0),
            },
        }
    }

    /// Non-blocking admission: reject over capacity, and in `drr` mode
    /// over the per-client quota.
    pub fn try_submit(&self, client: ClientId, req: Request) -> Submit {
        let mut g = self.inner.lock_recover();
        if g.closed {
            return Submit::Closed(req);
        }
        if g.total >= self.capacity {
            let retry_after_ms = retry_hint(&g, g.total);
            return Submit::Rejected(Rejection {
                req,
                reason: RejectReason::QueueFull,
                retry_after_ms,
            });
        }
        if self.opts.mode == SchedMode::Drr {
            let queued = g.queues.get(&client.0).map_or(0, VecDeque::len);
            if queued >= self.opts.client_quota {
                // under round robin this client's rows drain only every
                // ~Nth pop (N = active clients), so scale the global
                // drain estimate by the ring size or the hint would be
                // ~N× too optimistic
                let active = g.ring.len().max(1);
                let retry_after_ms = retry_hint(&g, queued * active);
                return Submit::Rejected(Rejection {
                    req,
                    reason: RejectReason::ClientQuota {
                        queued,
                        quota: self.opts.client_quota,
                    },
                    retry_after_ms,
                });
            }
        }
        self.push_locked(&mut g, client, req);
        self.readable.notify_one();
        Submit::Admitted
    }

    /// Blocking admission: wait for capacity (and quota, in `drr`) instead
    /// of rejecting — the backpressure path for the tail of an admitted
    /// batch. Returns the request if the scheduler closed while waiting.
    pub fn submit_blocking(&self, client: ClientId, req: Request) -> Result<(), Request> {
        let mut g = self.inner.lock_recover();
        loop {
            if g.closed {
                return Err(req);
            }
            let over_capacity = g.total >= self.capacity;
            let over_quota = self.opts.mode == SchedMode::Drr
                && g.queues.get(&client.0).map_or(0, VecDeque::len)
                    >= self.opts.client_quota;
            if !over_capacity && !over_quota {
                self.push_locked(&mut g, client, req);
                self.readable.notify_one();
                return Ok(());
            }
            g = self.writable.wait_recover(g);
        }
    }

    /// Dequeue the next request per policy, blocking until one arrives.
    /// `None` once the scheduler is closed *and* drained (every queued
    /// request is still delivered first, so shutdown flushes).
    pub fn recv(&self) -> Option<Request> {
        let mut g = self.inner.lock_recover();
        loop {
            if let Some(req) = self.pop_locked(&mut g) {
                return Some(req);
            }
            if g.closed {
                return None;
            }
            g = self.readable.wait_recover(g);
        }
    }

    /// [`Scheduler::recv_deadline`] with a relative timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Recv {
        self.recv_deadline(Instant::now() + timeout)
    }

    /// Like [`Scheduler::recv`] with a deadline (the batcher's
    /// batch-close timer).
    pub fn recv_deadline(&self, deadline: Instant) -> Recv {
        let mut g = self.inner.lock_recover();
        loop {
            if let Some(req) = self.pop_locked(&mut g) {
                return Recv::Req(req);
            }
            if g.closed {
                return Recv::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Recv::Timeout;
            }
            let (guard, timeout) =
                self.readable.wait_timeout_recover(g, deadline - now);
            g = guard;
            if timeout.timed_out() {
                // one last look: a submit may have raced the wakeup
                if let Some(req) = self.pop_locked(&mut g) {
                    return Recv::Req(req);
                }
                if g.closed {
                    return Recv::Closed;
                }
                return Recv::Timeout;
            }
        }
    }

    /// Close the scheduler: all waiting submitters fail, the batcher
    /// drains what is queued and then sees end-of-stream.
    pub fn close(&self) {
        let mut g = self.inner.lock_recover();
        g.closed = true;
        drop(g);
        self.readable.notify_all();
        self.writable.notify_all();
    }

    fn push_locked(&self, g: &mut Inner, client: ClientId, req: Request) {
        match self.opts.mode {
            SchedMode::Fifo => g.fifo.push_back(req),
            SchedMode::Drr => {
                let q = g.queues.entry(client.0).or_default();
                if q.is_empty() {
                    // (re)activate: join the ring at the back; a fresh
                    // ring front starts with a full window
                    if g.ring.is_empty() {
                        g.window_left = self.opts.fairness_window;
                    }
                    g.ring.push_back(client.0);
                }
                q.push_back(req);
            }
        }
        g.total += 1;
    }

    fn pop_locked(&self, g: &mut Inner) -> Option<Request> {
        let req = match self.opts.mode {
            SchedMode::Fifo => g.fifo.pop_front()?,
            SchedMode::Drr => {
                let front = *g.ring.front()?;
                // lint: allow(panic, "DRR structural invariant: every ring entry has a queue")
                let q = g.queues.get_mut(&front).expect("ring client has a queue");
                // lint: allow(panic, "DRR structural invariant: empty queues are removed from the ring")
                let req = q.pop_front().expect("ring queues are non-empty");
                if q.is_empty() {
                    g.queues.remove(&front);
                    g.ring.pop_front();
                    g.window_left = self.opts.fairness_window;
                } else {
                    g.window_left = g.window_left.saturating_sub(1);
                    if g.window_left == 0 {
                        // quantum spent: rotate to the next client
                        // lint: allow(panic, "DRR structural invariant: ring non-empty while its queue is")
                        let id = g.ring.pop_front().expect("ring non-empty");
                        g.ring.push_back(id);
                        g.window_left = self.opts.fairness_window;
                    }
                }
                req
            }
        };
        g.total -= 1;
        let now = Instant::now();
        if let Some(last) = g.last_pop {
            let dt_us = now.duration_since(last).as_secs_f64() * 1e6;
            // idle gaps (> 1 s) are not drain time; don't poison the EWMA
            if dt_us < 1e6 {
                g.ewma_pop_us = if g.ewma_pop_us > 0.0 {
                    0.9 * g.ewma_pop_us + 0.1 * dt_us
                } else {
                    dt_us
                };
            }
        }
        g.last_pop = Some(now);
        // a freed slot may satisfy any waiting client: wake them all
        self.writable.notify_all();
        Some(req)
    }
}

/// Best-effort "when might a slot free" estimate: `rows_ahead` pops at
/// the recent drain rate, clamped to a sane wire range. 1 ms/row when no
/// drain has been observed yet.
fn retry_hint(g: &Inner, rows_ahead: usize) -> u64 {
    let per_row_us = if g.ewma_pop_us > 0.0 { g.ewma_pop_us } else { 1000.0 };
    let ms = (rows_ahead as f64 * per_row_us / 1000.0).ceil() as u64;
    ms.clamp(1, 10_000)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Result;
    use std::sync::mpsc::{sync_channel, Receiver};

    fn opts(mode: SchedMode, quota: usize, window: usize) -> SchedulerOptions {
        SchedulerOptions { mode, client_quota: quota, fairness_window: window }
    }

    fn mk_request(v: f32) -> (Request, Receiver<Result<crate::coordinator::backend::RowOutput>>)
    {
        let (tx, rx) = sync_channel(1);
        (
            Request {
                features: vec![v],
                opts: crate::coordinator::backend::ExecOptions::default(),
                enqueued: Instant::now(),
                respond: tx,
                trace: None,
            },
            rx,
        )
    }

    fn admit(s: &Scheduler, client: ClientId, v: f32) {
        let (req, _rx) = mk_request(v);
        match s.try_submit(client, req) {
            Submit::Admitted => {}
            _ => panic!("expected admission for {v}"),
        }
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let s = Scheduler::new(16, opts(SchedMode::Fifo, 4, 2));
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        admit(&s, a, 1.0);
        admit(&s, b, 2.0);
        admit(&s, a, 3.0);
        for want in [1.0, 2.0, 3.0] {
            let req = s.recv().unwrap();
            assert_eq!(req.features[0], want);
        }
    }

    #[test]
    fn drr_interleaves_clients_by_window() {
        let s = Scheduler::new(64, opts(SchedMode::Drr, 64, 1));
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        for i in 0..6 {
            admit(&s, a, 10.0 + i as f32);
        }
        for i in 0..2 {
            admit(&s, b, 20.0 + i as f32);
        }
        let order: Vec<f32> = (0..8).map(|_| s.recv().unwrap().features[0]).collect();
        // window 1: strict alternation until b drains, then a alone
        assert_eq!(order, vec![10.0, 20.0, 11.0, 21.0, 12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn drr_window_takes_runs_before_rotating() {
        let s = Scheduler::new(64, opts(SchedMode::Drr, 64, 2));
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        for i in 0..4 {
            admit(&s, a, 10.0 + i as f32);
        }
        for i in 0..4 {
            admit(&s, b, 20.0 + i as f32);
        }
        let order: Vec<f32> = (0..8).map(|_| s.recv().unwrap().features[0]).collect();
        assert_eq!(
            order,
            vec![10.0, 11.0, 20.0, 21.0, 12.0, 13.0, 22.0, 23.0]
        );
    }

    #[test]
    fn drr_rejects_over_client_quota_but_admits_other_clients() {
        let s = Scheduler::new(16, opts(SchedMode::Drr, 2, 2));
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        admit(&s, a, 1.0);
        admit(&s, a, 2.0);
        let (req, _rx) = mk_request(3.0);
        match s.try_submit(a, req) {
            Submit::Rejected(r) => {
                assert_eq!(
                    r.reason,
                    RejectReason::ClientQuota { queued: 2, quota: 2 }
                );
                assert!(r.retry_after_ms >= 1);
            }
            _ => panic!("expected quota rejection"),
        }
        // an unrelated client is unaffected
        admit(&s, b, 4.0);
        assert_eq!(s.queued(), 3);
    }

    #[test]
    fn capacity_bound_rejects_in_both_modes() {
        for mode in [SchedMode::Fifo, SchedMode::Drr] {
            let s = Scheduler::new(2, opts(mode, 64, 2));
            let a = ClientId::fresh();
            admit(&s, a, 1.0);
            admit(&s, a, 2.0);
            let (req, _rx) = mk_request(3.0);
            match s.try_submit(ClientId::fresh(), req) {
                Submit::Rejected(r) => {
                    assert_eq!(r.reason, RejectReason::QueueFull)
                }
                _ => panic!("expected capacity rejection ({mode:?})"),
            }
        }
    }

    #[test]
    fn blocking_submit_waits_for_a_pop() {
        let s = std::sync::Arc::new(Scheduler::new(1, opts(SchedMode::Fifo, 1, 1)));
        let a = ClientId::fresh();
        admit(&s, a, 1.0);
        let s2 = s.clone();
        let handle = std::thread::spawn(move || {
            let (req, _rx) = mk_request(2.0);
            s2.submit_blocking(ClientId::fresh(), req).is_ok()
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(s.queued(), 1, "blocked submit must not enqueue early");
        assert_eq!(s.recv().unwrap().features[0], 1.0);
        assert!(handle.join().unwrap());
        assert_eq!(s.recv().unwrap().features[0], 2.0);
    }

    #[test]
    fn close_drains_then_ends() {
        let s = Scheduler::new(8, opts(SchedMode::Drr, 4, 2));
        let a = ClientId::fresh();
        admit(&s, a, 1.0);
        admit(&s, a, 2.0);
        s.close();
        // closed to new work...
        let (req, _rx) = mk_request(9.0);
        assert!(matches!(s.try_submit(a, req), Submit::Closed(_)));
        // ...but the queued rows still flush, then end-of-stream
        assert_eq!(s.recv().unwrap().features[0], 1.0);
        assert_eq!(s.recv().unwrap().features[0], 2.0);
        assert!(s.recv().is_none());
        assert!(matches!(
            s.recv_timeout(Duration::from_millis(1)),
            Recv::Closed
        ));
    }

    #[test]
    fn recv_deadline_times_out_when_idle() {
        let s = Scheduler::new(8, SchedulerOptions::default());
        let t0 = Instant::now();
        assert!(matches!(
            s.recv_timeout(Duration::from_millis(15)),
            Recv::Timeout
        ));
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn gauges_snapshot_depth_and_backlogs() {
        let s = Scheduler::new(16, opts(SchedMode::Drr, 8, 2));
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        admit(&s, a, 1.0);
        admit(&s, a, 2.0);
        admit(&s, a, 3.0);
        admit(&s, b, 4.0);
        let g = s.gauges();
        assert_eq!(g.depth, 4);
        assert_eq!(g.clients, 2);
        assert_eq!(g.max_client_backlog, 3);
        let _ = s.recv().unwrap();
        assert_eq!(s.gauges().depth, 3);
        // fifo keeps no per-client accounting: depth only
        let f = Scheduler::new(16, opts(SchedMode::Fifo, 8, 2));
        admit(&f, ClientId::fresh(), 1.0);
        let g = f.gauges();
        assert_eq!((g.depth, g.clients, g.max_client_backlog), (1, 0, 0));
    }

    #[test]
    fn fresh_client_ids_are_unique() {
        let a = ClientId::fresh();
        let b = ClientId::fresh();
        assert_ne!(a, b);
        assert!(b.raw() > a.raw());
    }
}
