//! TCP transport + connection lifecycle for the serving endpoint.
//!
//! Two wire protocols share one port, auto-detected from the first
//! bytes of each connection (see `docs/PROTOCOL.md` for the full
//! specification):
//!
//! * **v1 — JSON lines** (legacy): one `{"features": [...]}` request
//!   per line, one reply per line, strictly in order. Kept
//!   byte-compatible so pre-v2 client scripts work unchanged.
//! * **v2 — framed** : the connection opens with the 4-byte
//!   [`protocol::MAGIC`] preamble; after it every request/response is a
//!   length-prefixed JSON frame carrying a client-chosen `id`.
//!   Inference dispatches concurrently (up to
//!   [`TcpLimits::max_in_flight`] per connection) and responses are
//!   written as they complete — out of order — by a per-connection
//!   writer decoupled from the reader. Control verbs (`hello`, `ping`,
//!   `list_models`, `model_info`, `metrics`, `health`) are answered
//!   inline.
//!
//! Both protocols bound request size by [`TcpLimits::max_request_bytes`]
//! (`server.max_request_bytes` in config): an oversized line or frame
//! gets a structured `too_large` error and only that connection is
//! dropped. Parsing and dispatch live in [`super::protocol`]; this
//! module is transport only. One thread per connection plus one per
//! in-flight v2 dispatch (edge request rates make this the simplest
//! correct design); the shared [`Dispatch`] target batches across
//! connections.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::backend::{argmax_f32, BackendKind, ExecOptions};
use super::metrics::WireMetrics;
use super::protocol::{
    self, error_response, read_frame, write_frame, ErrorCode, FrameRead, Request,
    Response, RolloutVerb, WireRow,
};
use super::scheduler::ClientId;
use super::server::{Dispatch, RouteSpec};
use crate::util::sync::{CondvarExt, LockExt};
use crate::error::{Error, Result};
use crate::obs::trace::{Stage, TraceHub};
use crate::util::json::{obj, Value};

/// Per-connection transport limits (file side: the `[server]` config
/// section, translated by [`super::router::tcp_limits`]).
#[derive(Debug, Clone, Copy)]
pub struct TcpLimits {
    /// Max bytes in one v1 line or one v2 frame payload.
    pub max_request_bytes: usize,
    /// Max concurrently dispatched v2 requests per connection; the
    /// reader blocks (backpressure) once reached.
    pub max_in_flight: usize,
}

impl Default for TcpLimits {
    fn default() -> Self {
        Self { max_request_bytes: 1 << 20, max_in_flight: 64 }
    }
}

/// Spans returned by the v2 `trace` verb when the request names no
/// `limit` (the ring may hold more; see `observability.trace_ring`).
const DEFAULT_TRACE_SPANS: usize = 32;

/// Control-plane identity of a serving node, reported by the v2
/// `hello` and `health` verbs so a cluster router can tell replicas
/// apart (and detect restarts: `uptime_s` resets while `node_id`
/// stays stable when the CLI persists it next to the artifacts).
#[derive(Debug, Clone)]
pub struct NodeIdentity {
    /// Stable name of this node (config/CLI-chosen or generated once
    /// and persisted by the `serve` command).
    pub node_id: String,
    /// Process start, anchoring the `uptime_s` field.
    pub started: std::time::Instant,
}

impl NodeIdentity {
    pub fn new(node_id: impl Into<String>) -> Self {
        Self { node_id: node_id.into(), started: std::time::Instant::now() }
    }
}

/// A running TCP server; `shutdown` stops the accept loop promptly and
/// joins it (open connections finish on their own threads).
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    /// Transport counters (v1/v2 split, connections, in-flight HWM);
    /// also served by the v2 `metrics` verb.
    pub wire: Arc<WireMetrics>,
    /// Request-trace sampler + span ring serving the v2 `trace` verb
    /// (a disabled hub when the server was spawned without one).
    pub trace: Arc<TraceHub>,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `target`
    /// with default [`TcpLimits`].
    pub fn spawn(addr: &str, target: Arc<dyn Dispatch>) -> Result<TcpServer> {
        Self::spawn_with_limits(addr, target, TcpLimits::default())
    }

    /// Like [`TcpServer::spawn`] with explicit transport limits (request
    /// tracing disabled).
    pub fn spawn_with_limits(
        addr: &str,
        target: Arc<dyn Dispatch>,
        limits: TcpLimits,
    ) -> Result<TcpServer> {
        Self::spawn_with_obs(addr, target, limits, Arc::new(TraceHub::disabled()))
    }

    /// Like [`TcpServer::spawn_with_limits`] with a request-trace hub
    /// (see [`super::router::trace_hub`] for the config-driven one).
    pub fn spawn_with_obs(
        addr: &str,
        target: Arc<dyn Dispatch>,
        limits: TcpLimits,
        trace: Arc<TraceHub>,
    ) -> Result<TcpServer> {
        Self::spawn_with_identity(addr, target, limits, trace, None)
    }

    /// Like [`TcpServer::spawn_with_obs`] with a control-plane
    /// [`NodeIdentity`] reported by `hello`/`health` (`None` keeps the
    /// identity fields off the wire — single-node endpoints).
    pub fn spawn_with_identity(
        addr: &str,
        target: Arc<dyn Dispatch>,
        limits: TcpLimits,
        trace: Arc<TraceHub>,
        identity: Option<NodeIdentity>,
    ) -> Result<TcpServer> {
        let identity = identity.map(Arc::new);
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let wire = Arc::new(WireMetrics::new());
        let wire2 = wire.clone();
        let trace2 = trace.clone();
        let handle = std::thread::Builder::new()
            .name("kan-edge-tcp".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    // checked on every wakeup: `shutdown` sets the flag and
                    // then self-connects, so this observes it immediately
                    // instead of waiting for the next real client
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let target = target.clone();
                            let wire = wire2.clone();
                            let trace = trace2.clone();
                            let identity = identity.clone();
                            std::thread::spawn(move || {
                                handle_conn(s, target, limits, wire, trace, identity)
                            });
                        }
                        Err(e) => crate::obs::log::warn(
                            "tcp",
                            &format!("accept error: {e}"),
                        ),
                    }
                }
                // listener drops here: the port is released by the time
                // `shutdown` returns
            })
            .map_err(|e| crate::error::Error::Serving(format!("spawn tcp: {e}")))?;
        Ok(TcpServer {
            addr: local,
            wire,
            trace,
            stop,
            accept_thread: Mutex::new(Some(handle)),
        })
    }

    /// Stop accepting and wait for the accept loop to exit. The flag is
    /// set *before* the wake-up self-connection so the loop cannot accept
    /// a real client in between; without the self-connect the blocking
    /// `incoming()` would only notice the flag on the next organic
    /// connection, leaving tests (and process shutdown) hanging.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let woke = TcpStream::connect(self.addr).is_ok();
        if woke {
            // the loop is guaranteed to observe the flag now, so joining
            // cannot hang; take the handle in its own statement so the
            // accept_thread lock is released before the (blocking) join
            let taken = self.accept_thread.lock_recover().take();
            if let Some(handle) = taken {
                let _ = handle.join();
            }
        }
        // if the wake-up connect failed (e.g. an unroutable bind address),
        // leave the thread to exit on the next organic connection instead
        // of blocking the caller forever
    }
}

/// Serve one connection until EOF (protocol auto-detected). Each
/// connection gets one [`ClientId`] for its lifetime: under the `drr`
/// admission policy that is the fairness unit, so one connection's
/// burst cannot starve another connection's singletons.
pub fn handle_conn(
    stream: TcpStream,
    target: Arc<dyn Dispatch>,
    limits: TcpLimits,
    wire: Arc<WireMetrics>,
    trace: Arc<TraceHub>,
    identity: Option<Arc<NodeIdentity>>,
) {
    wire.connection_opened();
    serve_conn(stream, target, limits, &wire, trace, identity);
    wire.connection_closed();
}

fn serve_conn(
    mut stream: TcpStream,
    target: Arc<dyn Dispatch>,
    limits: TcpLimits,
    wire: &Arc<WireMetrics>,
    trace: Arc<TraceHub>,
    identity: Option<Arc<NodeIdentity>>,
) {
    let client = ClientId::fresh();
    // protocol sniff: a v2 connection opens with the 4-byte magic; the
    // first byte of a v1 JSON line can never be 'K'
    let mut first = [0u8; 1];
    let n = match stream.read(&mut first) {
        Ok(n) => n,
        Err(_) => return,
    };
    if n == 0 {
        return;
    }
    // lint: allow(index, "first is [u8; 1] just filled; MAGIC is a non-empty const")
    if first[0] == protocol::MAGIC[0] {
        // read the candidate magic byte-by-byte and bail to v1 on the
        // first divergent byte: a short garbage line like "K\n" must get
        // its structured v1 error reply, not block in a read_exact(3)
        // that waits for bytes the client will never send
        // lint: allow(index, "first is [u8; 1] just filled")
        let mut prefix = vec![first[0]];
        loop {
            let mut b = [0u8; 1];
            match stream.read(&mut b) {
                Ok(0) => {
                    // EOF mid-prefix: let v1 report the partial line
                    serve_v1(prefix, stream, client, target, limits, wire);
                    return;
                }
                Ok(_) => {}
                Err(_) => return,
            }
            // lint: allow(index, "b is [u8; 1] just filled")
            prefix.push(b[0]);
            // lint: allow(index, "prefix.len() <= MAGIC.len() is the loop exit condition")
            if b[0] != protocol::MAGIC[prefix.len() - 1] {
                serve_v1(prefix, stream, client, target, limits, wire);
                return;
            }
            if prefix.len() == protocol::MAGIC.len() {
                serve_v2(stream, client, target, limits, wire, trace, identity);
                return;
            }
        }
    } else {
        // lint: allow(index, "first is [u8; 1] just filled")
        serve_v1(vec![first[0]], stream, client, target, limits, wire);
    }
}

// ---- v1: JSON lines -------------------------------------------------------

enum LineRead {
    Line(String),
    Eof,
    TooLong,
}

/// Read one newline-terminated line into/through `pending`, bounded by
/// `max` bytes. `pending` may already hold sniffed bytes and keeps any
/// bytes read past the newline for the next call. A final line without
/// a trailing newline is still returned (matching `BufRead::lines`).
fn read_line_bounded(
    reader: &mut impl BufRead,
    pending: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    // bytes of `pending` already scanned for '\n' in this call: each
    // fill_buf round only searches the newly appended tail, keeping the
    // per-line cost linear even when a large line trickles in tiny
    // segments
    let mut scanned = 0;
    loop {
        // lint: allow(index, "scanned only advances to pending.len() below")
        if let Some(rel) = pending[scanned..].iter().position(|&b| b == b'\n') {
            let pos = scanned + rel;
            if pos > max {
                return Ok(LineRead::TooLong);
            }
            let rest = pending.split_off(pos + 1);
            let mut line = std::mem::replace(pending, rest);
            line.pop(); // the '\n'
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return Ok(LineRead::Line(String::from_utf8_lossy(&line).into_owned()));
        }
        scanned = pending.len();
        if pending.len() > max {
            return Ok(LineRead::TooLong);
        }
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            if pending.is_empty() {
                return Ok(LineRead::Eof);
            }
            let line = std::mem::take(pending);
            return Ok(LineRead::Line(String::from_utf8_lossy(&line).into_owned()));
        }
        let n = chunk.len();
        pending.extend_from_slice(chunk);
        reader.consume(n);
    }
}

fn serve_v1(
    prefix: Vec<u8>,
    stream: TcpStream,
    client: ClientId,
    target: Arc<dyn Dispatch>,
    limits: TcpLimits,
    wire: &WireMetrics,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut pending = prefix;
    loop {
        let line =
            match read_line_bounded(&mut reader, &mut pending, limits.max_request_bytes) {
                Ok(LineRead::Line(l)) => l,
                Ok(LineRead::Eof) => break,
                Ok(LineRead::TooLong) => {
                    // structured error, then drop only this connection:
                    // the rest of the oversized line cannot be resynced
                    wire.record_oversized();
                    let v = obj(vec![
                        (
                            "error",
                            Value::Str(format!(
                                "request too large: line exceeds {} bytes",
                                limits.max_request_bytes
                            )),
                        ),
                        ("code", Value::Str(ErrorCode::TooLarge.as_str().into())),
                    ]);
                    let _ = write_line(&mut writer, &v);
                    // generous byte budget: leaving the line's remainder
                    // unread turns the close into an RST that can destroy
                    // the reply just written; the wall-clock deadline in
                    // drain_before_close bounds a firehose client instead
                    drain_before_close(&writer, 64 << 20);
                    break;
                }
                Err(_) => break,
            };
        if line.trim().is_empty() {
            continue;
        }
        wire.record_v1_request();
        let reply = respond(&line, client, target.as_ref());
        if write_line(&mut writer, &reply).is_err() {
            break;
        }
    }
}

fn write_line(w: &mut impl Write, v: &Value) -> std::io::Result<()> {
    let mut text = v.to_string();
    text.push('\n');
    w.write_all(text.as_bytes())
}

fn error_reply(msg: impl Into<String>) -> Value {
    obj(vec![("error", Value::Str(msg.into()))])
}

/// A fresh noise seed for unseeded requests, resolved once at the wire
/// edge so the primary execution and any shadow mirror of the same row
/// share one concrete draw. Unseeded traffic carries no
/// reproducibility contract, but it must still *sample the noise
/// distribution*: a fixed fallback — or one keyed to the client-chosen
/// request id, which restarts at 1 on every connection — would make
/// unseeded ACIM responses (and their shadow comparisons) replay a
/// handful of noise realizations, silently biasing exactly the
/// statistics shadow serving measures.
fn fresh_unseeded_seed() -> u64 {
    use std::sync::atomic::AtomicU64;
    static NEXT: AtomicU64 = AtomicU64::new(1);
    crate::util::rng::mix(0x5EED_C0DE, NEXT.fetch_add(1, Ordering::Relaxed))
}

/// Pure v1 request→response mapping (unit-testable without sockets).
pub fn respond(line: &str, client: ClientId, target: &dyn Dispatch) -> Value {
    let parsed = match Value::parse(line) {
        Ok(v) => v,
        Err(_) => return error_reply("bad request: not valid JSON"),
    };
    // v1 has no per-request execution surface; a request that names one
    // must get a structured refusal, not a silent drop of the option
    // (the caller clearly expected it to take effect)
    for field in ["backend", "seed", "trials"] {
        if parsed.get(field).is_some() {
            return obj(vec![
                (
                    "error",
                    Value::Str(format!(
                        "'{field}' requires protocol v2 (per-request execution \
                         options are not part of the v1 JSON-lines protocol)"
                    )),
                ),
                ("code", Value::Str(ErrorCode::Unsupported.as_str().into())),
            ]);
        }
    }
    let features = match parsed.f32_vec("features") {
        Ok(f) => f,
        Err(_) => {
            return error_reply("bad request: expected {\"features\": [...]}")
        }
    };
    let model = match parsed.get("model") {
        None => None,
        Some(Value::Str(s)) => Some(s.as_str()),
        Some(_) => return error_reply("bad request: 'model' must be a string"),
    };
    // v1 names no seed, so give the request its own draw (see
    // fresh_unseeded_seed); deterministic backends ignore it
    let route = RouteSpec {
        opts: ExecOptions { seed: Some(fresh_unseeded_seed()), trials: 1 },
        ..RouteSpec::to_model(model)
    };
    match target.dispatch(client, &route, features) {
        Ok((id, out)) => {
            let pred = argmax_f32(&out.logits);
            let items: Vec<Value> =
                out.logits.iter().map(|&v| Value::Float(v as f64)).collect();
            obj(vec![
                ("logits", Value::Array(items)),
                ("class", Value::Int(pred as i64)),
                ("model", Value::Str(id)),
            ])
        }
        // structured admission rejection: v1 stays one-line JSON, but the
        // error object gains the machine-readable code + backoff hint
        // (plain seed-era errors keep their exact `{"error": ...}` shape)
        Err(e @ Error::Overloaded { retry_after_ms, .. }) => obj(vec![
            ("error", Value::Str(e.to_string())),
            ("code", Value::Str(ErrorCode::Overloaded.as_str().into())),
            ("retry_after_ms", Value::Int(retry_after_ms as i64)),
        ]),
        Err(e) => error_reply(e.to_string()),
    }
}

/// Best-effort discard of whatever the peer is still sending before an
/// error-close, bounded in bytes and wall time. Closing a socket with
/// unread data queued makes the kernel send RST, which would destroy
/// the structured `too_large` error we just wrote; draining first turns
/// the close into a clean FIN in the common case.
fn drain_before_close(stream: &TcpStream, mut budget: usize) {
    let mut s = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = s.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
    let mut buf = [0u8; 8192];
    while budget > 0 && std::time::Instant::now() < deadline {
        match s.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => budget = budget.saturating_sub(n),
            Err(_) => break, // timeout or socket error: good enough
        }
    }
}

// ---- v2: framed, pipelined ------------------------------------------------

/// Per-connection in-flight dispatch counter with blocking acquisition.
struct InFlight {
    max: usize,
    count: Mutex<usize>,
    cv: Condvar,
}

impl InFlight {
    fn new(max: usize) -> Self {
        Self { max: max.max(1), count: Mutex::new(0), cv: Condvar::new() }
    }

    /// Block until a slot frees, take it, and return the new depth.
    fn acquire(&self) -> usize {
        let mut g = self.count.lock_recover();
        while *g >= self.max {
            g = self.cv.wait_recover(g);
        }
        *g += 1;
        *g
    }

    fn release(&self) {
        let mut g = self.count.lock_recover();
        *g -= 1;
        self.cv.notify_one();
    }
}

/// RAII slot holder: releases on drop, so a panicking dispatch (or a
/// failed thread spawn, which drops the un-run closure) can never leak
/// its in-flight slot and wedge the connection at the cap.
struct InFlightPermit(Arc<InFlight>);

impl Drop for InFlightPermit {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// Inference work units dispatched off the reader thread.
enum Work {
    One { features: Vec<f32> },
    Batch { rows: Vec<Vec<f32>> },
}

/// Resolve the wire-level execution fields into a [`RouteSpec`]: an
/// explicit `seed` passes through verbatim (the fixed-`(row, seed)`
/// reproducibility contract), an absent one resolves to a fresh
/// server-side draw here at the edge — see [`fresh_unseeded_seed`] —
/// so every unseeded request gets its own noise stream regardless of
/// protocol or connection churn.
fn route_for(
    model: Option<String>,
    backend: Option<BackendKind>,
    exec: ExecOptions,
) -> RouteSpec {
    RouteSpec {
        model,
        backend,
        opts: ExecOptions {
            seed: Some(exec.seed.unwrap_or_else(fresh_unseeded_seed)),
            trials: exec.trials,
        },
        trace: None,
    }
}

/// Shared state of one v2 connection.
struct V2Conn {
    /// Fairness identity of this connection for admission scheduling.
    client: ClientId,
    target: Arc<dyn Dispatch>,
    writer: Arc<Mutex<TcpStream>>,
    in_flight: Arc<InFlight>,
    wire: Arc<WireMetrics>,
    trace: Arc<TraceHub>,
    limits: TcpLimits,
    identity: Option<Arc<NodeIdentity>>,
}

fn serve_v2(
    stream: TcpStream,
    client: ClientId,
    target: Arc<dyn Dispatch>,
    limits: TcpLimits,
    wire: &Arc<WireMetrics>,
    trace: Arc<TraceHub>,
    identity: Option<Arc<NodeIdentity>>,
) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let conn = V2Conn {
        client,
        target,
        writer,
        in_flight: Arc::new(InFlight::new(limits.max_in_flight)),
        wire: wire.clone(),
        trace,
        limits,
        identity,
    };
    loop {
        let payload = match read_frame(&mut reader, limits.max_request_bytes) {
            Ok(FrameRead::Frame(p)) => p,
            Ok(FrameRead::Eof) => break,
            Ok(FrameRead::TooLarge(n)) => {
                // the oversized payload was never consumed, so the frame
                // stream cannot be resynced: report and drop the
                // connection (only this one; the server keeps serving)
                conn.wire.record_oversized();
                let _ = conn.send(&Response::Error {
                    id: None,
                    code: ErrorCode::TooLarge,
                    message: format!(
                        "frame of {n} bytes exceeds limit of {} bytes",
                        limits.max_request_bytes
                    ),
                    retry_after_ms: None,
                });
                drain_before_close(reader.get_ref(), n.min(64 << 20));
                break;
            }
            Err(_) => break, // truncated frame or socket error
        };
        let req = match Request::from_bytes(&payload) {
            Ok(r) => r,
            Err(we) => {
                // framing is intact, so only this frame is garbage: send
                // a structured error and keep the connection alive
                conn.wire.record_protocol_error();
                if conn.send(&we.into_response()).is_err() {
                    break;
                }
                continue;
            }
        };
        if !conn.handle(req) {
            break;
        }
    }
    // dispatch threads still in flight hold their own Arc clones of the
    // writer and finish on their own; dropping the reader here is safe
}

/// Serialize one response and write it as a frame under the shared
/// per-connection writer lock — the single encode path for both inline
/// control replies and async dispatch completions.
fn send_response(writer: &Mutex<TcpStream>, resp: &Response) -> std::io::Result<()> {
    let payload = resp.to_value().to_string();
    let mut w = writer.lock_recover();
    // lint: allow(lock-blocking, "per-connection writer lock: serializing frame writes is its purpose")
    write_frame(&mut *w, payload.as_bytes())
}

impl V2Conn {
    fn send(&self, resp: &Response) -> std::io::Result<()> {
        send_response(&self.writer, resp)
    }

    /// The metrics snapshot body: per-model serving reports (with the
    /// per-stage trace rollup folded in), the trace-sampler summary,
    /// and the wire counters. Shared by the `metrics` (JSON) and
    /// `metrics_prom` (Prometheus text) verbs so both expose the same
    /// numbers.
    fn metrics_body(&self) -> Value {
        let models = self
            .target
            .metrics_reports()
            .into_iter()
            .map(|(mid, mut r)| {
                r.stages = self.trace.stage_report(&mid);
                (mid, r.to_value())
            })
            .collect::<Vec<_>>();
        let models_obj = Value::Object(models.into_iter().collect());
        let mut body: std::collections::BTreeMap<String, Value> = vec![
            ("models".to_string(), models_obj),
            ("trace".to_string(), self.trace.summary_value()),
            ("wire".to_string(), self.wire.to_value()),
        ]
        .into_iter()
        .collect();
        // endpoint-specific sections (the cluster router's `cluster` /
        // `nodes` rollups) override same-named standard sections: the
        // overlay's view is the authoritative one for such endpoints
        if let Some(Value::Object(extra)) = self.target.metrics_overlay() {
            for (k, v) in extra {
                body.insert(k, v);
            }
        }
        Value::Object(body)
    }

    /// `(node_id, uptime_s)` fields for `hello`/`health`, both `None`
    /// when the server was spawned without an identity.
    fn identity_fields(&self) -> (Option<String>, Option<u64>) {
        match &self.identity {
            Some(n) => (Some(n.node_id.clone()), Some(n.started.elapsed().as_secs())),
            None => (None, None),
        }
    }

    /// Handle one parsed request; returns `false` when the connection
    /// should close (write failure).
    fn handle(&self, req: Request) -> bool {
        match req {
            Request::Hello { id, .. } => {
                self.wire.record_v2_control();
                let (node_id, uptime_s) = self.identity_fields();
                self.send(&Response::Hello {
                    id,
                    protocol: protocol::PROTOCOL_VERSION,
                    server: concat!("kan-edge/", env!("CARGO_PKG_VERSION")).to_string(),
                    max_frame: self.limits.max_request_bytes,
                    max_in_flight: self.limits.max_in_flight,
                    node_id,
                    uptime_s,
                })
                .is_ok()
            }
            Request::Ping { id } => {
                self.wire.record_v2_control();
                self.send(&Response::Pong { id }).is_ok()
            }
            Request::ListModels { id } => {
                self.wire.record_v2_control();
                self.send(&Response::ModelList {
                    id,
                    models: self.target.model_summaries(),
                })
                .is_ok()
            }
            Request::ModelInfo { id, model } => {
                self.wire.record_v2_control();
                // the exact spec grammar inference routing uses: bare
                // "name" or pinned "name@version"
                let resp = match crate::registry::parse_model_spec(&model) {
                    Err(e) => Response::Error {
                        id: Some(id),
                        code: ErrorCode::BadRequest,
                        message: e.to_string(),
                        retry_after_ms: None,
                    },
                    Ok((name, pinned)) => {
                        let found = self
                            .target
                            .model_summaries()
                            .into_iter()
                            .find(|m| {
                                m.name == name
                                    && pinned.map_or(true, |v| v == m.version)
                            });
                        match found {
                            Some(m) => Response::ModelInfo { id, model: m },
                            None => Response::Error {
                                id: Some(id),
                                code: ErrorCode::NotFound,
                                message: format!("model '{model}' not found"),
                                retry_after_ms: None,
                            },
                        }
                    }
                };
                self.send(&resp).is_ok()
            }
            Request::Metrics { id } => {
                self.wire.record_v2_control();
                let body = self.metrics_body();
                self.send(&Response::Metrics { id, body }).is_ok()
            }
            Request::MetricsProm { id } => {
                self.wire.record_v2_control();
                let text = crate::obs::prom::render(&self.metrics_body());
                self.send(&Response::MetricsProm { id, text }).is_ok()
            }
            Request::Trace { id, limit } => {
                self.wire.record_v2_control();
                let body = self.trace.to_value(limit.unwrap_or(DEFAULT_TRACE_SPANS));
                self.send(&Response::Trace { id, body }).is_ok()
            }
            Request::Health { id } => {
                self.wire.record_v2_control();
                let (node_id, uptime_s) = self.identity_fields();
                self.send(&Response::Health {
                    id,
                    status: "ok".to_string(),
                    models_live: self.target.live_model_count(),
                    node_id,
                    uptime_s,
                })
                .is_ok()
            }
            Request::PullArtifact { id, digest } => {
                self.wire.record_v2_control();
                let resp = match self.target.pull_artifact(&digest) {
                    Ok((meta, data)) => Response::Artifact { id, digest, data, meta },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::PushArtifact { id, model, version, digest, data } => {
                self.wire.record_v2_control();
                let out = self.target.push_artifact(&model, version, &digest, &data);
                let resp = match out {
                    Ok(resolved) => Response::Published { id, model: resolved, digest },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::RolloutStart { id, model, baseline } => {
                self.wire.record_v2_control();
                let resp = match self.target.rollout_start(&model, &baseline) {
                    Ok(body) => Response::Rollout { id, verb: RolloutVerb::Start, body },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::RolloutStatus { id, model } => {
                self.wire.record_v2_control();
                let resp = match self.target.rollout_status(model.as_deref()) {
                    Ok(body) => Response::Rollout { id, verb: RolloutVerb::Status, body },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::RolloutAbort { id, model } => {
                self.wire.record_v2_control();
                let resp = match self.target.rollout_abort(&model) {
                    Ok(body) => Response::Rollout { id, verb: RolloutVerb::Abort, body },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::RolloutClear { id, model } => {
                self.wire.record_v2_control();
                let resp = match self.target.rollout_clear(&model) {
                    Ok(body) => Response::Rollout { id, verb: RolloutVerb::Clear, body },
                    Err(e) => error_response(Some(id), &e),
                };
                self.send(&resp).is_ok()
            }
            Request::Infer { id, model, backend, exec, features } => {
                self.wire.record_v2_infer(1);
                let mut route = route_for(model, backend, exec);
                // single-row requests are the traced unit: the sampler
                // decides here, the span's t0 is now, and the stages are
                // stamped as the request crosses each pipeline layer
                route.trace = self.trace.sample(id);
                self.dispatch_async(id, route, Work::One { features });
                true
            }
            Request::InferBatch { id, model, backend, exec, rows } => {
                self.wire.record_v2_infer(rows.len() as u64);
                let route = route_for(model, backend, exec);
                self.dispatch_async(id, route, Work::Batch { rows });
                true
            }
        }
    }

    /// Dispatch inference on its own thread so the reader keeps pulling
    /// frames (pipelining); responses are written as they complete, out
    /// of order. Blocks for backpressure once `max_in_flight` dispatches
    /// are outstanding on this connection.
    fn dispatch_async(&self, id: i64, route: RouteSpec, work: Work) {
        let depth = self.in_flight.acquire();
        self.wire.observe_in_flight(depth as u64);
        let permit = InFlightPermit(self.in_flight.clone());
        let client = self.client;
        let target = self.target.clone();
        let writer = self.writer.clone();
        let hub = self.trace.clone();
        let span = route.trace.clone();
        let requested_model = route.model.clone();
        let spawned = std::thread::Builder::new()
            .name("kan-edge-v2-dispatch".into())
            .spawn(move || {
                let _permit = permit; // released on drop, even on panic
                // a panicking dispatch must still answer: the connection
                // stays healthy, so without a frame the client would wait
                // on this id forever
                let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || run_work(id, client, route, work, target.as_ref()),
                ))
                .unwrap_or_else(|_| Response::Error {
                    id: Some(id),
                    code: ErrorCode::Internal,
                    message: "dispatch panicked".to_string(),
                    retry_after_ms: None,
                });
                let _ = send_response(&writer, &resp);
                if let Some(s) = &span {
                    // respond closes after the frame write; key the
                    // rollup by the id that actually served (errors
                    // yield incomplete spans, ring-only)
                    s.mark(Stage::Respond);
                    let model = match &resp {
                        Response::Infer { model, .. } => model.clone(),
                        _ => requested_model.unwrap_or_else(|| "default".into()),
                    };
                    hub.finish(s, &model);
                }
            });
        if spawned.is_err() {
            // thread exhaustion: the un-run closure was dropped (slot
            // released by the permit) — fail this request, never the
            // handler
            let _ = self.send(&Response::Error {
                id: Some(id),
                code: ErrorCode::Internal,
                message: "cannot spawn dispatch thread".to_string(),
                retry_after_ms: None,
            });
        }
    }
}

fn run_work(
    id: i64,
    client: ClientId,
    route: RouteSpec,
    work: Work,
    target: &dyn Dispatch,
) -> Response {
    fn wire_row(out: crate::coordinator::backend::RowOutput) -> WireRow {
        let class = argmax_f32(&out.logits);
        WireRow { logits: out.logits, class, std: out.trial_std }
    }
    match work {
        Work::One { features } => match target.dispatch(client, &route, features) {
            Ok((mid, out)) => Response::Infer { id, model: mid, row: wire_row(out) },
            Err(e) => error_response(Some(id), &e),
        },
        Work::Batch { rows } => match target.dispatch_batch(client, &route, rows) {
            Ok((mid, outs)) => {
                let results = outs.into_iter().map(wire_row).collect();
                Response::InferBatch { id, model: mid, results }
            }
            Err(e) => error_response(Some(id), &e),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{
        BackendSpec, ExecutionSession, RowOutput,
    };
    use crate::coordinator::server::{InferenceService, ServeOptions};
    use crate::error::{Error, Result};

    struct Sum;

    impl ExecutionSession for Sum {
        fn name(&self) -> &str {
            "sum"
        }

        fn spec(&self) -> BackendSpec {
            BackendSpec::synthetic(2)
        }

        fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
            Ok(rows
                .iter()
                .map(|r| {
                    let s: f32 = r.iter().sum();
                    vec![s, -s].into()
                })
                .collect())
        }
    }

    fn svc() -> Arc<dyn Dispatch> {
        Arc::new(InferenceService::start(
            std::sync::Arc::new(Sum),
            ServeOptions::default(),
        ))
    }

    /// Two-model router used to exercise the `"model"` field without a
    /// full registry.
    struct TwoModels;

    impl Dispatch for TwoModels {
        fn dispatch(
            &self,
            _client: ClientId,
            route: &RouteSpec,
            features: Vec<f32>,
        ) -> Result<(String, RowOutput)> {
            let s: f32 = features.iter().sum();
            match route.model.as_deref().unwrap_or("pos") {
                "pos" => Ok(("pos@1".into(), vec![s, -s].into())),
                "neg" => Ok(("neg@2".into(), vec![-s, s].into())),
                other => Err(Error::Registry(format!("model '{other}' not found"))),
            }
        }
    }

    #[test]
    fn respond_happy_path() {
        let v = respond(
            r#"{"features": [1.0, 2.0]}"#,
            ClientId::fresh(),
            svc().as_ref(),
        );
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0); // 3 > -3
        let logits = v.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits[0].as_f64().unwrap(), 3.0);
        assert_eq!(v.get("model").unwrap().as_str().unwrap(), "default");
    }

    #[test]
    fn respond_rejects_garbage() {
        let svc = svc();
        for bad in [
            "not json",
            "{}",
            r#"{"features": "x"}"#,
            r#"{"features": [1, "a"]}"#,
            r#"{"features": [1.0], "model": 7}"#,
        ] {
            let v = respond(bad, ClientId::fresh(), svc.as_ref());
            assert!(v.get("error").is_some(), "accepted {bad}");
        }
    }

    #[test]
    fn single_model_endpoint_rejects_model_field() {
        let v = respond(
            r#"{"features": [1.0], "model": "other"}"#,
            ClientId::fresh(),
            svc().as_ref(),
        );
        let err = v.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("single model"), "{err}");
    }

    #[test]
    fn model_field_routes_between_variants() {
        let router = TwoModels;
        let c = ClientId::fresh();
        let a = respond(r#"{"features": [2.0], "model": "pos"}"#, c, &router);
        assert_eq!(a.get("class").unwrap().as_i64().unwrap(), 0);
        assert_eq!(a.get("model").unwrap().as_str().unwrap(), "pos@1");
        let b = respond(r#"{"features": [2.0], "model": "neg"}"#, c, &router);
        assert_eq!(b.get("class").unwrap().as_i64().unwrap(), 1);
        assert_eq!(b.get("model").unwrap().as_str().unwrap(), "neg@2");
        let missing = respond(r#"{"features": [2.0], "model": "nope"}"#, c, &router);
        assert!(missing.get("error").unwrap().as_str().unwrap().contains("nope"));
    }

    #[test]
    fn v1_rejects_per_request_execution_options() {
        let svc = svc();
        for body in [
            r#"{"features": [1.0], "backend": "acim"}"#,
            r#"{"features": [1.0], "seed": 42}"#,
            r#"{"features": [1.0], "trials": 8}"#,
        ] {
            let v = respond(body, ClientId::fresh(), svc.as_ref());
            let err = v.get("error").unwrap().as_str().unwrap();
            assert!(err.contains("protocol v2"), "{body}: {err}");
            assert_eq!(v.get("code").unwrap().as_str().unwrap(), "unsupported");
        }
        // plain v1 traffic is untouched
        let v = respond(r#"{"features": [1.0]}"#, ClientId::fresh(), svc.as_ref());
        assert!(v.get("error").is_none());
    }

    #[test]
    fn v1_overloaded_reply_is_structured() {
        /// Always-overloaded target.
        struct Full;

        impl Dispatch for Full {
            fn dispatch(
                &self,
                _client: ClientId,
                _route: &RouteSpec,
                _features: Vec<f32>,
            ) -> Result<(String, RowOutput)> {
                Err(Error::Overloaded {
                    message: "client quota exceeded (4/4 rows in queue)".into(),
                    retry_after_ms: 9,
                })
            }
        }

        let v = respond(r#"{"features": [1.0]}"#, ClientId::fresh(), &Full);
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(v.get("retry_after_ms").unwrap().as_i64().unwrap(), 9);
        assert!(v.get("error").unwrap().as_str().unwrap().contains("quota"));
    }

    #[test]
    fn bounded_line_reader_handles_prefix_splits_and_caps() {
        use std::io::Cursor;
        // prefix carried over from the protocol sniff + two lines in one
        // buffer + a final line without a trailing newline
        let mut reader = Cursor::new(&b"irst\nsecond\nlast"[..]);
        let mut pending = b"f".to_vec();
        match read_line_bounded(&mut reader, &mut pending, 64).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "first"),
            _ => panic!("expected line"),
        }
        match read_line_bounded(&mut reader, &mut pending, 64).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "second"),
            _ => panic!("expected line"),
        }
        match read_line_bounded(&mut reader, &mut pending, 64).unwrap() {
            LineRead::Line(l) => assert_eq!(l, "last"),
            _ => panic!("expected line"),
        }
        assert!(matches!(
            read_line_bounded(&mut reader, &mut pending, 64).unwrap(),
            LineRead::Eof
        ));
        // oversized line is reported, not buffered forever
        let long = vec![b'x'; 100];
        let mut reader = Cursor::new(long);
        let mut pending = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut reader, &mut pending, 10).unwrap(),
            LineRead::TooLong
        ));
    }

    #[test]
    fn tcp_roundtrip_over_real_socket() {
        use std::io::{BufRead, BufReader, Write};
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
        conn.write_all(b"{\"features\": [2.0, 2.0, 1.0]}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);
        // pipelined second request on the same connection
        conn.write_all(b"{\"features\": [-5.0]}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        let v2 = Value::parse(&line2).unwrap();
        assert_eq!(v2.get("class").unwrap().as_i64().unwrap(), 1); // -(-5) wins
        server.shutdown();
    }

    #[test]
    fn concurrent_tcp_clients() {
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..6 {
            handles.push(std::thread::spawn(move || {
                use std::io::{BufRead, BufReader, Write};
                let mut conn = std::net::TcpStream::connect(addr).unwrap();
                let req = format!("{{\"features\": [{}.0]}}\n", i);
                conn.write_all(req.as_bytes()).unwrap();
                let mut reader = BufReader::new(conn);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = Value::parse(&line).unwrap();
                let logits = v.get("logits").unwrap().as_array().unwrap();
                assert_eq!(logits[0].as_f64().unwrap(), i as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_prompt_and_releases_port() {
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let addr = server.addr;
        let t0 = std::time::Instant::now();
        server.shutdown(); // joins the accept loop
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "shutdown took {:?}",
            t0.elapsed()
        );
        // accept loop exited -> the listener is closed; rebinding the same
        // address must succeed (SO_REUSEADDR-free proof the socket is gone)
        let rebound = std::net::TcpListener::bind(addr);
        assert!(rebound.is_ok(), "port still held after shutdown");
    }
}
