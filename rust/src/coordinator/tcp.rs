//! TCP wire protocol: JSON lines over a plain socket.
//!
//! Request:  `{"features": [f32; din]}\n`
//! Response: `{"logits": [...], "class": k}\n` or `{"error": "..."}\n`
//!
//! One thread per connection (edge request rates make this the simplest
//! correct design); the shared [`InferenceService`] behind it batches
//! across connections.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::server::InferenceService;
use crate::error::Result;
use crate::kan::model::argmax;
use crate::util::json::{obj, Value};

/// A running TCP server; dropping the handle does not stop it (process
/// lifetime), but `shutdown` flips the accept loop off for tests.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `svc`.
    pub fn spawn(addr: &str, svc: InferenceService) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        std::thread::Builder::new()
            .name("kan-edge-tcp".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let svc = svc.clone();
                            std::thread::spawn(move || handle_conn(s, svc));
                        }
                        Err(e) => eprintln!("accept error: {e}"),
                    }
                }
            })
            .map_err(|e| crate::error::Error::Serving(format!("spawn tcp: {e}")))?;
        Ok(TcpServer { addr: local, stop })
    }

    /// Ask the accept loop to exit after the next connection attempt.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the listener so `incoming()` yields once more
        let _ = TcpStream::connect(self.addr);
    }
}

/// Serve one connection until EOF.
pub fn handle_conn(stream: TcpStream, svc: InferenceService) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = respond(&line, &svc);
        let mut text = reply.to_string();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            break;
        }
    }
}

/// Pure request→response mapping (unit-testable without sockets).
pub fn respond(line: &str, svc: &InferenceService) -> Value {
    match Value::parse(line).ok().and_then(|v| v.f32_vec("features").ok()) {
        Some(features) => match svc.infer(features) {
            Ok(logits) => {
                let pred =
                    argmax(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>());
                let items: Vec<Value> =
                    logits.iter().map(|&v| Value::Float(v as f64)).collect();
                obj(vec![
                    ("logits", Value::Array(items)),
                    ("class", Value::Int(pred as i64)),
                ])
            }
            Err(e) => obj(vec![("error", Value::Str(e.to_string()))]),
        },
        None => obj(vec![(
            "error",
            Value::Str("bad request: expected {\"features\": [...]}".into()),
        )]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::InferBackend;
    use crate::coordinator::server::ServeOptions;
    use crate::error::Result;

    struct Sum;

    impl InferBackend for Sum {
        fn name(&self) -> &str {
            "sum"
        }

        fn output_dim(&self) -> usize {
            2
        }

        fn infer_batch(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(rows
                .iter()
                .map(|r| {
                    let s: f32 = r.iter().sum();
                    vec![s, -s]
                })
                .collect())
        }
    }

    fn svc() -> InferenceService {
        InferenceService::start(std::sync::Arc::new(Sum), ServeOptions::default())
    }

    #[test]
    fn respond_happy_path() {
        let v = respond(r#"{"features": [1.0, 2.0]}"#, &svc());
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0); // 3 > -3
        let logits = v.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits[0].as_f64().unwrap(), 3.0);
    }

    #[test]
    fn respond_rejects_garbage() {
        for bad in ["not json", "{}", r#"{"features": "x"}"#, r#"{"features": [1, "a"]}"#] {
            let v = respond(bad, &svc());
            assert!(v.get("error").is_some(), "accepted {bad}");
        }
    }

    #[test]
    fn tcp_roundtrip_over_real_socket() {
        use std::io::{BufRead, BufReader, Write};
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
        conn.write_all(b"{\"features\": [2.0, 2.0, 1.0]}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);
        // pipelined second request on the same connection
        conn.write_all(b"{\"features\": [-5.0]}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        let v2 = Value::parse(&line2).unwrap();
        assert_eq!(v2.get("class").unwrap().as_i64().unwrap(), 1); // -(-5) wins
        server.shutdown();
    }

    #[test]
    fn concurrent_tcp_clients() {
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..6 {
            handles.push(std::thread::spawn(move || {
                use std::io::{BufRead, BufReader, Write};
                let mut conn = std::net::TcpStream::connect(addr).unwrap();
                let req = format!("{{\"features\": [{}.0]}}\n", i);
                conn.write_all(req.as_bytes()).unwrap();
                let mut reader = BufReader::new(conn);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = Value::parse(&line).unwrap();
                let logits = v.get("logits").unwrap().as_array().unwrap();
                assert_eq!(logits[0].as_f64().unwrap(), i as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
