//! TCP wire protocol: JSON lines over a plain socket.
//!
//! Request:  `{"features": [f32; din]}\n`
//!           `{"model": "name"           , "features": [...]}\n`
//!           `{"model": "name@version"   , "features": [...]}\n`
//! Response: `{"logits": [...], "class": k, "model": "name@version"}\n`
//!           or `{"error": "..."}\n`
//!
//! The optional `"model"` field routes to a variant by name (latest
//! published version) or pinned `name@version`; omitting it hits the
//! endpoint's default model. The response always echoes the resolved
//! `name@version` id so clients observe hot-reload version switches.
//!
//! One thread per connection (edge request rates make this the simplest
//! correct design); the shared [`Dispatch`] target behind it batches
//! across connections — per model, when serving a
//! [`crate::registry::ModelRegistry`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::server::Dispatch;
use crate::error::Result;
use crate::kan::model::argmax;
use crate::util::json::{obj, Value};

/// A running TCP server; `shutdown` stops the accept loop promptly and
/// joins it (open connections finish on their own threads).
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl TcpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `target`.
    pub fn spawn(addr: &str, target: Arc<dyn Dispatch>) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("kan-edge-tcp".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    // checked on every wakeup: `shutdown` sets the flag and
                    // then self-connects, so this observes it immediately
                    // instead of waiting for the next real client
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let target = target.clone();
                            std::thread::spawn(move || handle_conn(s, target));
                        }
                        Err(e) => eprintln!("accept error: {e}"),
                    }
                }
                // listener drops here: the port is released by the time
                // `shutdown` returns
            })
            .map_err(|e| crate::error::Error::Serving(format!("spawn tcp: {e}")))?;
        Ok(TcpServer { addr: local, stop, accept_thread: Mutex::new(Some(handle)) })
    }

    /// Stop accepting and wait for the accept loop to exit. The flag is
    /// set *before* the wake-up self-connection so the loop cannot accept
    /// a real client in between; without the self-connect the blocking
    /// `incoming()` would only notice the flag on the next organic
    /// connection, leaving tests (and process shutdown) hanging.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let woke = TcpStream::connect(self.addr).is_ok();
        if woke {
            // the loop is guaranteed to observe the flag now, so joining
            // cannot hang
            if let Some(handle) = self.accept_thread.lock().unwrap().take() {
                let _ = handle.join();
            }
        }
        // if the wake-up connect failed (e.g. an unroutable bind address),
        // leave the thread to exit on the next organic connection instead
        // of blocking the caller forever
    }
}

/// Serve one connection until EOF.
pub fn handle_conn(stream: TcpStream, target: Arc<dyn Dispatch>) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = respond(&line, target.as_ref());
        let mut text = reply.to_string();
        text.push('\n');
        if writer.write_all(text.as_bytes()).is_err() {
            break;
        }
    }
}

fn error_reply(msg: impl Into<String>) -> Value {
    obj(vec![("error", Value::Str(msg.into()))])
}

/// Pure request→response mapping (unit-testable without sockets).
pub fn respond(line: &str, target: &dyn Dispatch) -> Value {
    let parsed = match Value::parse(line) {
        Ok(v) => v,
        Err(_) => return error_reply("bad request: not valid JSON"),
    };
    let features = match parsed.f32_vec("features") {
        Ok(f) => f,
        Err(_) => {
            return error_reply("bad request: expected {\"features\": [...]}")
        }
    };
    let model = match parsed.get("model") {
        None => None,
        Some(Value::Str(s)) => Some(s.as_str()),
        Some(_) => return error_reply("bad request: 'model' must be a string"),
    };
    match target.dispatch(model, features) {
        Ok((id, logits)) => {
            let pred = argmax(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>());
            let items: Vec<Value> =
                logits.iter().map(|&v| Value::Float(v as f64)).collect();
            obj(vec![
                ("logits", Value::Array(items)),
                ("class", Value::Int(pred as i64)),
                ("model", Value::Str(id)),
            ])
        }
        Err(e) => error_reply(e.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::InferBackend;
    use crate::coordinator::server::{InferenceService, ServeOptions};
    use crate::error::{Error, Result};

    struct Sum;

    impl InferBackend for Sum {
        fn name(&self) -> &str {
            "sum"
        }

        fn output_dim(&self) -> usize {
            2
        }

        fn infer_batch(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(rows
                .iter()
                .map(|r| {
                    let s: f32 = r.iter().sum();
                    vec![s, -s]
                })
                .collect())
        }
    }

    fn svc() -> Arc<dyn Dispatch> {
        Arc::new(InferenceService::start(
            std::sync::Arc::new(Sum),
            ServeOptions::default(),
        ))
    }

    /// Two-model router used to exercise the `"model"` field without a
    /// full registry.
    struct TwoModels;

    impl Dispatch for TwoModels {
        fn dispatch(
            &self,
            model: Option<&str>,
            features: Vec<f32>,
        ) -> Result<(String, Vec<f32>)> {
            let s: f32 = features.iter().sum();
            match model.unwrap_or("pos") {
                "pos" => Ok(("pos@1".into(), vec![s, -s])),
                "neg" => Ok(("neg@2".into(), vec![-s, s])),
                other => Err(Error::Registry(format!("model '{other}' not found"))),
            }
        }
    }

    #[test]
    fn respond_happy_path() {
        let v = respond(r#"{"features": [1.0, 2.0]}"#, svc().as_ref());
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0); // 3 > -3
        let logits = v.get("logits").unwrap().as_array().unwrap();
        assert_eq!(logits[0].as_f64().unwrap(), 3.0);
        assert_eq!(v.get("model").unwrap().as_str().unwrap(), "default");
    }

    #[test]
    fn respond_rejects_garbage() {
        let svc = svc();
        for bad in [
            "not json",
            "{}",
            r#"{"features": "x"}"#,
            r#"{"features": [1, "a"]}"#,
            r#"{"features": [1.0], "model": 7}"#,
        ] {
            let v = respond(bad, svc.as_ref());
            assert!(v.get("error").is_some(), "accepted {bad}");
        }
    }

    #[test]
    fn single_model_endpoint_rejects_model_field() {
        let v = respond(r#"{"features": [1.0], "model": "other"}"#, svc().as_ref());
        let err = v.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("single model"), "{err}");
    }

    #[test]
    fn model_field_routes_between_variants() {
        let router = TwoModels;
        let a = respond(r#"{"features": [2.0], "model": "pos"}"#, &router);
        assert_eq!(a.get("class").unwrap().as_i64().unwrap(), 0);
        assert_eq!(a.get("model").unwrap().as_str().unwrap(), "pos@1");
        let b = respond(r#"{"features": [2.0], "model": "neg"}"#, &router);
        assert_eq!(b.get("class").unwrap().as_i64().unwrap(), 1);
        assert_eq!(b.get("model").unwrap().as_str().unwrap(), "neg@2");
        let missing = respond(r#"{"features": [2.0], "model": "nope"}"#, &router);
        assert!(missing.get("error").unwrap().as_str().unwrap().contains("nope"));
    }

    #[test]
    fn tcp_roundtrip_over_real_socket() {
        use std::io::{BufRead, BufReader, Write};
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
        conn.write_all(b"{\"features\": [2.0, 2.0, 1.0]}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);
        // pipelined second request on the same connection
        conn.write_all(b"{\"features\": [-5.0]}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        let v2 = Value::parse(&line2).unwrap();
        assert_eq!(v2.get("class").unwrap().as_i64().unwrap(), 1); // -(-5) wins
        server.shutdown();
    }

    #[test]
    fn concurrent_tcp_clients() {
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let addr = server.addr;
        let mut handles = Vec::new();
        for i in 0..6 {
            handles.push(std::thread::spawn(move || {
                use std::io::{BufRead, BufReader, Write};
                let mut conn = std::net::TcpStream::connect(addr).unwrap();
                let req = format!("{{\"features\": [{}.0]}}\n", i);
                conn.write_all(req.as_bytes()).unwrap();
                let mut reader = BufReader::new(conn);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = Value::parse(&line).unwrap();
                let logits = v.get("logits").unwrap().as_array().unwrap();
                assert_eq!(logits[0].as_f64().unwrap(), i as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_prompt_and_releases_port() {
        let server = TcpServer::spawn("127.0.0.1:0", svc()).unwrap();
        let addr = server.addr;
        let t0 = std::time::Instant::now();
        server.shutdown(); // joins the accept loop
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(2),
            "shutdown took {:?}",
            t0.elapsed()
        );
        // accept loop exited -> the listener is closed; rebinding the same
        // address must succeed (SO_REUSEADDR-free proof the socket is gone)
        let rebound = std::net::TcpListener::bind(addr);
        assert!(rebound.is_ok(), "port still held after shutdown");
    }
}
