//! Serving metrics: latency percentiles, throughput, batch occupancy —
//! in **bounded** memory.
//!
//! ## Exact vs sampled (the contract)
//!
//! * **Counters are exact**: `requests`, `batches`, `rejected`,
//!   `errors`, the batch-occupancy mean (`requests-summed-per-batch /
//!   batches`), and the wall-clock throughput are monotonic integers or
//!   ratios of them — never sampled, never reset.
//! * **Percentiles are sampled**: latency and queue-wait distributions
//!   are kept as fixed-size reservoirs (Vitter's Algorithm R over the
//!   crate's deterministic [`crate::util::rng::Rng`]). Up to
//!   [`DEFAULT_RESERVOIR_SIZE`] observations per series the percentiles
//!   are exact; beyond that each retained sample is a uniform draw from
//!   the full history, so a reported percentile is an unbiased estimate
//!   with error O(1/√size) in rank. Memory and snapshot cost are
//!   O(reservoir) **regardless of uptime** — the v2 `metrics`/`health`
//!   verbs make snapshots remotely triggerable per connection, so they
//!   must not grow with request count.
//!
//! With multi-model serving each model's
//! [`crate::coordinator::server::InferenceService`] owns one [`Metrics`];
//! a [`MetricsHub`] keys them by model id (`name@version`). The hub
//! rollup merges reservoirs *weighted by how many observations each
//! sample represents* (percentiles of the merged sample population, not
//! averages of percentiles); counters roll up exactly. Retired model
//! versions keep their metrics in the hub so the rollup stays complete
//! across hot-reloads.
//!
//! Lock discipline: every public read path snapshots under the lock and
//! sorts/serializes after releasing it, and the hub clones its per-model
//! `Arc`s before snapshotting, so a slow remote `metrics` client can
//! never stall `record_request` on the serving path or `for_model` on
//! the load path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::LockExt;
use crate::util::json::{obj, Value};
use crate::util::rng::Rng;

/// Retained samples per series (latency, queue wait). 2 × 8 KiB per
/// model at u64 samples — edge-friendly.
pub const DEFAULT_RESERVOIR_SIZE: usize = 1024;

/// Fixed-size uniform sample of an unbounded observation stream
/// (Vitter's Algorithm R). Deterministic given the seed; the modulo on
/// the raw 64-bit draw has negligible bias at these ranges. Generic
/// over the sample type: `u64` for the microsecond series, `f64` for
/// the shadow divergence errors.
#[derive(Debug, Clone)]
struct Reservoir<T> {
    cap: usize,
    seen: u64,
    samples: Vec<T>,
    rng: Rng,
}

impl<T: Copy> Reservoir<T> {
    fn new(cap: usize, seed: u64) -> Self {
        Self { cap: cap.max(1), seen: 0, samples: Vec::new(), rng: Rng::new(seed) }
    }

    fn record(&mut self, v: T) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            let j = self.rng.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Observations each retained sample stands for (≥ 1.0).
    fn weight(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.seen as f64 / self.samples.len() as f64
        }
    }
}

#[derive(Debug, Clone)]
struct Inner {
    latencies_us: Reservoir<u64>,
    queue_waits_us: Reservoir<u64>,
    requests: u64,
    batches: u64,
    /// Σ batch size — `batched_rows / batches` is the exact mean
    /// occupancy over any interval (via deltas), with no per-batch state.
    batched_rows: u64,
    rejected: u64,
    errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Inner {
    fn new(reservoir: usize) -> Self {
        // fixed distinct seeds: determinism is a feature (reproducible
        // reports in tests), independence between the two series is not
        // statistically needed — they are never compared sample-wise
        Self {
            latencies_us: Reservoir::new(reservoir, 0x1A7E_11C1),
            queue_waits_us: Reservoir::new(reservoir, 0x9E_0F_ABCD),
            requests: 0,
            batches: 0,
            batched_rows: 0,
            rejected: 0,
            errors: 0,
            started: None,
            finished: None,
        }
    }

    fn wall_secs(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Consumes the snapshot so the reservoirs sort in place (no second
    /// copy on top of the one `snapshot()` took under the lock).
    fn report(mut self) -> MetricsReport {
        self.latencies_us.samples.sort_unstable();
        self.queue_waits_us.samples.sort_unstable();
        let wall = self.wall_secs();
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            rejected: self.rejected,
            errors: self.errors,
            throughput_rps: if wall > 0.0 {
                self.requests as f64 / wall
            } else {
                0.0
            },
            latency_p50_us: percentile(&self.latencies_us.samples, 0.50),
            latency_p99_us: percentile(&self.latencies_us.samples, 0.99),
            queue_wait_p50_us: percentile(&self.queue_waits_us.samples, 0.50),
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batched_rows as f64 / self.batches as f64
            },
            shadow: None,
            queue_depth: None,
            queue_clients: None,
            max_client_backlog: None,
            stages: None,
            engine_profile: None,
            rollout: None,
        }
    }
}

/// Aggregated serving metrics (one per model pipeline; see module docs
/// for the exact-vs-sampled contract).
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// A point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    /// Number of closed batches executed (requests / batches = exact
    /// mean occupancy over any interval, via deltas).
    pub batches: u64,
    pub rejected: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub queue_wait_p50_us: u64,
    pub mean_batch: f64,
    /// Shadow-execution divergence, when the model runs with a mirror
    /// backend (attached by the registry; `None` for plain pipelines).
    pub shadow: Option<ShadowReport>,
    /// Instantaneous admission-queue depth (rows queued across all
    /// clients). Attached by the serving layer that owns the scheduler;
    /// `None` when the report comes from a bare [`Metrics`].
    pub queue_depth: Option<usize>,
    /// Distinct clients with queued rows (`drr` only; 0 under `fifo`).
    pub queue_clients: Option<usize>,
    /// Deepest single-client backlog (`drr` only; 0 under `fifo`).
    pub max_client_backlog: Option<usize>,
    /// Per-stage p50/p99 rollup over sampled request traces (attached
    /// from the [`crate::obs::trace::TraceHub`]; `None` when tracing is
    /// off or nothing completed yet) — see `docs/OBSERVABILITY.md`.
    pub stages: Option<crate::obs::trace::StageReport>,
    /// Live engine profile (tiles touched, fused hits, per-layer
    /// interval occupancy vs the SAM calibration prior), when the
    /// model's session runs with profiling on.
    pub engine_profile: Option<Value>,
    /// Rollout status for the model this report describes, when it is
    /// the candidate of an active or recorded canary rollout (attached
    /// by the registry; see [`crate::rollout`]).
    pub rollout: Option<Value>,
}

impl MetricsReport {
    /// JSON shape served by the v2 `metrics` verb.
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("requests", Value::Int(self.requests as i64)),
            ("batches", Value::Int(self.batches as i64)),
            ("rejected", Value::Int(self.rejected as i64)),
            ("errors", Value::Int(self.errors as i64)),
            ("throughput_rps", Value::Float(self.throughput_rps)),
            ("latency_p50_us", Value::Int(self.latency_p50_us as i64)),
            ("latency_p99_us", Value::Int(self.latency_p99_us as i64)),
            ("queue_wait_p50_us", Value::Int(self.queue_wait_p50_us as i64)),
            ("mean_batch", Value::Float(self.mean_batch)),
        ];
        if let Some(s) = &self.shadow {
            fields.push(("shadow", s.to_value()));
        }
        if let Some(d) = self.queue_depth {
            fields.push(("queue_depth", Value::Int(d as i64)));
        }
        if let Some(c) = self.queue_clients {
            fields.push(("queue_clients", Value::Int(c as i64)));
        }
        if let Some(b) = self.max_client_backlog {
            fields.push(("max_client_backlog", Value::Int(b as i64)));
        }
        if let Some(st) = &self.stages {
            fields.push(("stages", st.to_value()));
        }
        if let Some(p) = &self.engine_profile {
            fields.push(("engine_profile", p.clone()));
        }
        if let Some(r) = &self.rollout {
            fields.push(("rollout", r.clone()));
        }
        obj(fields)
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::with_reservoir(DEFAULT_RESERVOIR_SIZE)
    }

    /// Explicit reservoir size (tests; production uses the default).
    pub fn with_reservoir(size: usize) -> Self {
        Self { inner: Mutex::new(Inner::new(size)) }
    }

    pub fn record_request(&self, latency: Duration, queue_wait: Duration) {
        let mut g = self.inner.lock_recover();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.latencies_us.record(latency.as_micros() as u64);
        g.queue_waits_us.record(queue_wait.as_micros() as u64);
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock_recover();
        g.batches += 1;
        g.batched_rows += size as u64;
    }

    pub fn record_rejection(&self) {
        self.inner.lock_recover().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock_recover().errors += 1;
    }

    pub fn report(&self) -> MetricsReport {
        // snapshot under the lock, sort outside it: the v2 `metrics`
        // verb makes reports remotely triggerable, and post-processing
        // must not stall `record_request` on the serving path
        self.snapshot().report()
    }

    /// `(retained, observed)` for the latency series — the test hook for
    /// the boundedness contract (retained ≤ reservoir size always).
    pub fn latency_sample_state(&self) -> (usize, u64) {
        let g = self.inner.lock_recover();
        (g.latencies_us.samples.len(), g.latencies_us.seen)
    }

    fn snapshot(&self) -> Inner {
        self.inner.lock_recover().clone()
    }
}

/// Per-model metrics registry with a weighted aggregate rollup.
#[derive(Debug, Default)]
pub struct MetricsHub {
    models: Mutex<BTreeMap<String, Arc<Metrics>>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// The [`Metrics`] for model `id`, created on first use. Ids persist
    /// for the hub's lifetime so retired versions still roll up.
    pub fn for_model(&self, id: &str) -> Arc<Metrics> {
        self.models
            .lock_recover()
            .entry(id.to_string())
            .or_default()
            .clone()
    }

    /// Clone the per-model handles under the hub lock and release it
    /// before touching any per-model state — the snapshot/sort/serialize
    /// work (O(reservoir) each) must never run under the hub lock, or a
    /// slow remote `metrics` client would stall `for_model` (lazy loads,
    /// hot reloads) and recording.
    fn handles(&self) -> Vec<(String, Arc<Metrics>)> {
        self.models
            .lock_recover()
            .iter()
            .map(|(id, m)| (id.clone(), m.clone()))
            .collect()
    }

    /// Per-model reports, sorted by model id.
    pub fn reports(&self) -> Vec<(String, MetricsReport)> {
        self.handles()
            .into_iter()
            .map(|(id, m)| (id, m.report()))
            .collect()
    }

    /// Rollup across every model ever served by this hub: exact counter
    /// sums; percentiles over the union of the reservoirs with each
    /// sample weighted by the observations it represents.
    pub fn aggregate(&self) -> MetricsReport {
        let snapshots: Vec<Inner> = self
            .handles()
            .into_iter()
            .map(|(_, m)| m.snapshot())
            .collect();
        let mut requests = 0u64;
        let mut batches = 0u64;
        let mut batched_rows = 0u64;
        let mut rejected = 0u64;
        let mut errors = 0u64;
        let mut started: Option<Instant> = None;
        let mut finished: Option<Instant> = None;
        let mut latencies: Vec<(u64, f64)> = Vec::new();
        let mut queue_waits: Vec<(u64, f64)> = Vec::new();
        for s in &snapshots {
            requests += s.requests;
            batches += s.batches;
            batched_rows += s.batched_rows;
            rejected += s.rejected;
            errors += s.errors;
            started = match (started, s.started) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            finished = match (finished, s.finished) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            };
            let lw = s.latencies_us.weight();
            latencies.extend(s.latencies_us.samples.iter().map(|&v| (v, lw)));
            let qw = s.queue_waits_us.weight();
            queue_waits.extend(s.queue_waits_us.samples.iter().map(|&v| (v, qw)));
        }
        let wall = match (started, finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        // sort once per series; both percentile walks reuse the order
        // (this runs on the remotely-triggerable v2 `metrics` path)
        latencies.sort_unstable_by_key(|&(v, _)| v);
        queue_waits.sort_unstable_by_key(|&(v, _)| v);
        MetricsReport {
            requests,
            batches,
            rejected,
            errors,
            throughput_rps: if wall > 0.0 { requests as f64 / wall } else { 0.0 },
            latency_p50_us: percentile_weighted(&latencies, 0.50),
            latency_p99_us: percentile_weighted(&latencies, 0.99),
            queue_wait_p50_us: percentile_weighted(&queue_waits, 0.50),
            mean_batch: if batches == 0 {
                0.0
            } else {
                batched_rows as f64 / batches as f64
            },
            shadow: None,
            queue_depth: None,
            queue_clients: None,
            max_client_backlog: None,
            stages: None,
            engine_profile: None,
            rollout: None,
        }
    }
}

// ---- shadow divergence -----------------------------------------------------

/// Online digital-vs-analog divergence statistics for one shadow mirror
/// (see [`super::shadow`]): exact counters plus bounded reservoirs for
/// the error distributions — the paper's non-ideal-effect statistics,
/// measured from live traffic. Same exact-vs-sampled contract as
/// [`Metrics`].
#[derive(Debug)]
pub struct ShadowMetrics {
    sampled: AtomicU64,
    mirrored: AtomicU64,
    dropped: AtomicU64,
    errors: AtomicU64,
    argmax_flips: AtomicU64,
    inner: Mutex<ShadowInner>,
}

#[derive(Debug)]
struct ShadowInner {
    mae_sum: f64,
    /// Mean-absolute-logit-error distribution over mirrored rows.
    mae: Reservoir<f64>,
    /// Per-layer mean absolute partial-sum error distributions, lazily
    /// sized on the first observation.
    layer_err: Vec<Reservoir<f64>>,
}

/// Point-in-time shadow divergence report.
#[derive(Debug, Clone, PartialEq)]
pub struct ShadowReport {
    /// Rows the sampler selected for mirroring.
    pub sampled: u64,
    /// Rows the mirror actually executed and compared.
    pub mirrored: u64,
    /// Sampled rows dropped because the (bounded, non-blocking) mirror
    /// queue was full — the price of never delaying a primary response.
    pub dropped: u64,
    /// Mirror executions that failed.
    pub errors: u64,
    /// Mirrored rows whose analog argmax differed from the served one.
    pub argmax_flips: u64,
    /// `argmax_flips / mirrored` (0 when nothing mirrored).
    pub flip_rate: f64,
    /// Mean of the per-row mean-absolute-logit-error (exact).
    pub logit_mae_mean: f64,
    /// p50/p99 of the per-row MAE distribution (sampled).
    pub logit_mae_p50: f64,
    pub logit_mae_p99: f64,
    /// Per-layer `(p50, p99)` of the mean absolute partial-sum error —
    /// where in the stack the analog path diverges.
    pub layer_err_quantiles: Vec<(f64, f64)>,
}

impl ShadowMetrics {
    pub fn new() -> Self {
        Self {
            sampled: AtomicU64::new(0),
            mirrored: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            argmax_flips: AtomicU64::new(0),
            inner: Mutex::new(ShadowInner {
                mae_sum: 0.0,
                mae: Reservoir::new(DEFAULT_RESERVOIR_SIZE, 0x5AD0_11AE),
                layer_err: Vec::new(),
            }),
        }
    }

    pub fn record_sampled(&self) {
        self.sampled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_dropped(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one completed mirror comparison.
    pub fn record_mirror(&self, flip: bool, mae: f64, layer_err: &[f64]) {
        self.mirrored.fetch_add(1, Ordering::Relaxed);
        if flip {
            self.argmax_flips.fetch_add(1, Ordering::Relaxed);
        }
        let mut g = self.inner.lock_recover();
        g.mae_sum += mae;
        g.mae.record(mae);
        while g.layer_err.len() < layer_err.len() {
            let salt = 0xE8_A0 + g.layer_err.len() as u64;
            g.layer_err.push(Reservoir::new(DEFAULT_RESERVOIR_SIZE, salt));
        }
        for (r, &e) in g.layer_err.iter_mut().zip(layer_err) {
            r.record(e);
        }
    }

    /// Zero every counter and reservoir. Divergence statistics are only
    /// meaningful for one (baseline, candidate) pair: whoever owns the
    /// mirror must reset (or replace) the metrics whenever the mirrored
    /// target changes, so a new comparison never inherits a previous
    /// candidate's flip/MAE reservoirs. The rollout plane also uses
    /// this at observation-window boundaries to get per-window gates.
    pub fn reset(&self) {
        // take the inner lock first so a concurrent `record_mirror`
        // cannot interleave a counter bump between the two phases
        let mut g = self.inner.lock_recover();
        self.sampled.store(0, Ordering::Relaxed);
        self.mirrored.store(0, Ordering::Relaxed);
        self.dropped.store(0, Ordering::Relaxed);
        self.errors.store(0, Ordering::Relaxed);
        self.argmax_flips.store(0, Ordering::Relaxed);
        g.mae_sum = 0.0;
        g.mae = Reservoir::new(DEFAULT_RESERVOIR_SIZE, 0x5AD0_11AE);
        g.layer_err = Vec::new();
    }

    pub fn report(&self) -> ShadowReport {
        let (mae_sum, mut mae, layer) = {
            let g = self.inner.lock_recover();
            (g.mae_sum, g.mae.samples.clone(), g.layer_err.clone())
        };
        mae.sort_unstable_by(f64::total_cmp);
        let mirrored = self.mirrored.load(Ordering::Relaxed);
        let layer_err_quantiles = layer
            .into_iter()
            .map(|r| {
                let mut s = r.samples;
                s.sort_unstable_by(f64::total_cmp);
                (percentile(&s, 0.50), percentile(&s, 0.99))
            })
            .collect();
        ShadowReport {
            sampled: self.sampled.load(Ordering::Relaxed),
            mirrored,
            dropped: self.dropped.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            argmax_flips: self.argmax_flips.load(Ordering::Relaxed),
            flip_rate: if mirrored > 0 {
                self.argmax_flips.load(Ordering::Relaxed) as f64 / mirrored as f64
            } else {
                0.0
            },
            logit_mae_mean: if mirrored > 0 { mae_sum / mirrored as f64 } else { 0.0 },
            logit_mae_p50: percentile(&mae, 0.50),
            logit_mae_p99: percentile(&mae, 0.99),
            layer_err_quantiles,
        }
    }
}

impl Default for ShadowMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowReport {
    /// The `"shadow"` section of a per-model metrics report.
    pub fn to_value(&self) -> Value {
        let layers: Vec<Value> = self
            .layer_err_quantiles
            .iter()
            .map(|&(p50, p99)| {
                obj(vec![("p50", Value::Float(p50)), ("p99", Value::Float(p99))])
            })
            .collect();
        obj(vec![
            ("sampled", Value::Int(self.sampled as i64)),
            ("mirrored", Value::Int(self.mirrored as i64)),
            ("dropped", Value::Int(self.dropped as i64)),
            ("errors", Value::Int(self.errors as i64)),
            ("argmax_flips", Value::Int(self.argmax_flips as i64)),
            ("flip_rate", Value::Float(self.flip_rate)),
            ("logit_mae_mean", Value::Float(self.logit_mae_mean)),
            ("logit_mae_p50", Value::Float(self.logit_mae_p50)),
            ("logit_mae_p99", Value::Float(self.logit_mae_p99)),
            ("layer_err", Value::Array(layers)),
        ])
    }
}


/// Transport-level counters for the TCP endpoint: per-protocol-version
/// request counts, connection lifecycle, and the per-connection
/// pipelining high-water mark. One instance per
/// [`TcpServer`](super::tcp::TcpServer); surfaced over the wire by the
/// v2 `metrics` verb (the `"wire"` section).
#[derive(Debug, Default)]
pub struct WireMetrics {
    v1_requests: AtomicU64,
    v2_requests: AtomicU64,
    v2_rows: AtomicU64,
    v2_control: AtomicU64,
    connections_opened: AtomicU64,
    connections_closed: AtomicU64,
    in_flight_hwm: AtomicU64,
    oversized: AtomicU64,
    protocol_errors: AtomicU64,
}

impl WireMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_v1_request(&self) {
        self.v1_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One v2 inference request carrying `rows` feature rows (1 for
    /// `infer`, the batch size for `infer_batch`).
    pub fn record_v2_infer(&self, rows: u64) {
        self.v2_requests.fetch_add(1, Ordering::Relaxed);
        self.v2_rows.fetch_add(rows, Ordering::Relaxed);
    }

    pub fn record_v2_control(&self) {
        self.v2_control.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connection_opened(&self) {
        self.connections_opened.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connection_closed(&self) {
        self.connections_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an observed per-connection in-flight depth; keeps the max.
    pub fn observe_in_flight(&self, depth: u64) {
        self.in_flight_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn record_oversized(&self) {
        self.oversized.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn active_connections(&self) -> u64 {
        let opened = self.connections_opened.load(Ordering::Relaxed);
        let closed = self.connections_closed.load(Ordering::Relaxed);
        opened.saturating_sub(closed)
    }

    /// JSON shape of the `"wire"` section of the `metrics` verb.
    pub fn to_value(&self) -> Value {
        let int = |a: &AtomicU64| Value::Int(a.load(Ordering::Relaxed) as i64);
        obj(vec![
            ("v1_requests", int(&self.v1_requests)),
            ("v2_requests", int(&self.v2_requests)),
            ("v2_rows", int(&self.v2_rows)),
            ("v2_control", int(&self.v2_control)),
            ("connections_total", int(&self.connections_opened)),
            ("connections_active", Value::Int(self.active_connections() as i64)),
            ("in_flight_hwm", int(&self.in_flight_hwm)),
            ("oversized", int(&self.oversized)),
            ("protocol_errors", int(&self.protocol_errors)),
        ])
    }
}

/// Index-based percentile over a sorted series (`T::default()`, i.e.
/// zero, when empty). Generic over the sample type — the `u64`
/// microsecond series and the `f64` shadow divergence series share one
/// index contract. Public so out-of-crate consumers (e.g. `kan-edge
/// bench-net`) report percentiles with exactly the serving core's
/// formula.
pub fn percentile<T: Copy + Default>(sorted: &[T], p: f64) -> T {
    if sorted.is_empty() {
        return T::default();
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Percentile over weighted samples **already sorted by value**: walk
/// the cumulative weight to `p × total`. Used for the hub rollup, where
/// reservoirs of different coverage merge (a sample from a busy model
/// stands for more observations than one from an idle model).
fn percentile_weighted(sorted: &[(u64, f64)], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0].0 <= w[1].0));
    let total: f64 = sorted.iter().map(|&(_, w)| w).sum();
    let target = p * total;
    let mut cum = 0.0;
    for &(v, w) in sorted.iter() {
        cum += w;
        if cum >= target {
            return v;
        }
    }
    sorted.last().map(|&(v, _)| v).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn weighted_percentiles() {
        // one heavy sample (stands for 9 observations) vs one light,
        // pre-sorted by value as the contract requires
        let s = vec![(100u64, 9.0), (900u64, 1.0)];
        assert_eq!(percentile_weighted(&s, 0.50), 100);
        assert_eq!(percentile_weighted(&s, 0.95), 900);
        assert_eq!(percentile_weighted(&[], 0.5), 0);
    }

    #[test]
    fn report_aggregates() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record_request(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
            );
        }
        m.record_batch(4);
        m.record_batch(6);
        m.record_rejection();
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.mean_batch, 5.0);
        assert!(r.latency_p50_us >= 100);
        assert!(r.latency_p99_us >= r.latency_p50_us);
    }

    #[test]
    fn reservoir_is_bounded_and_uniform() {
        let m = Metrics::with_reservoir(64);
        for i in 0..10_000u64 {
            m.record_request(Duration::from_micros(i), Duration::from_micros(1));
        }
        let (retained, seen) = m.latency_sample_state();
        assert_eq!(retained, 64, "reservoir must stay at capacity");
        assert_eq!(seen, 10_000);
        // counters stay exact while percentiles are sampled
        let r = m.report();
        assert_eq!(r.requests, 10_000);
        // p50 of uniform 0..10000 ≈ 5000; 64 samples → σ ≈ 6.2% of the
        // range, so ±25% is > 4σ — deterministic anyway (fixed rng seed)
        let p50 = r.latency_p50_us as f64;
        assert!((2_500.0..=7_500.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn small_streams_report_exact_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i), Duration::from_micros(i));
        }
        let r = m.report();
        // fewer observations than the reservoir: everything retained
        assert_eq!(r.latency_p50_us, 50);
        assert_eq!(r.latency_p99_us, 99);
        assert_eq!(r.queue_wait_p50_us, 50);
    }

    #[test]
    fn hub_rolls_up_across_models() {
        let hub = MetricsHub::new();
        let a = hub.for_model("kan1@1");
        let b = hub.for_model("kan2@1");
        for _ in 0..3 {
            a.record_request(Duration::from_micros(100), Duration::from_micros(1));
        }
        b.record_request(Duration::from_micros(900), Duration::from_micros(1));
        b.record_error();

        let reports = hub.reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].0, "kan1@1");
        assert_eq!(reports[0].1.requests, 3);
        assert_eq!(reports[1].1.errors, 1);

        let agg = hub.aggregate();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.errors, 1);
        // merged population: p50 of {100,100,100,900} is 100, not 500
        assert_eq!(agg.latency_p50_us, 100);
    }

    #[test]
    fn hub_rollup_weights_unequal_coverage() {
        // model a saw 4096 fast requests through a tiny reservoir; model
        // b saw 2 slow ones fully retained — the rollup must not let b's
        // 2 observations outvote a's thousands
        let hub = MetricsHub::new();
        let a = Arc::new(Metrics::with_reservoir(8));
        hub.models.lock_recover().insert("a@1".into(), a.clone());
        let b = hub.for_model("b@1");
        for _ in 0..4096 {
            a.record_request(Duration::from_micros(10), Duration::from_micros(1));
        }
        for _ in 0..2 {
            b.record_request(Duration::from_micros(9_000), Duration::from_micros(1));
        }
        let agg = hub.aggregate();
        assert_eq!(agg.requests, 4098);
        assert_eq!(agg.latency_p50_us, 10);
        // b's 2 observations are < 0.05% of the merged population, so
        // they must NOT surface at p99 — an unweighted concat of the
        // reservoirs (8 + 2 samples) would wrongly report 9000 here
        assert_eq!(agg.latency_p99_us, 10);
    }

    #[test]
    fn report_counts_batches() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(6);
        let r = m.report();
        assert_eq!(r.batches, 2);
        let v = r.to_value();
        assert_eq!(v.get("batches").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("mean_batch").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn wire_metrics_counters() {
        let w = WireMetrics::new();
        w.connection_opened();
        w.connection_opened();
        w.connection_closed();
        w.record_v1_request();
        w.record_v2_infer(1);
        w.record_v2_infer(16);
        w.record_v2_control();
        w.observe_in_flight(3);
        w.observe_in_flight(9);
        w.observe_in_flight(5);
        w.record_oversized();
        let v = w.to_value();
        assert_eq!(v.get("v1_requests").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("v2_requests").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("v2_rows").unwrap().as_i64().unwrap(), 17);
        assert_eq!(v.get("v2_control").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("connections_total").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("connections_active").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("in_flight_hwm").unwrap().as_i64().unwrap(), 9);
        assert_eq!(v.get("oversized").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn shadow_metrics_report_counts_and_quantiles() {
        let s = ShadowMetrics::new();
        for _ in 0..10 {
            s.record_sampled();
        }
        s.record_dropped();
        for i in 0..8 {
            let flip = i % 4 == 0;
            s.record_mirror(flip, 0.1 * (i + 1) as f64, &[0.01, 0.02 * (i + 1) as f64]);
        }
        let r = s.report();
        assert_eq!(r.sampled, 10);
        assert_eq!(r.mirrored, 8);
        assert_eq!(r.dropped, 1);
        assert_eq!(r.argmax_flips, 2);
        assert!((r.flip_rate - 0.25).abs() < 1e-12);
        assert!((r.logit_mae_mean - 0.45).abs() < 1e-9, "{}", r.logit_mae_mean);
        assert!(r.logit_mae_p50 > 0.0 && r.logit_mae_p99 >= r.logit_mae_p50);
        assert_eq!(r.layer_err_quantiles.len(), 2);
        assert!(r.layer_err_quantiles[1].1 >= r.layer_err_quantiles[1].0);
        // serialization carries the section
        let v = r.to_value();
        assert_eq!(v.get("mirrored").unwrap().as_i64().unwrap(), 8);
        assert_eq!(v.get("argmax_flips").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("layer_err").unwrap().as_array().unwrap().len(), 2);
        // a report with shadow attached serializes it under "shadow"
        let mut mr = Metrics::new().report();
        assert!(mr.to_value().get("shadow").is_none());
        mr.shadow = Some(r);
        assert!(mr.to_value().get("shadow").unwrap().get("flip_rate").is_some());
    }

    #[test]
    fn optional_report_sections_serialize_when_attached() {
        use crate::obs::trace::{StageReport, STAGES};
        let mut r = Metrics::new().report();
        let v = r.to_value();
        assert!(v.get("queue_depth").is_none());
        assert!(v.get("stages").is_none());
        assert!(v.get("engine_profile").is_none());
        r.queue_depth = Some(7);
        r.queue_clients = Some(2);
        r.max_client_backlog = Some(4);
        r.stages = Some(StageReport {
            count: 3,
            p50_us: [1; STAGES],
            p99_us: [2; STAGES],
        });
        r.engine_profile = Some(obj(vec![("samples", Value::Int(5))]));
        let v = r.to_value();
        assert_eq!(v.get("queue_depth").unwrap().as_i64().unwrap(), 7);
        assert_eq!(v.get("queue_clients").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("max_client_backlog").unwrap().as_i64().unwrap(), 4);
        assert_eq!(
            v.get("stages").unwrap().get("count").unwrap().as_i64().unwrap(),
            3
        );
        assert_eq!(
            v.get("engine_profile")
                .unwrap()
                .get("samples")
                .unwrap()
                .as_i64()
                .unwrap(),
            5
        );
    }

    #[test]
    fn hub_returns_same_instance_per_id() {
        let hub = MetricsHub::new();
        let a1 = hub.for_model("m@1");
        let a2 = hub.for_model("m@1");
        a1.record_rejection();
        assert_eq!(a2.report().rejected, 1);
    }
}
