//! Serving metrics: latency percentiles, throughput, batch occupancy.
//!
//! Lock-free on the hot path is unnecessary at edge request rates; a
//! mutexed reservoir keeps the code simple and the report exact.
//!
//! With multi-model serving each model's [`crate::coordinator::server::InferenceService`]
//! owns one [`Metrics`]; a [`MetricsHub`] keys them by model id
//! (`name@version`) and computes an exact aggregate rollup by merging the
//! raw reservoirs (percentiles of merged samples, not averages of
//! percentiles). Retired model versions keep their metrics in the hub so
//! the rollup stays complete across hot-reloads.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default, Clone)]
struct Inner {
    latencies_us: Vec<u64>,
    queue_waits_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    requests: u64,
    rejected: u64,
    errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Inner {
    fn merge(&mut self, other: &Inner) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.queue_waits_us.extend_from_slice(&other.queue_waits_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    fn report(&self) -> MetricsReport {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        let mut qw = self.queue_waits_us.clone();
        qw.sort_unstable();
        let wall = match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        MetricsReport {
            requests: self.requests,
            rejected: self.rejected,
            errors: self.errors,
            throughput_rps: if wall > 0.0 {
                self.requests as f64 / wall
            } else {
                0.0
            },
            latency_p50_us: percentile(&lat, 0.50),
            latency_p99_us: percentile(&lat, 0.99),
            queue_wait_p50_us: percentile(&qw, 0.50),
            mean_batch: if self.batch_sizes.is_empty() {
                0.0
            } else {
                self.batch_sizes.iter().sum::<usize>() as f64
                    / self.batch_sizes.len() as f64
            },
        }
    }
}

/// A point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    pub rejected: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub queue_wait_p50_us: u64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency: Duration, queue_wait: Duration) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.latencies_us.push(latency.as_micros() as u64);
        g.queue_waits_us.push(queue_wait.as_micros() as u64);
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size);
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn report(&self) -> MetricsReport {
        self.inner.lock().unwrap().report()
    }

    fn snapshot(&self) -> Inner {
        self.inner.lock().unwrap().clone()
    }
}

/// Per-model metrics registry with an exact aggregate rollup.
#[derive(Debug, Default)]
pub struct MetricsHub {
    models: Mutex<BTreeMap<String, Arc<Metrics>>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// The [`Metrics`] for model `id`, created on first use. Ids persist
    /// for the hub's lifetime so retired versions still roll up.
    pub fn for_model(&self, id: &str) -> Arc<Metrics> {
        self.models
            .lock()
            .unwrap()
            .entry(id.to_string())
            .or_default()
            .clone()
    }

    /// Per-model reports, sorted by model id.
    pub fn reports(&self) -> Vec<(String, MetricsReport)> {
        self.models
            .lock()
            .unwrap()
            .iter()
            .map(|(id, m)| (id.clone(), m.report()))
            .collect()
    }

    /// Exact rollup across every model ever served by this hub.
    pub fn aggregate(&self) -> MetricsReport {
        let snapshots: Vec<Inner> = self
            .models
            .lock()
            .unwrap()
            .values()
            .map(|m| m.snapshot())
            .collect();
        let mut acc = Inner::default();
        for s in &snapshots {
            acc.merge(s);
        }
        acc.report()
    }
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn report_aggregates() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record_request(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
            );
        }
        m.record_batch(4);
        m.record_batch(6);
        m.record_rejection();
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.mean_batch, 5.0);
        assert!(r.latency_p50_us >= 100);
        assert!(r.latency_p99_us >= r.latency_p50_us);
    }

    #[test]
    fn hub_rolls_up_across_models() {
        let hub = MetricsHub::new();
        let a = hub.for_model("kan1@1");
        let b = hub.for_model("kan2@1");
        for _ in 0..3 {
            a.record_request(Duration::from_micros(100), Duration::from_micros(1));
        }
        b.record_request(Duration::from_micros(900), Duration::from_micros(1));
        b.record_error();

        let reports = hub.reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].0, "kan1@1");
        assert_eq!(reports[0].1.requests, 3);
        assert_eq!(reports[1].1.errors, 1);

        let agg = hub.aggregate();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.errors, 1);
        // merged reservoir: p50 of [100,100,100,900] is 100, not 500
        assert_eq!(agg.latency_p50_us, 100);
    }

    #[test]
    fn hub_returns_same_instance_per_id() {
        let hub = MetricsHub::new();
        let a1 = hub.for_model("m@1");
        let a2 = hub.for_model("m@1");
        a1.record_rejection();
        assert_eq!(a2.report().rejected, 1);
    }
}
