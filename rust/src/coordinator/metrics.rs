//! Serving metrics: latency percentiles, throughput, batch occupancy.
//!
//! Lock-free on the hot path is unnecessary at edge request rates; a
//! mutexed reservoir keeps the code simple and the report exact.
//!
//! With multi-model serving each model's [`crate::coordinator::server::InferenceService`]
//! owns one [`Metrics`]; a [`MetricsHub`] keys them by model id
//! (`name@version`) and computes an exact aggregate rollup by merging the
//! raw reservoirs (percentiles of merged samples, not averages of
//! percentiles). Retired model versions keep their metrics in the hub so
//! the rollup stays complete across hot-reloads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::{obj, Value};

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default, Clone)]
struct Inner {
    latencies_us: Vec<u64>,
    queue_waits_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    requests: u64,
    rejected: u64,
    errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Inner {
    fn merge(&mut self, other: &Inner) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.queue_waits_us.extend_from_slice(&other.queue_waits_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.requests += other.requests;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    /// Consumes the snapshot so the reservoirs sort in place (no second
    /// copy on top of the one `snapshot()` took under the lock).
    fn report(mut self) -> MetricsReport {
        self.latencies_us.sort_unstable();
        self.queue_waits_us.sort_unstable();
        let wall = match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        MetricsReport {
            requests: self.requests,
            batches: self.batch_sizes.len() as u64,
            rejected: self.rejected,
            errors: self.errors,
            throughput_rps: if wall > 0.0 {
                self.requests as f64 / wall
            } else {
                0.0
            },
            latency_p50_us: percentile(&self.latencies_us, 0.50),
            latency_p99_us: percentile(&self.latencies_us, 0.99),
            queue_wait_p50_us: percentile(&self.queue_waits_us, 0.50),
            mean_batch: if self.batch_sizes.is_empty() {
                0.0
            } else {
                self.batch_sizes.iter().sum::<usize>() as f64
                    / self.batch_sizes.len() as f64
            },
        }
    }
}

/// A point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    /// Number of closed batches executed (requests / batches = exact
    /// mean occupancy over any interval, via deltas).
    pub batches: u64,
    pub rejected: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub queue_wait_p50_us: u64,
    pub mean_batch: f64,
}

impl MetricsReport {
    /// JSON shape served by the v2 `metrics` verb.
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("requests", Value::Int(self.requests as i64)),
            ("batches", Value::Int(self.batches as i64)),
            ("rejected", Value::Int(self.rejected as i64)),
            ("errors", Value::Int(self.errors as i64)),
            ("throughput_rps", Value::Float(self.throughput_rps)),
            ("latency_p50_us", Value::Int(self.latency_p50_us as i64)),
            ("latency_p99_us", Value::Int(self.latency_p99_us as i64)),
            ("queue_wait_p50_us", Value::Int(self.queue_wait_p50_us as i64)),
            ("mean_batch", Value::Float(self.mean_batch)),
        ])
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency: Duration, queue_wait: Duration) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.latencies_us.push(latency.as_micros() as u64);
        g.queue_waits_us.push(queue_wait.as_micros() as u64);
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size);
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn report(&self) -> MetricsReport {
        // snapshot under the lock, sort outside it: the v2 `metrics`
        // verb makes reports remotely triggerable, and sorting a large
        // reservoir must not stall `record_request` on the serving path
        self.snapshot().report()
    }

    fn snapshot(&self) -> Inner {
        self.inner.lock().unwrap().clone()
    }
}

/// Per-model metrics registry with an exact aggregate rollup.
#[derive(Debug, Default)]
pub struct MetricsHub {
    models: Mutex<BTreeMap<String, Arc<Metrics>>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// The [`Metrics`] for model `id`, created on first use. Ids persist
    /// for the hub's lifetime so retired versions still roll up.
    pub fn for_model(&self, id: &str) -> Arc<Metrics> {
        self.models
            .lock()
            .unwrap()
            .entry(id.to_string())
            .or_default()
            .clone()
    }

    /// Per-model reports, sorted by model id. The hub lock is held only
    /// to clone the `Arc`s — the per-model snapshot/sort (O(reservoir))
    /// runs after it is released, so a remote `metrics` request cannot
    /// stall `for_model` (lazy loads, hot reloads).
    pub fn reports(&self) -> Vec<(String, MetricsReport)> {
        let handles: Vec<(String, Arc<Metrics>)> = self
            .models
            .lock()
            .unwrap()
            .iter()
            .map(|(id, m)| (id.clone(), m.clone()))
            .collect();
        handles
            .into_iter()
            .map(|(id, m)| (id, m.report()))
            .collect()
    }

    /// Exact rollup across every model ever served by this hub.
    pub fn aggregate(&self) -> MetricsReport {
        let snapshots: Vec<Inner> = self
            .models
            .lock()
            .unwrap()
            .values()
            .map(|m| m.snapshot())
            .collect();
        let mut acc = Inner::default();
        for s in &snapshots {
            acc.merge(s);
        }
        acc.report()
    }
}

/// Transport-level counters for the TCP endpoint: per-protocol-version
/// request counts, connection lifecycle, and the per-connection
/// pipelining high-water mark. One instance per
/// [`TcpServer`](super::tcp::TcpServer); surfaced over the wire by the
/// v2 `metrics` verb (the `"wire"` section).
#[derive(Debug, Default)]
pub struct WireMetrics {
    v1_requests: AtomicU64,
    v2_requests: AtomicU64,
    v2_rows: AtomicU64,
    v2_control: AtomicU64,
    connections_opened: AtomicU64,
    connections_closed: AtomicU64,
    in_flight_hwm: AtomicU64,
    oversized: AtomicU64,
    protocol_errors: AtomicU64,
}

impl WireMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_v1_request(&self) {
        self.v1_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One v2 inference request carrying `rows` feature rows (1 for
    /// `infer`, the batch size for `infer_batch`).
    pub fn record_v2_infer(&self, rows: u64) {
        self.v2_requests.fetch_add(1, Ordering::Relaxed);
        self.v2_rows.fetch_add(rows, Ordering::Relaxed);
    }

    pub fn record_v2_control(&self) {
        self.v2_control.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connection_opened(&self) {
        self.connections_opened.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connection_closed(&self) {
        self.connections_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an observed per-connection in-flight depth; keeps the max.
    pub fn observe_in_flight(&self, depth: u64) {
        self.in_flight_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn record_oversized(&self) {
        self.oversized.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn active_connections(&self) -> u64 {
        let opened = self.connections_opened.load(Ordering::Relaxed);
        let closed = self.connections_closed.load(Ordering::Relaxed);
        opened.saturating_sub(closed)
    }

    /// JSON shape of the `"wire"` section of the `metrics` verb.
    pub fn to_value(&self) -> Value {
        let int = |a: &AtomicU64| Value::Int(a.load(Ordering::Relaxed) as i64);
        obj(vec![
            ("v1_requests", int(&self.v1_requests)),
            ("v2_requests", int(&self.v2_requests)),
            ("v2_rows", int(&self.v2_rows)),
            ("v2_control", int(&self.v2_control)),
            ("connections_total", int(&self.connections_opened)),
            ("connections_active", Value::Int(self.active_connections() as i64)),
            ("in_flight_hwm", int(&self.in_flight_hwm)),
            ("oversized", int(&self.oversized)),
            ("protocol_errors", int(&self.protocol_errors)),
        ])
    }
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn report_aggregates() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record_request(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
            );
        }
        m.record_batch(4);
        m.record_batch(6);
        m.record_rejection();
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.mean_batch, 5.0);
        assert!(r.latency_p50_us >= 100);
        assert!(r.latency_p99_us >= r.latency_p50_us);
    }

    #[test]
    fn hub_rolls_up_across_models() {
        let hub = MetricsHub::new();
        let a = hub.for_model("kan1@1");
        let b = hub.for_model("kan2@1");
        for _ in 0..3 {
            a.record_request(Duration::from_micros(100), Duration::from_micros(1));
        }
        b.record_request(Duration::from_micros(900), Duration::from_micros(1));
        b.record_error();

        let reports = hub.reports();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].0, "kan1@1");
        assert_eq!(reports[0].1.requests, 3);
        assert_eq!(reports[1].1.errors, 1);

        let agg = hub.aggregate();
        assert_eq!(agg.requests, 4);
        assert_eq!(agg.errors, 1);
        // merged reservoir: p50 of [100,100,100,900] is 100, not 500
        assert_eq!(agg.latency_p50_us, 100);
    }

    #[test]
    fn report_counts_batches() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(6);
        let r = m.report();
        assert_eq!(r.batches, 2);
        let v = r.to_value();
        assert_eq!(v.get("batches").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("mean_batch").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn wire_metrics_counters() {
        let w = WireMetrics::new();
        w.connection_opened();
        w.connection_opened();
        w.connection_closed();
        w.record_v1_request();
        w.record_v2_infer(1);
        w.record_v2_infer(16);
        w.record_v2_control();
        w.observe_in_flight(3);
        w.observe_in_flight(9);
        w.observe_in_flight(5);
        w.record_oversized();
        let v = w.to_value();
        assert_eq!(v.get("v1_requests").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("v2_requests").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("v2_rows").unwrap().as_i64().unwrap(), 17);
        assert_eq!(v.get("v2_control").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("connections_total").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("connections_active").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("in_flight_hwm").unwrap().as_i64().unwrap(), 9);
        assert_eq!(v.get("oversized").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn hub_returns_same_instance_per_id() {
        let hub = MetricsHub::new();
        let a1 = hub.for_model("m@1");
        let a2 = hub.for_model("m@1");
        a1.record_rejection();
        assert_eq!(a2.report().rejected, 1);
    }
}
