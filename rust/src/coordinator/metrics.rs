//! Serving metrics: latency percentiles, throughput, batch occupancy.
//!
//! Lock-free on the hot path is unnecessary at edge request rates; a
//! mutexed reservoir keeps the code simple and the report exact.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u64>,
    queue_waits_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    requests: u64,
    rejected: u64,
    errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A point-in-time metrics report.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: u64,
    pub rejected: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub queue_wait_p50_us: u64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency: Duration, queue_wait: Duration) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = Some(now);
        g.latencies_us.push(latency.as_micros() as u64);
        g.queue_waits_us.push(queue_wait.as_micros() as u64);
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size);
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn report(&self) -> MetricsReport {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let mut qw = g.queue_waits_us.clone();
        qw.sort_unstable();
        let wall = match (g.started, g.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        MetricsReport {
            requests: g.requests,
            rejected: g.rejected,
            errors: g.errors,
            throughput_rps: if wall > 0.0 { g.requests as f64 / wall } else { 0.0 },
            latency_p50_us: percentile(&lat, 0.50),
            latency_p99_us: percentile(&lat, 0.99),
            queue_wait_p50_us: percentile(&qw, 0.50),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
            },
        }
    }
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).floor() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn report_aggregates() {
        let m = Metrics::new();
        for i in 0..10 {
            m.record_request(
                Duration::from_micros(100 + i * 10),
                Duration::from_micros(5),
            );
        }
        m.record_batch(4);
        m.record_batch(6);
        m.record_rejection();
        let r = m.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.mean_batch, 5.0);
        assert!(r.latency_p50_us >= 100);
        assert!(r.latency_p99_us >= r.latency_p50_us);
    }
}
