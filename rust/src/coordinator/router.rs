//! Backend construction + routing: turn config + artifacts into running
//! [`ExecutionSession`]s.
//!
//! The two-stage API (`docs/BACKENDS.md`): a [`BackendKind`] is parsed
//! once at config load / the wire boundary, and a [`BackendFactory`]
//! compiles `(manifest entry, kind)` into an [`ExecutionSession`]
//! carrying its [`BackendSpec`](super::backend::BackendSpec) capability
//! descriptor. Single-model serving calls [`build_session`] directly;
//! multi-model serving goes through [`crate::registry::ModelRegistry`],
//! which owns a factory and gives each compiled session its own dynamic
//! batcher + worker pool.
//!
//! ACIM builds need per-layer interval-occupancy statistics for the
//! KAN-SAM mapping. Those are expensive (a full calibration-set forward
//! per layer), so the factory caches them by weights digest: a registry
//! hot reload — or building an ACIM mirror next to a digital primary —
//! never repays calibration for unchanged weights. Calibration
//! activations propagate in **f64** end-to-end: the pre-v2 code
//! truncated each layer's outputs through `f32`, the same double
//! rounding PR 4 removed from serving, so calibration-time interval
//! occupancy could disagree with serve-time codes at level boundaries.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::backend::{
    AcimSession, BackendKind, DigitalSession, ExecutionSession, MlpSession, PjrtSession,
};
use super::batcher::BatchPolicy;
use super::scheduler::{SchedMode, SchedulerOptions};
use super::server::ServeOptions;
use super::tcp::TcpLimits;
use crate::util::sync::LockExt;
use crate::acim::{AcimModel, AcimOptions};
use crate::baseline::MlpModel;
use crate::config::AppConfig;
use crate::error::{Error, Result};
use crate::kan::checkpoint::{Dataset, Manifest};
use crate::kan::QuantKanModel;
use crate::mapping::{self, MappingStrategy};

/// Translate the file-side server config into runtime [`ServeOptions`].
pub fn serve_options(cfg: &AppConfig) -> ServeOptions {
    ServeOptions {
        policy: BatchPolicy {
            max_batch: cfg.server.max_batch,
            deadline: std::time::Duration::from_micros(cfg.server.batch_deadline_us),
        },
        queue_depth: cfg.server.queue_depth,
        workers: cfg.server.workers,
        scheduler: SchedulerOptions {
            // config validation rejects anything but fifo | drr
            mode: if cfg.scheduler.policy == "drr" {
                SchedMode::Drr
            } else {
                SchedMode::Fifo
            },
            client_quota: cfg.scheduler.quota,
            fairness_window: cfg.scheduler.fairness_window,
        },
    }
}

/// Translate the file-side server config into transport [`TcpLimits`].
pub fn tcp_limits(cfg: &AppConfig) -> TcpLimits {
    TcpLimits {
        max_request_bytes: cfg.server.max_request_bytes,
        max_in_flight: cfg.server.max_in_flight,
    }
}

/// Build the request-trace hub from the `[observability]` section.
pub fn trace_hub(cfg: &AppConfig) -> Arc<crate::obs::trace::TraceHub> {
    Arc::new(crate::obs::trace::TraceHub::new(
        cfg.observability.sample_every,
        cfg.observability.trace_ring,
    ))
}

/// Compiles manifest entries into execution sessions, caching the
/// expensive intermediate products (per-layer calibration occupancy)
/// across builds.
pub struct BackendFactory {
    cfg: AppConfig,
    dir: PathBuf,
    /// Per-layer interval-occupancy statistics keyed by weights digest:
    /// hot reloads and mirror builds of unchanged weights skip the full
    /// calibration propagation.
    occupancy: Mutex<HashMap<String, Arc<Vec<Vec<f64>>>>>,
}

impl BackendFactory {
    pub fn new(cfg: &AppConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            dir: PathBuf::from(&cfg.artifacts.dir),
            occupancy: Mutex::new(HashMap::new()),
        }
    }

    /// Compile `model` (a manifest entry) into a session executing
    /// `kind`. MLP artifacts always execute the float MLP path —
    /// requesting `mlp` on a KAN artifact (or a KAN kind on an MLP
    /// artifact's weights) fails when the checkpoint cannot back it.
    pub fn build(
        &self,
        manifest: &Manifest,
        model: &str,
        kind: BackendKind,
    ) -> Result<Arc<dyn ExecutionSession>> {
        let entry = manifest
            .models
            .get(model)
            .ok_or_else(|| Error::Artifact(format!("model '{model}' not in manifest")))?;

        if entry.kind == "mlp" || kind == BackendKind::Mlp {
            if entry.kind != "mlp" {
                return Err(Error::Artifact(format!(
                    "model '{model}' is a '{}' artifact; the mlp backend needs \
                     mlp weights",
                    entry.kind
                )));
            }
            let mlp = MlpModel::load(self.dir.join(&entry.weights))?;
            return Ok(Arc::new(MlpSession { model: Arc::new(mlp) }));
        }

        match kind {
            // the mlp branch above returned for both mlp cases; a
            // fall-through is a routing bug, surfaced as a structured
            // error rather than a panic on the serving path
            BackendKind::Mlp => Err(Error::Runtime(format!(
                "backend routing bug: mlp fell through for model '{model}'"
            ))),
            BackendKind::Pjrt => {
                let batch = self.cfg.server.max_batch;
                // use the largest compiled batch <= configured max
                let mut pairs: Vec<(usize, &String)> =
                    entry.hlo.iter().map(|(&s, f)| (s, f)).collect();
                pairs.sort_unstable_by_key(|&(s, _)| s);
                let (chosen, file) = pairs
                    .iter()
                    .rev()
                    .find(|&&(s, _)| s <= batch)
                    .or(pairs.first())
                    .copied()
                    .ok_or_else(|| {
                        Error::Artifact(format!("model '{model}' has no HLO"))
                    })?;
                let (&in_dim, &out_dim) = entry
                    .dims
                    .first()
                    .zip(entry.dims.last())
                    .ok_or_else(|| {
                        Error::Artifact(format!("model '{model}' has empty dims"))
                    })?;
                let session = PjrtSession::spawn(
                    self.dir.join(file),
                    chosen,
                    in_dim,
                    out_dim,
                    model.to_string(),
                )?;
                Ok(Arc::new(session))
            }
            BackendKind::Digital => {
                let qk = QuantKanModel::load(self.dir.join(&entry.weights))?;
                Ok(Arc::new(DigitalSession::with_engine_profiled(
                    Arc::new(qk),
                    self.cfg.server.engine,
                    self.cfg.observability.engine_profiling,
                )))
            }
            BackendKind::Acim => {
                let (acim, _qk) = self.build_acim_pair(manifest, model)?;
                Ok(Arc::new(AcimSession::new(acim, model.to_string())))
            }
        }
    }

    /// Build the programmed ACIM simulator for `model` together with the
    /// digital reference it was programmed from — the pair the shadow
    /// mirror needs for per-layer divergence attribution.
    pub fn build_acim_pair(
        &self,
        manifest: &Manifest,
        model: &str,
    ) -> Result<(Arc<AcimModel>, Arc<QuantKanModel>)> {
        let entry = manifest
            .models
            .get(model)
            .ok_or_else(|| Error::Artifact(format!("model '{model}' not in manifest")))?;
        let weights_path = self.dir.join(&entry.weights);
        let qk = Arc::new(QuantKanModel::load(&weights_path)?);
        let occupancy = self.occupancy_for(&qk, &weights_path)?;
        let opts = self.cfg.hardware.acim;
        let mappings: Vec<Vec<usize>> = occupancy
            .iter()
            .map(|probs| {
                mapping::build_mapping(probs, opts.array.rows, MappingStrategy::Sam)
            })
            .collect();
        let acim = AcimModel::program(&qk, opts, &mappings)?;
        Ok((Arc::new(acim), qk))
    }

    /// Per-layer occupancy statistics for `model`, cached by weights
    /// digest. Prefers the artifact calibration set; a registry without
    /// one (synthetic/bench deployments) falls back to the centered-
    /// Gaussian prior — the same fallback the engine plan uses.
    fn occupancy_for(
        &self,
        model: &QuantKanModel,
        weights_path: &Path,
    ) -> Result<Arc<Vec<Vec<f64>>>> {
        let key = crate::registry::digest_file(weights_path)?;
        if let Some(hit) = self.occupancy.lock_recover().get(&key) {
            return Ok(hit.clone());
        }
        // compute outside the lock: calibration propagation is the slow
        // part, and a concurrent identical build just recomputes
        let probs = match Dataset::load(&self.dir) {
            Ok(ds) => layer_occupancy(model, &ds),
            Err(_) => model
                .layers
                .iter()
                .map(|l| mapping::gaussian(l, 0.0, 0.5))
                .collect(),
        };
        let arc = Arc::new(probs);
        self.occupancy
            .lock_recover()
            .entry(key)
            .or_insert_with(|| arc.clone());
        Ok(arc)
    }

    /// Number of cached occupancy entries (test hook for the
    /// calibrate-once contract).
    pub fn occupancy_cache_len(&self) -> usize {
        self.occupancy.lock_recover().len()
    }

    /// Build the mirror executor for shadow serving `model` on `kind`.
    ///
    /// The ACIM mirror compares at two granularities: the full analog
    /// forward against the served logits (argmax flip, logit MAE), and
    /// each layer's analog output against the digital golden output *for
    /// the same layer inputs* — isolating per-layer partial-sum error
    /// (the paper's non-ideal-effect statistic) from compounded drift.
    /// Any other mirror kind compares final logits only.
    pub fn build_shadow_exec(
        &self,
        manifest: &Manifest,
        model: &str,
        kind: BackendKind,
    ) -> Result<super::shadow::ShadowExec> {
        use super::backend::{argmax_f32, trial_seed};
        use super::shadow::ShadowObservation;
        use crate::acim::NoiseModel;

        fn mae32(a: &[f32], b: &[f32]) -> f64 {
            if a.is_empty() {
                return 0.0;
            }
            a.iter()
                .zip(b)
                .map(|(x, y)| (*x as f64 - *y as f64).abs())
                .sum::<f64>()
                / a.len() as f64
        }

        if kind != BackendKind::Acim {
            let session = self.build(manifest, model, kind)?;
            return Ok(Box::new(move |job| {
                let out = session.run(vec![job.features.clone()], &[job.opts])?;
                let mirror = &out[0].logits;
                Ok(ShadowObservation {
                    flip: argmax_f32(mirror) != argmax_f32(&job.primary),
                    mae: mae32(mirror, &job.primary),
                    layer_err: Vec::new(),
                })
            }));
        }

        let (acim, digital) = self.build_acim_pair(manifest, model)?;
        // draw counter for unseeded jobs (embedded callers that skip the
        // wire edge's seed resolution): without it every such mirrored
        // row would replay one frozen noise realization and the
        // divergence statistics would measure a single draw instead of
        // the distribution. The worker thread owns the closure, so a
        // plain counter suffices.
        let mut unseeded: u64 = 0;
        Ok(Box::new(move |job| {
            // same seed derivation as AcimSession trial 0: an explicitly
            // seeded request's mirror run is reproducible offline
            let base = job.opts.seed.unwrap_or_else(|| {
                unseeded += 1;
                crate::util::rng::mix(acim.opts.seed ^ 0x77, unseeded)
            });
            let mut noise = NoiseModel::from_config(trial_seed(base, 0), &acim.opts.array);
            let mirror64 = acim.forward(&job.features, &mut noise);
            let mirror: Vec<f32> = mirror64.iter().map(|&v| v as f32).collect();

            // per-layer partial-sum error: feed each analog layer the
            // *golden* activations so errors do not compound across layers
            let mut layer_err = Vec::with_capacity(acim.layers.len());
            let mut h: Vec<f64> =
                job.features.iter().map(|&v| v as f64).collect();
            for (al, dl) in acim.layers.iter().zip(&digital.layers) {
                let xq: Vec<u32> = h.iter().map(|&v| dl.spec.quantize(v)).collect();
                let mut want = vec![0.0f64; dl.dout];
                dl.forward_digital(&xq, &mut want);
                let mut got = vec![0.0f64; al.dout];
                al.forward(&xq, &acim.opts, &mut noise, &mut got);
                let err = if want.is_empty() {
                    0.0
                } else {
                    want.iter()
                        .zip(&got)
                        .map(|(w, g)| (w - g).abs())
                        .sum::<f64>()
                        / want.len() as f64
                };
                layer_err.push(err);
                h = want; // golden path continues in f64
            }
            Ok(ShadowObservation {
                flip: argmax_f32(&mirror) != argmax_f32(&job.primary),
                mae: mae32(&mirror, &job.primary),
                layer_err,
            })
        }))
    }
}

/// Build the session named by `cfg.server.backend` for `model` — the
/// single-model entry point (transient factory, no cache reuse).
pub fn build_session(
    cfg: &AppConfig,
    manifest: &Manifest,
    model: &str,
) -> Result<Arc<dyn ExecutionSession>> {
    BackendFactory::new(cfg).build(manifest, model, cfg.server.backend)
}

/// Per-layer expected word-line drive (interval occupancy) over the
/// calibration set, with activations propagated in f64 end-to-end.
fn layer_occupancy(model: &QuantKanModel, ds: &Dataset) -> Vec<Vec<f64>> {
    // the dataset stores f32 rows — that is the true input precision;
    // everything after the first quantization stays f64
    let mut acts: Vec<Vec<f64>> = ds
        .calib_rows()
        .map(|r| r.iter().map(|&v| v as f64).collect())
        .collect();
    let mut probs = Vec::with_capacity(model.layers.len());
    for layer in &model.layers {
        probs.push(mapping::empirical(layer, acts.iter().map(|r| r.as_slice())));
        // next layer's calibration inputs = this layer's digital outputs,
        // kept in f64 (no inter-layer f32 double rounding)
        acts = acts
            .iter()
            .map(|r| {
                let xq: Vec<u32> =
                    r.iter().map(|&v| layer.spec.quantize(v)).collect();
                let mut out = vec![0.0; layer.dout];
                layer.forward_digital(&xq, &mut out);
                out
            })
            .collect();
    }
    probs
}

/// Program a quantized KAN onto the ACIM simulator with the given mapping
/// strategy (probabilities estimated from the artifact calibration set).
pub fn build_acim(
    model: &QuantKanModel,
    opts: AcimOptions,
    artifacts_dir: &Path,
    strategy: MappingStrategy,
) -> Result<AcimModel> {
    let ds = Dataset::load(artifacts_dir)?;
    build_acim_with_calib(model, opts, &ds, strategy)
}

/// Same as [`build_acim`] but with an explicit dataset (used by benches
/// and `kan-edge eval/sam`).
pub fn build_acim_with_calib(
    model: &QuantKanModel,
    opts: AcimOptions,
    ds: &Dataset,
    strategy: MappingStrategy,
) -> Result<AcimModel> {
    let mappings: Vec<Vec<usize>> = layer_occupancy(model, ds)
        .iter()
        .map(|probs| mapping::build_mapping(probs, opts.array.rows, strategy))
        .collect();
    AcimModel::program(model, opts, &mappings)
}
