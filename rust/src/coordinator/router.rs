//! Backend construction + routing: turn config + artifacts into a running
//! [`InferenceService`](super::server::InferenceService).
//!
//! Single-model serving calls [`build_backend`] directly; multi-model
//! serving goes through [`crate::registry::ModelRegistry`], which calls
//! back into [`build_backend`] per variant and gives each one its own
//! dynamic batcher + worker pool.

use std::path::Path;
use std::sync::Arc;

use super::backend::{AcimBackend, DigitalBackend, InferBackend, MlpBackend, PjrtBackend};
use super::batcher::BatchPolicy;
use super::scheduler::{SchedMode, SchedulerOptions};
use super::server::ServeOptions;
use super::tcp::TcpLimits;
use crate::acim::{AcimModel, AcimOptions};
use crate::baseline::MlpModel;
use crate::config::AppConfig;
use crate::error::{Error, Result};
use crate::kan::checkpoint::{Dataset, Manifest};
use crate::kan::QuantKanModel;
use crate::mapping::{self, MappingStrategy};

/// Translate the file-side server config into runtime [`ServeOptions`].
pub fn serve_options(cfg: &AppConfig) -> ServeOptions {
    ServeOptions {
        policy: BatchPolicy {
            max_batch: cfg.server.max_batch,
            deadline: std::time::Duration::from_micros(cfg.server.batch_deadline_us),
        },
        queue_depth: cfg.server.queue_depth,
        workers: cfg.server.workers,
        scheduler: SchedulerOptions {
            // config validation rejects anything but fifo | drr
            mode: if cfg.scheduler.policy == "drr" {
                SchedMode::Drr
            } else {
                SchedMode::Fifo
            },
            client_quota: cfg.scheduler.quota,
            fairness_window: cfg.scheduler.fairness_window,
        },
    }
}

/// Translate the file-side server config into transport [`TcpLimits`].
pub fn tcp_limits(cfg: &AppConfig) -> TcpLimits {
    TcpLimits {
        max_request_bytes: cfg.server.max_request_bytes,
        max_in_flight: cfg.server.max_in_flight,
    }
}

/// Build the backend named by `cfg.server.backend` for `model`.
pub fn build_backend(
    cfg: &AppConfig,
    manifest: &Manifest,
    model: &str,
) -> Result<Arc<dyn InferBackend>> {
    let dir = Path::new(&cfg.artifacts.dir);
    let entry = manifest
        .models
        .get(model)
        .ok_or_else(|| Error::Artifact(format!("model '{model}' not in manifest")))?;

    match (cfg.server.backend.as_str(), entry.kind.as_str()) {
        (_, "mlp") => {
            let mlp = MlpModel::load(dir.join(&entry.weights))?;
            Ok(Arc::new(MlpBackend { model: Arc::new(mlp) }))
        }
        ("pjrt", _) => {
            let batch = cfg.server.max_batch;
            // use the largest compiled batch <= configured max
            let mut sizes: Vec<usize> = entry.hlo.keys().copied().collect();
            sizes.sort_unstable();
            let chosen = sizes
                .iter()
                .rev()
                .find(|&&s| s <= batch)
                .or(sizes.first())
                .copied()
                .ok_or_else(|| Error::Artifact(format!("model '{model}' has no HLO")))?;
            let file = entry.hlo.get(&chosen).expect("chosen batch exists");
            let backend = PjrtBackend::spawn(
                dir.join(file),
                chosen,
                entry.dims[0],
                *entry.dims.last().unwrap(),
                model.to_string(),
            )?;
            Ok(Arc::new(backend))
        }
        ("digital", _) => {
            let qk = QuantKanModel::load(dir.join(&entry.weights))?;
            Ok(Arc::new(DigitalBackend::with_engine(
                Arc::new(qk),
                cfg.server.engine,
            )))
        }
        ("acim", _) => {
            let qk = QuantKanModel::load(dir.join(&entry.weights))?;
            let acim = build_acim(&qk, cfg.hardware.acim, dir, MappingStrategy::Sam)?;
            Ok(Arc::new(AcimBackend::new(Arc::new(acim), model.to_string())))
        }
        (other, _) => Err(Error::Config(format!("unknown backend '{other}'"))),
    }
}

/// Program a quantized KAN onto the ACIM simulator with the given mapping
/// strategy (probabilities estimated from the artifact calibration set).
pub fn build_acim(
    model: &QuantKanModel,
    opts: AcimOptions,
    artifacts_dir: &Path,
    strategy: MappingStrategy,
) -> Result<AcimModel> {
    let ds = Dataset::load(artifacts_dir)?;
    build_acim_with_calib(model, opts, &ds, strategy)
}

/// Same as [`build_acim`] but with an explicit dataset (used by benches).
pub fn build_acim_with_calib(
    model: &QuantKanModel,
    opts: AcimOptions,
    ds: &Dataset,
    strategy: MappingStrategy,
) -> Result<AcimModel> {
    let mut mappings = Vec::new();
    // propagate calibration activations layer by layer to estimate each
    // layer's input distribution
    let mut acts: Vec<Vec<f32>> = ds.calib_rows().map(|r| r.to_vec()).collect();
    for layer in &model.layers {
        let probs = mapping::empirical(layer, acts.iter().cloned());
        mappings.push(mapping::build_mapping(&probs, opts.array.rows, strategy));
        // next layer's calibration inputs = this layer's digital outputs
        acts = acts
            .iter()
            .map(|r| {
                let xq = layer.quantize_input(r);
                let mut out = vec![0.0; layer.dout];
                layer.forward_digital(&xq, &mut out);
                out.iter().map(|&v| v as f32).collect()
            })
            .collect();
    }
    AcimModel::program(model, opts, &mappings)
}
