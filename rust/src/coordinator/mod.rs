//! L3 edge-inference serving runtime.
//!
//! Pipeline: fair admission ([`scheduler`]: FIFO or deficit-round-robin
//! with per-client quotas) → [`batcher`] (size/deadline dynamic
//! batching) → worker pool → [`backend`] (PJRT digital reference, rust
//! integer reference, ACIM analog simulator, or MLP baseline), with
//! [`metrics`] throughout and [`router`] turning config + artifacts into a
//! running [`server::InferenceService`].
//!
//! The wire surface is split in two layers: [`protocol`] defines the
//! typed v2 requests/responses and the frame codec, and [`tcp`] is the
//! transport — one port auto-detecting legacy v1 JSON lines and framed,
//! pipelined v2 per connection (`docs/PROTOCOL.md` is the spec). The
//! matching typed client lives in [`crate::client`].
//!
//! Multi-model serving layers on top: [`crate::registry::ModelRegistry`]
//! owns one such pipeline per live `name@version` variant and implements
//! [`server::Dispatch`], which the [`tcp`] endpoint routes to via the
//! request's optional `"model"` field — plus the v2 control plane
//! (`list_models`, `model_info`, `metrics`, `health`). Metrics are per
//! model ([`metrics::MetricsHub`]) with an exact aggregate rollup, and
//! per transport ([`metrics::WireMetrics`]).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod shadow;
pub mod tcp;

pub use backend::{
    AcimSession, BackendKind, BackendSpec, DigitalSession, ExecOptions,
    ExecutionSession, MlpSession, PjrtSession, RowOutput,
};
pub use batcher::{Batch, BatchPolicy, Request};
pub use metrics::{
    Metrics, MetricsHub, MetricsReport, ShadowMetrics, ShadowReport, WireMetrics,
};
pub use protocol::{BackendInfo, ErrorCode, ModelSummary, WireRow};
pub use router::{
    build_acim, build_acim_with_calib, build_session, serve_options, tcp_limits,
    BackendFactory,
};
pub use scheduler::{ClientId, SchedMode, Scheduler, SchedulerOptions};
pub use server::{Dispatch, InferenceService, RouteSpec, ServeOptions};
pub use shadow::{ShadowExec, ShadowJob, ShadowObservation, ShadowState};
pub use tcp::{NodeIdentity, TcpLimits, TcpServer};
