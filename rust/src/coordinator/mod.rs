//! L3 edge-inference serving runtime.
//!
//! Pipeline: admission control → [`batcher`] (size/deadline dynamic
//! batching) → worker pool → [`backend`] (PJRT digital reference, rust
//! integer reference, ACIM analog simulator, or MLP baseline), with
//! [`metrics`] throughout and [`router`] turning config + artifacts into a
//! running [`server::InferenceService`].
//!
//! Multi-model serving layers on top: [`crate::registry::ModelRegistry`]
//! owns one such pipeline per live `name@version` variant and implements
//! [`server::Dispatch`], which the [`tcp`] endpoint routes to via the
//! request's optional `"model"` field. Metrics are per model
//! ([`metrics::MetricsHub`]) with an exact aggregate rollup.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod tcp;

pub use backend::{AcimBackend, DigitalBackend, InferBackend, MlpBackend, PjrtBackend};
pub use batcher::{Batch, BatchPolicy, Request};
pub use metrics::{Metrics, MetricsHub, MetricsReport};
pub use router::{build_acim, build_acim_with_calib, build_backend, serve_options};
pub use server::{Dispatch, InferenceService, ServeOptions};
pub use tcp::TcpServer;
