//! The four serving-path rule families: lock discipline (ordering
//! cycles + guards held across blocking calls), panic policy, direct
//! indexing on the wire-facing set, and the hot-path allocation policy.

use super::facts::{fn_facts, Acquisition, FnFacts};
use super::report::Report;
use super::ScannedFile;
use std::collections::{BTreeMap, BTreeSet};

/// Serving-path modules policed by the panic and lock rules. Compute
/// modules (kan/, acim/, quant/, …) are exempt: they run under the
/// coordinator which catches nothing — panics there are caught by the
/// engine test suite, not by request traffic.
fn policed(rel_src: &str) -> bool {
    ["coordinator/", "cluster/", "registry/", "rollout/", "obs/"]
        .iter()
        .any(|d| rel_src.starts_with(d))
}

/// Identity of a lock: `file_stem.field_name`. Coarse by design — one
/// name per (file, field) pair is exactly the granularity the
/// coordinator/registry code uses for its mutexes.
fn lock_id(rel_src: &str, field: &str) -> String {
    let stem = rel_src
        .rsplit('/')
        .next()
        .unwrap_or(rel_src)
        .trim_end_matches(".rs");
    format!("{stem}.{field}")
}

/// Key for one function: (file index, fn index) into the scan set.
type FnKey = (usize, usize);

struct LockWorld<'a> {
    files: &'a [ScannedFile],
    facts: BTreeMap<FnKey, FnFacts>,
    /// Unique simple-name resolution: fn name -> its only definition.
    /// Ambiguous names are absent (documented limitation: calls to them
    /// are not traced inter-procedurally).
    unique: BTreeMap<String, FnKey>,
    may_acq: BTreeMap<FnKey, BTreeSet<String>>,
    may_blk: BTreeMap<FnKey, BTreeSet<String>>,
}

fn build_world(files: &[ScannedFile]) -> LockWorld<'_> {
    let mut facts = BTreeMap::new();
    let mut seen: BTreeMap<String, Vec<FnKey>> = BTreeMap::new();
    for (fi, file) in files.iter().enumerate() {
        for (gi, f) in file.fns.iter().enumerate() {
            if f.test {
                continue;
            }
            let key = (fi, gi);
            seen.entry(f.name.clone()).or_default().push(key);
            facts.insert(key, fn_facts(&file.lx, &file.braces, f));
        }
    }
    let unique: BTreeMap<String, FnKey> = seen
        .into_iter()
        .filter_map(|(n, ks)| (ks.len() == 1).then(|| (n, ks[0])))
        .collect();
    // seed the fixpoint with each function's direct facts
    let mut may_acq: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    let mut may_blk: BTreeMap<FnKey, BTreeSet<String>> = BTreeMap::new();
    for (&key, ff) in &facts {
        let rel = &files[key.0].rel_src;
        may_acq.insert(
            key,
            ff.acqs.iter().map(|a| lock_id(rel, &a.name)).collect(),
        );
        may_blk.insert(key, ff.blocks.iter().map(|b| b.2.clone()).collect());
    }
    // propagate through the call graph to fixpoint
    let mut changed = true;
    while changed {
        changed = false;
        let keys: Vec<FnKey> = facts.keys().copied().collect();
        for key in keys {
            let callees: Vec<FnKey> = facts[&key]
                .calls
                .iter()
                .filter_map(|(n, _)| self::resolve(&unique, n, key))
                .collect();
            for tgt in callees {
                let (acq, blk) = (may_acq[&tgt].clone(), may_blk[&tgt].clone());
                let a = may_acq.get_mut(&key).expect("seeded");
                if !acq.is_subset(a) {
                    a.extend(acq);
                    changed = true;
                }
                let b = may_blk.get_mut(&key).expect("seeded");
                if !blk.is_subset(b) {
                    b.extend(blk);
                    changed = true;
                }
            }
        }
    }
    LockWorld { files, facts, unique, may_acq, may_blk }
}

fn resolve(unique: &BTreeMap<String, FnKey>, name: &str, caller: FnKey) -> Option<FnKey> {
    let tgt = *unique.get(name)?;
    (tgt != caller).then_some(tgt)
}

/// Lock-discipline rule: build the inter-procedural lock graph over the
/// policed modules, flag order cycles, and flag guards held across
/// blocking channel/socket/thread waits (direct or through calls).
pub fn lock_rule(files: &[ScannedFile], report: &mut Report) {
    let world = build_world(files);
    // edges: held-lock -> acquired-while-held, with a witness site
    let mut edges: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut witness: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    for (&key, ff) in &world.facts {
        let file = &world.files[key.0];
        if !policed(&file.rel_src) {
            continue;
        }
        for a in &ff.acqs {
            let held = lock_id(&file.rel_src, &a.name);
            check_extent(&world, key, ff, a, &held, report);
            // nested direct acquisitions
            for b in &ff.acqs {
                if b.idx > a.ext_start && b.idx <= a.ext_end && b.idx != a.idx {
                    let tgt = lock_id(&file.rel_src, &b.name);
                    if tgt != held {
                        edges.entry(held.clone()).or_default().insert(tgt.clone());
                        witness
                            .entry((held.clone(), tgt))
                            .or_insert_with(|| (file.rel.clone(), b.line));
                    }
                }
            }
            // acquisitions reached through calls inside the extent
            for (cn, ci) in &ff.calls {
                if !(*ci > a.ext_start && *ci <= a.ext_end) {
                    continue;
                }
                let Some(tgt) = resolve(&world.unique, cn, key) else { continue };
                for lid in &world.may_acq[&tgt] {
                    if lid != &held {
                        edges
                            .entry(held.clone())
                            .or_default()
                            .insert(lid.clone());
                        witness
                            .entry((held.clone(), lid.clone()))
                            .or_insert_with(|| (file.rel.clone(), file.lx.line(*ci)));
                    }
                }
            }
        }
    }
    if let Some(cycle) = find_cycle(&edges) {
        let w = witness
            .get(&(cycle[0].clone(), cycle[1].clone()))
            .cloned()
            .unwrap_or_else(|| ("rust/src".into(), 0));
        report.report(
            "lock-cycle",
            &w.0,
            w.1,
            format!("lock order cycle: {}", cycle.join(" -> ")),
        );
    }
}

/// Blocking calls inside one guard's extent (direct + through calls).
fn check_extent(
    world: &LockWorld<'_>,
    key: FnKey,
    ff: &FnFacts,
    a: &Acquisition,
    held: &str,
    report: &mut Report,
) {
    let file = &world.files[key.0];
    for (bi, bline, what) in &ff.blocks {
        if *bi > a.ext_start && *bi <= a.ext_end {
            report.report(
                "lock-blocking",
                &file.rel,
                *bline,
                format!(
                    "guard `{held}` (acquired line {}) held across blocking `{what}()`",
                    a.line
                ),
            );
        }
    }
    for (cn, ci) in &ff.calls {
        if !(*ci > a.ext_start && *ci <= a.ext_end) {
            continue;
        }
        let Some(tgt) = resolve(&world.unique, cn, key) else { continue };
        for what in &world.may_blk[&tgt] {
            report.report(
                "lock-blocking",
                &file.rel,
                file.lx.line(*ci),
                format!(
                    "guard `{held}` (line {}) held across call `{cn}()` \
                     which may block on `{what}`",
                    a.line
                ),
            );
        }
    }
}

/// First lock-order cycle in the edge set, as the node sequence
/// `a -> b -> ... -> a`, or `None` when the graph is acyclic.
fn find_cycle(edges: &BTreeMap<String, BTreeSet<String>>) -> Option<Vec<String>> {
    // iterative DFS with tri-color marking, deterministic via BTreeMap
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Grey,
        Black,
    }
    let mut color: BTreeMap<&str, Color> = BTreeMap::new();
    fn dfs<'a>(
        u: &'a str,
        edges: &'a BTreeMap<String, BTreeSet<String>>,
        color: &mut BTreeMap<&'a str, Color>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(u, Color::Grey);
        stack.push(u);
        for v in edges.get(u).into_iter().flatten() {
            match color.get(v.as_str()).copied().unwrap_or(Color::White) {
                Color::Grey => {
                    let pos =
                        stack.iter().position(|s| *s == v.as_str()).unwrap_or(0);
                    let mut cyc: Vec<String> =
                        stack[pos..].iter().map(|s| s.to_string()).collect();
                    cyc.push(v.clone());
                    return Some(cyc);
                }
                Color::White => {
                    if let Some(c) = dfs(v.as_str(), edges, color, stack) {
                        return Some(c);
                    }
                }
                Color::Black => {}
            }
        }
        stack.pop();
        color.insert(u, Color::Black);
        None
    }
    for u in edges.keys() {
        if color.get(u.as_str()).copied().unwrap_or(Color::White) == Color::White {
            let mut stack = Vec::new();
            if let Some(c) = dfs(u, edges, &mut color, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Panic-policy rule over the serving-path modules: no panic-family
/// macros, no `.unwrap()`/`.expect()` outside tests. A bare unwrap
/// directly on a lock/condvar acquisition is reported as the distinct
/// `poison` rule (the fix is the `util::sync` recover helpers, not an
/// error return).
pub fn panic_rule(files: &[ScannedFile], report: &mut Report) {
    for file in files {
        if !policed(&file.rel_src) {
            continue;
        }
        let lx = &file.lx;
        for f in &file.fns {
            if f.test {
                continue;
            }
            for i in f.body_open..f.body_close {
                if lx.kind(i) != Some(super::lexer::TokKind::Id) {
                    continue;
                }
                let t = lx.s(i);
                if PANIC_MACROS.contains(&t) && lx.is_punct(i + 1, "!") {
                    report.report(
                        "panic",
                        &file.rel,
                        lx.line(i),
                        format!("`{t}!` on serving path"),
                    );
                }
                if (t == "unwrap" || t == "expect") && i > 0 && lx.is_punct(i - 1, ".") {
                    let t = t.to_string();
                    if is_poison_unwrap(file, i) {
                        report.report(
                            "poison",
                            &file.rel,
                            lx.line(i),
                            format!(
                                "bare poison-`{t}` on lock acquisition \
                                 (use util::sync recover helpers)"
                            ),
                        );
                    } else {
                        report.report(
                            "panic",
                            &file.rel,
                            lx.line(i),
                            format!("`.{t}()` on serving path"),
                        );
                    }
                }
            }
        }
    }
}

/// Is `.unwrap()` at token `i` applied directly to a lock/condvar
/// acquisition result (`.lock().unwrap()`, `cv.wait(g).unwrap()`)?
fn is_poison_unwrap(file: &ScannedFile, i: usize) -> bool {
    let lx = &file.lx;
    // token before the `.` must be the `)` closing the receiver call
    if i < 2 || !lx.is_punct(i - 2, ")") {
        return false;
    }
    // scan back to the matching `(` and read the callee
    let mut depth = 1usize;
    let mut j = i - 2;
    while j > 0 && depth > 0 {
        j -= 1;
        let t = lx.s(j);
        if t == ")" {
            depth += 1;
        } else if t == "(" {
            depth -= 1;
        }
    }
    if j == 0 || depth != 0 {
        return false;
    }
    let callee = lx.s(j - 1);
    let empty = lx.is_punct(j + 1, ")");
    match callee {
        // RwLock/Mutex ops take no args; io::Read::read does
        "lock" | "read" | "write" => empty,
        "wait" | "wait_timeout" | "wait_while" => true,
        _ => false,
    }
}

/// Files whose `fn`s are policed for direct `[...]` indexing: the
/// wire-facing set, where every index is driven by request-derived
/// data and a slip is a remote panic trigger.
const INDEX_FILES: &[&str] = &["coordinator/protocol.rs", "coordinator/tcp.rs"];

/// Keywords that can directly precede a `[` that is an array literal
/// or pattern, not an indexing expression.
const INDEX_KEYWORDS: &[&str] = &[
    "in", "return", "break", "continue", "else", "match", "if", "while", "loop",
    "move", "mut", "ref", "as", "let",
];

pub fn index_rule(files: &[ScannedFile], report: &mut Report) {
    use super::lexer::TokKind;
    for file in files {
        if !INDEX_FILES.contains(&file.rel_src.as_str()) {
            continue;
        }
        let lx = &file.lx;
        for f in &file.fns {
            if f.test {
                continue;
            }
            for i in f.body_open..f.body_close {
                if !lx.is_punct(i, "[") || i == 0 {
                    continue;
                }
                let pk = lx.kind(i - 1);
                let pt = lx.s(i - 1);
                let indexing = (pk == Some(TokKind::Id)
                    && !INDEX_KEYWORDS.contains(&pt))
                    || (pk == Some(TokKind::Punct) && (pt == ")" || pt == "]"));
                if !indexing {
                    continue;
                }
                // `&x[..]` full-range reborrow cannot panic
                if lx.is_punct(i + 1, ".")
                    && lx.is_punct(i + 2, ".")
                    && lx.is_punct(i + 3, "]")
                {
                    continue;
                }
                report.report(
                    "index",
                    &file.rel,
                    lx.line(i),
                    format!("direct indexing in `{}`", f.name),
                );
            }
        }
    }
}

/// Hot-path allocation policy: the engine steady-state functions and
/// the kernels must not allocate per row/batch — scratch is provided by
/// the caller. `(file, policed fn names)`; `None` = every fn.
fn hot_fns(rel_src: &str) -> Option<Option<&'static [&'static str]>> {
    match rel_src {
        "kan/engine.rs" => Some(Some(&["forward_into", "forward_rows", "forward_block"])),
        "kan/plan.rs" => Some(Some(&["accumulate_batch", "finish_batch_row"])),
        "kan/kernels.rs" => Some(None),
        _ => None,
    }
}

const ALLOC_METHODS: &[&str] =
    &["to_vec", "to_string", "to_owned", "clone", "collect", "with_capacity"];
const ALLOC_TYPES: &[&str] = &["Vec", "String", "Box", "BTreeMap", "HashMap"];

pub fn alloc_rule(files: &[ScannedFile], report: &mut Report) {
    use super::lexer::TokKind;
    for file in files {
        let Some(only) = hot_fns(&file.rel_src) else { continue };
        let lx = &file.lx;
        for f in &file.fns {
            if f.test {
                continue;
            }
            if let Some(names) = only {
                if !names.contains(&f.name.as_str()) {
                    continue;
                }
            }
            for i in f.body_open..f.body_close {
                if lx.kind(i) != Some(TokKind::Id) {
                    continue;
                }
                let t = lx.s(i);
                if (t == "format" || t == "vec") && lx.is_punct(i + 1, "!") {
                    report.report(
                        "alloc",
                        &file.rel,
                        lx.line(i),
                        format!("`{t}!` in hot path `{}`", f.name),
                    );
                }
                if ALLOC_TYPES.contains(&t) && lx.is_punct(i + 1, ":") {
                    report.report(
                        "alloc",
                        &file.rel,
                        lx.line(i),
                        format!("`{t}::` constructor in hot path `{}`", f.name),
                    );
                }
                if ALLOC_METHODS.contains(&t)
                    && i > 0
                    && lx.is_punct(i - 1, ".")
                    && lx.is_punct(i + 1, "(")
                {
                    report.report(
                        "alloc",
                        &file.rel,
                        lx.line(i),
                        format!("`.{t}()` in hot path `{}`", f.name),
                    );
                }
            }
        }
    }
}

/// The policed-module prefixes, for the CLI's self-description.
pub fn policed_dirs() -> &'static [&'static str] {
    &["coordinator/", "cluster/", "registry/", "rollout/", "obs/"]
}
