//! Per-function fact extraction over the token stream: function body
//! spans (with `#[cfg(test)]` / `#[test]` code excluded from policed
//! rules), lock-guard acquisition sites, the token extent each guard is
//! held over, direct blocking calls, and plain call sites for the
//! inter-procedural fixpoint in the lock rule.

use super::lexer::{Lexed, TokKind};
use std::collections::HashMap;

/// One `fn` item: token-index span of its body plus metadata.
#[derive(Clone, Debug)]
pub struct FnInfo {
    pub name: String,
    pub body_open: usize,
    pub body_close: usize,
    pub line: u32,
    /// Inside a `#[cfg(test)]` module or under `#[test]` — exempt from
    /// every policed rule (tests are where unwrap is the right idiom).
    pub test: bool,
}

/// Guard acquisition kind: which primitive the method maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcqKind {
    Lock,
    Read,
    Write,
}

/// One guard acquisition: `recv.lock()` / `recv.lock_recover()` /
/// `recv.read()` / … at token `idx`, with the receiver's last field
/// name and the token extent the guard is live over.
#[derive(Clone, Debug)]
pub struct Acquisition {
    pub idx: usize,
    pub kind: AcqKind,
    pub name: String,
    pub line: u32,
    pub ext_start: usize,
    pub ext_end: usize,
}

/// Extracted facts for one function body.
pub struct FnFacts {
    pub acqs: Vec<Acquisition>,
    /// Direct blocking calls: (token idx, line, method name).
    pub blocks: Vec<(usize, u32, String)>,
    /// Plain call sites `name(`: (name, token idx) — fed to the
    /// inter-procedural fixpoint.
    pub calls: Vec<(String, usize)>,
}

/// Calls that can park the thread indefinitely while a guard is held.
/// Channel/socket waits are unbounded (the peer may never act), which
/// is what makes holding a lock across them a serving-path hazard;
/// bounded local file I/O is deliberately NOT here (atomic
/// publish-under-lock is a legitimate registry idiom). `Condvar::wait`
/// is also absent: it releases the lock while parked.
const BLOCKING: &[&str] = &[
    "send",
    "recv",
    "recv_timeout",
    "join",
    "accept",
    "connect",
    "read_exact",
    "write_all",
    "flush",
    "read_to_end",
    "sleep",
];

/// Receivers whose `.lock()` is std stream locking, not a Mutex.
const IO_RECEIVERS: &[&str] = &["stderr", "stdout", "stdin"];

/// Methods the lock rule treats as guard acquisitions (empty-args only:
/// `Read::read`/`Write::write` take buffer arguments, RwLock ops none).
fn acq_kind(meth: &str) -> Option<AcqKind> {
    match meth {
        "lock" | "lock_recover" => Some(AcqKind::Lock),
        "read" | "read_recover" => Some(AcqKind::Read),
        "write" | "write_recover" => Some(AcqKind::Write),
        _ => None,
    }
}

/// Matching brace indices (both directions) over the token stream.
pub fn match_braces(lx: &Lexed) -> HashMap<usize, usize> {
    let mut map = HashMap::new();
    let mut stack = Vec::new();
    for idx in 0..lx.toks.len() {
        if lx.is_punct(idx, "{") {
            stack.push(idx);
        } else if lx.is_punct(idx, "}") {
            if let Some(open) = stack.pop() {
                map.insert(open, idx);
                map.insert(idx, open);
            }
        }
    }
    map
}

/// Token-index spans covered by `#[cfg(test)]` modules or `#[test]`
/// functions: the attribute token through the close of the following
/// braced item.
fn test_spans(lx: &Lexed, braces: &HashMap<usize, usize>) -> Vec<(usize, usize)> {
    let n = lx.toks.len();
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < n {
        if lx.is_punct(i, "#") && lx.is_punct(i + 1, "[") {
            // collect the attribute text up to the matching ]
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut attr = String::new();
            while j < n && depth > 0 {
                let t = lx.s(j);
                if t == "[" {
                    depth += 1;
                } else if t == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                attr.push_str(t);
                j += 1;
            }
            if attr == "cfg(test)" || attr == "test" {
                // the next braced item closes the span
                let mut p = j + 1;
                while p < n && !(lx.is_punct(p, "{") || lx.is_punct(p, ";")) {
                    p += 1;
                }
                if p < n && lx.is_punct(p, "{") {
                    if let Some(&close) = braces.get(&p) {
                        spans.push((i, close));
                    }
                }
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    spans
}

/// Extract every `fn` item with a body from the token stream.
pub fn extract_functions(lx: &Lexed, braces: &HashMap<usize, usize>) -> Vec<FnInfo> {
    let n = lx.toks.len();
    let tests = test_spans(lx, braces);
    let in_test = |idx: usize| tests.iter().any(|&(a, b)| a <= idx && idx <= b);
    let mut fns = Vec::new();
    for idx in 0..n {
        if !lx.is_id(idx, "fn") || lx.kind(idx + 1) != Some(TokKind::Id) {
            continue;
        }
        let name = lx.s(idx + 1).to_string();
        // scan past the signature: the body `{` at paren depth 0, or a
        // trait-declaration `;`
        let mut p = idx + 2;
        let mut pdepth = 0i32;
        let mut body = None;
        while p < n {
            let t = lx.s(p);
            match t {
                "(" => pdepth += 1,
                ")" => pdepth -= 1,
                "{" if pdepth == 0 => {
                    body = Some(p);
                    break;
                }
                ";" if pdepth == 0 => break,
                _ => {}
            }
            p += 1;
        }
        let Some(body_open) = body else { continue };
        let Some(&body_close) = braces.get(&body_open) else { continue };
        fns.push(FnInfo {
            name,
            body_open,
            body_close,
            line: lx.line(idx),
            test: in_test(idx),
        });
    }
    fns
}

/// Last field-ish identifier of the receiver chain ending at the `.`
/// before an acquisition method, skipping call/index groups:
/// `self.inner.state[i].lock()` → `state`. Returns `None` for chains
/// that start with a call result and for std stream receivers.
fn receiver_name(lx: &Lexed, dot_idx: usize) -> Option<String> {
    let mut j = dot_idx;
    loop {
        if j == 0 {
            return None;
        }
        j -= 1;
        let t = lx.s(j);
        if lx.kind(j) == Some(TokKind::Punct) && (t == ")" || t == "]") {
            let (close, open) = if t == ")" { (")", "(") } else { ("]", "[") };
            let mut depth = 1usize;
            while j > 0 && depth > 0 {
                j -= 1;
                let tt = lx.s(j);
                if tt == close {
                    depth += 1;
                } else if tt == open {
                    depth -= 1;
                }
            }
            continue;
        }
        if lx.kind(j) == Some(TokKind::Id) {
            let name = lx.s(j);
            if IO_RECEIVERS.contains(&name) {
                return None;
            }
            return Some(name.to_string());
        }
        return None;
    }
}

/// The token extent a guard acquired at `acq_idx` is held over.
///
/// Three shapes, mirroring how Rust scopes temporaries:
/// * scrutinee of `if`/`while`/`match` — the guard lives through the
///   whole following block (scrutinee temporary extension);
/// * `let g = recv.lock()...;` where the chain (through
///   unwrap/expect/unwrap_or_else/map_err/`?`) IS the whole initializer
///   — held to the end of the enclosing block, truncated at `drop(g)`;
/// * anything else — a statement temporary, released at the `;`.
fn guard_extent(
    lx: &Lexed,
    braces: &HashMap<usize, usize>,
    fi: &FnInfo,
    acq_idx: usize,
) -> (usize, usize) {
    let n = lx.toks.len();
    // statement start: scan back to `;` `{` `}` `(` `[` at relative depth 0
    let mut j = acq_idx;
    let mut depth = 0i32;
    let mut stmt_start = fi.body_open + 1;
    while j > fi.body_open {
        j -= 1;
        if lx.kind(j) != Some(TokKind::Punct) {
            continue;
        }
        match lx.s(j) {
            ")" | "}" | "]" => depth += 1,
            "(" | "{" | "[" => {
                if depth == 0 {
                    stmt_start = j + 1;
                    break;
                }
                depth -= 1;
            }
            ";" if depth == 0 => {
                stmt_start = j + 1;
                break;
            }
            _ => {}
        }
    }
    // statement end: scan forward to `;` or an unmatched closer
    let mut j = acq_idx;
    let mut depth = 0i32;
    let mut stmt_end = fi.body_close;
    while j < fi.body_close {
        if lx.kind(j) == Some(TokKind::Punct) {
            match lx.s(j) {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    if depth == 0 {
                        stmt_end = j;
                        break;
                    }
                    depth -= 1;
                }
                ";" if depth == 0 => {
                    stmt_end = j;
                    break;
                }
                _ => {}
            }
        }
        j += 1;
    }

    // scrutinee extension: acquisition inside an if/while/match head
    let first = lx.s(stmt_start);
    if lx.kind(stmt_start) == Some(TokKind::Id)
        && (first == "if" || first == "while" || first == "match")
    {
        let mut j = stmt_start;
        let mut depth = 0i32;
        while j < fi.body_close {
            let t = lx.s(j);
            if t == "(" || t == "[" {
                depth += 1;
            } else if t == ")" || t == "]" {
                depth -= 1;
            } else if t == "{" && depth == 0 {
                break;
            }
            j += 1;
        }
        if j < fi.body_close && acq_idx < j {
            if let Some(&close) = braces.get(&j) {
                return (acq_idx, close);
            }
        }
        return (acq_idx, stmt_end);
    }

    // let-bound guard: `let [mut] NAME = <acquisition chain>;`
    if lx.is_id(stmt_start, "let") {
        let mut p = stmt_start + 1;
        if lx.is_id(p, "mut") {
            p += 1;
        }
        if lx.kind(p) == Some(TokKind::Id) && lx.is_punct(p + 1, "=") {
            let gname = lx.s(p).to_string();
            // walk from the acquisition's `()` through passthrough
            // adapters; a binding is a guard only when the chain lands
            // exactly on the statement end (no further projection)
            let mut j = acq_idx + 4; // past `.meth()` → first token after `)`
            const PASS: &[&str] = &["unwrap", "expect", "unwrap_or_else", "map_err"];
            while j < stmt_end {
                if lx.is_punct(j, ".") && PASS.contains(&lx.s(j + 1)) {
                    j += 2;
                    if lx.is_punct(j, "(") {
                        let mut depth = 1i32;
                        j += 1;
                        while j < n && depth > 0 {
                            if lx.is_punct(j, "(") {
                                depth += 1;
                            } else if lx.is_punct(j, ")") {
                                depth -= 1;
                            }
                            j += 1;
                        }
                    }
                    continue;
                }
                if lx.is_punct(j, "?") {
                    j += 1;
                    continue;
                }
                break;
            }
            if j == stmt_end {
                // enclosing block = nearest unmatched `{` before the stmt
                let mut open_idx = None;
                let mut depth = 0i32;
                let mut j = stmt_start;
                while j > fi.body_open {
                    j -= 1;
                    if lx.is_punct(j, "}") {
                        depth += 1;
                    } else if lx.is_punct(j, "{") {
                        if depth == 0 {
                            open_idx = Some(j);
                            break;
                        }
                        depth -= 1;
                    }
                }
                let mut end = open_idx
                    .and_then(|o| braces.get(&o).copied())
                    .unwrap_or(fi.body_close);
                // explicit early release truncates the extent
                let mut j = stmt_end;
                while j + 3 < end {
                    if lx.is_id(j, "drop")
                        && lx.is_punct(j + 1, "(")
                        && lx.s(j + 2) == gname
                        && lx.is_punct(j + 3, ")")
                    {
                        end = j;
                        break;
                    }
                    j += 1;
                }
                return (acq_idx, end);
            }
        }
    }
    (acq_idx, stmt_end)
}

/// Extract acquisition/blocking/call facts for one function body.
pub fn fn_facts(lx: &Lexed, braces: &HashMap<usize, usize>, fi: &FnInfo) -> FnFacts {
    let mut facts = FnFacts { acqs: Vec::new(), blocks: Vec::new(), calls: Vec::new() };
    let mut i = fi.body_open;
    while i < fi.body_close {
        // `.meth()` with empty parens → acquisition candidate
        if lx.is_punct(i, ".")
            && lx.kind(i + 1) == Some(TokKind::Id)
            && lx.is_punct(i + 2, "(")
            && lx.is_punct(i + 3, ")")
        {
            if let Some(kind) = acq_kind(lx.s(i + 1)) {
                if let Some(name) = receiver_name(lx, i) {
                    let (ext_start, ext_end) = guard_extent(lx, braces, fi, i);
                    facts.acqs.push(Acquisition {
                        idx: i,
                        kind,
                        name,
                        line: lx.line(i + 1),
                        ext_start,
                        ext_end,
                    });
                }
            }
        }
        if lx.kind(i) == Some(TokKind::Id) && lx.is_punct(i + 1, "(") {
            let name = lx.s(i);
            if BLOCKING.contains(&name) {
                // `join` blocks only as JoinHandle::join(), which takes
                // no arguments (Path::join / slice::join both do)
                let arg_join = name == "join" && !lx.is_punct(i + 2, ")");
                if !arg_join {
                    facts.blocks.push((i, lx.line(i), name.to_string()));
                }
            } else {
                facts.calls.push((name.to_string(), i));
            }
        }
        i += 1;
    }
    facts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::tokenize;

    fn lex(src: &str) -> Lexed {
        Lexed { text: src.to_string(), toks: tokenize(src) }
    }

    #[test]
    fn functions_and_test_spans() {
        let src = "fn a() { 1 }\n#[cfg(test)]\nmod t { #[test]\nfn b() {} fn c() {} }\n";
        let lx = lex(src);
        let braces = match_braces(&lx);
        let fns = extract_functions(&lx, &braces);
        let by_name: Vec<(&str, bool)> =
            fns.iter().map(|f| (f.name.as_str(), f.test)).collect();
        assert_eq!(by_name, [("a", false), ("b", true), ("c", true)]);
    }

    #[test]
    fn statement_temporary_released_at_semicolon() {
        let src = "fn f(&self) { self.m.lock().unwrap().push(1); self.tx.send(2); }";
        let lx = lex(src);
        let braces = match_braces(&lx);
        let fns = extract_functions(&lx, &braces);
        let facts = fn_facts(&lx, &braces, &fns[0]);
        assert_eq!(facts.acqs.len(), 1);
        let a = &facts.acqs[0];
        // the send() comes after the statement end: not in extent
        let send = facts.blocks.iter().find(|b| b.2 == "send").unwrap();
        assert!(send.0 > a.ext_end);
    }

    #[test]
    fn let_bound_guard_extends_to_block_end() {
        let src = "fn f(&self) { let g = self.m.lock().unwrap(); self.tx.send(2); }";
        let lx = lex(src);
        let braces = match_braces(&lx);
        let fns = extract_functions(&lx, &braces);
        let facts = fn_facts(&lx, &braces, &fns[0]);
        let a = &facts.acqs[0];
        let send = facts.blocks.iter().find(|b| b.2 == "send").unwrap();
        assert!(send.0 < a.ext_end, "guard should cover the send");
    }

    #[test]
    fn drop_truncates_guard_extent() {
        let src =
            "fn f(&self) { let g = self.m.lock().unwrap(); drop(g); self.tx.send(2); }";
        let lx = lex(src);
        let braces = match_braces(&lx);
        let fns = extract_functions(&lx, &braces);
        let facts = fn_facts(&lx, &braces, &fns[0]);
        let a = &facts.acqs[0];
        let send = facts.blocks.iter().find(|b| b.2 == "send").unwrap();
        assert!(send.0 > a.ext_end, "drop(g) should end the extent");
    }

    #[test]
    fn path_join_is_not_blocking() {
        let src = "fn f(&self) { let p = self.dir.join(\"x\"); }";
        let lx = lex(src);
        let braces = match_braces(&lx);
        let fns = extract_functions(&lx, &braces);
        let facts = fn_facts(&lx, &braces, &fns[0]);
        assert!(facts.blocks.is_empty());
    }
}
