//! Comment- and string-aware Rust token scanner.
//!
//! The analyzer does not need a real parser: every rule in this
//! subsystem is phrased over a flat token stream (identifier before a
//! `[`, `.lock()` method chains, brace nesting). What it *does* need is
//! to never be fooled by comments, string literals, raw strings, char
//! literals, or lifetimes — a `".lock()"` inside a doc string must not
//! count as an acquisition. This lexer handles exactly that and nothing
//! more; numeric literal shapes beyond "digits and embedded dots" are
//! out of scope because no rule looks inside numbers.

/// Token class. `Life` (lifetimes) and `Char` are distinguished from
/// punctuation so `'a` in generics never half-consumes a char literal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Id,
    Num,
    Str,
    Char,
    Life,
    Punct,
}

/// One token: kind, byte span into the source, and 1-based line of the
/// span start. Text is borrowed back from the source on demand.
#[derive(Clone, Copy, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub start: usize,
    pub end: usize,
    pub line: u32,
}

/// Tokenized file: the source plus its token stream.
pub struct Lexed {
    pub text: String,
    pub toks: Vec<Token>,
}

impl Lexed {
    /// Token text; empty for an out-of-range index (simplifies lookahead).
    pub fn s(&self, idx: usize) -> &str {
        match self.toks.get(idx) {
            Some(t) => &self.text[t.start..t.end],
            None => "",
        }
    }

    pub fn kind(&self, idx: usize) -> Option<TokKind> {
        self.toks.get(idx).map(|t| t.kind)
    }

    pub fn line(&self, idx: usize) -> u32 {
        self.toks.get(idx).map(|t| t.line).unwrap_or(0)
    }

    /// True when token `idx` is an identifier with this exact text.
    pub fn is_id(&self, idx: usize, text: &str) -> bool {
        self.kind(idx) == Some(TokKind::Id) && self.s(idx) == text
    }

    /// True when token `idx` is this punctuation character.
    pub fn is_punct(&self, idx: usize, text: &str) -> bool {
        self.kind(idx) == Some(TokKind::Punct) && self.s(idx) == text
    }
}

fn is_id_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_id_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize Rust source. Comments are skipped (the caller collects
/// `// lint:` annotations line-by-line from the raw text); strings and
/// chars become single tokens carrying their quoted text.
pub fn tokenize(text: &str) -> Vec<Token> {
    let b = text.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte-raw strings: r"..."  r#"..."#  br##"..."##
        if c == b'r' || c == b'b' {
            let mut j = i;
            if b[j] == b'b' && j + 1 < n && b[j + 1] == b'r' {
                j += 2;
            } else if b[j] == b'r' {
                j += 1;
            } else {
                j = usize::MAX;
            }
            if j != usize::MAX {
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && b[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == b'"' {
                    // scan for closing quote followed by `hashes` #s
                    let mut e = k + 1;
                    let start = i;
                    loop {
                        if e >= n {
                            break;
                        }
                        if b[e] == b'"'
                            && n - e - 1 >= hashes
                            && b[e + 1..e + 1 + hashes].iter().all(|&h| h == b'#')
                        {
                            e += 1 + hashes;
                            break;
                        }
                        if b[e] == b'\n' {
                            line += 1;
                        }
                        e += 1;
                    }
                    toks.push(Token { kind: TokKind::Str, start, end: e, line });
                    i = e;
                    continue;
                }
            }
        }
        // plain / byte strings
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let start = i;
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Token { kind: TokKind::Str, start, end: j.min(n), line });
            i = j.min(n);
            continue;
        }
        // lifetime vs char literal
        if c == b'\'' {
            // lifetime: 'ident not followed by a closing quote
            let mut j = i + 1;
            while j < n && is_id_cont(b[j]) {
                j += 1;
            }
            if j > i + 1 && is_id_start(b[i + 1]) && (j >= n || b[j] != b'\'') {
                toks.push(Token { kind: TokKind::Life, start: i, end: j, line });
                i = j;
                continue;
            }
            // char literal: '<escape-or-byte>'
            let mut j = i + 1;
            if j < n && b[j] == b'\\' {
                j += 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
            } else {
                // a char may be multi-byte UTF-8; scan to the close quote
                while j < n && b[j] != b'\'' && b[j] != b'\n' {
                    j += 1;
                }
            }
            if j < n && b[j] == b'\'' {
                toks.push(Token { kind: TokKind::Char, start: i, end: j + 1, line });
                i = j + 1;
                continue;
            }
            toks.push(Token { kind: TokKind::Punct, start: i, end: i + 1, line });
            i += 1;
            continue;
        }
        if is_id_start(c) {
            let start = i;
            while i < n && is_id_cont(b[i]) {
                i += 1;
            }
            toks.push(Token { kind: TokKind::Id, start, end: i, line });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n {
                if b[i] == b'.' {
                    // only part of the number when a digit follows:
                    // `1.max(2)` must split at the dot
                    if i + 1 < n && b[i + 1].is_ascii_digit() {
                        i += 1;
                        continue;
                    }
                    break;
                }
                if !is_id_cont(b[i]) {
                    break;
                }
                i += 1;
            }
            toks.push(Token { kind: TokKind::Num, start, end: i, line });
            continue;
        }
        toks.push(Token { kind: TokKind::Punct, start: i, end: i + 1, line });
        i += 1;
    }
    toks
}

/// One `// lint: allow(rule, "reason")` annotation. A reason-less allow
/// still suppresses its rule but is itself reported as `bad-annotation`
/// — the grammar makes justification mandatory, not optional.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: u32,
    pub rule: String,
    pub reason: Option<String>,
}

/// Collect `// lint: allow(...)` annotations from raw source text. An
/// allow on line L covers findings reported on L and L+1 (same line or
/// the line directly below the comment).
pub fn collect_allows(text: &str) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = idx as u32 + 1;
        let mut rest = raw;
        while let Some(pos) = rest.find("//") {
            let tail = &rest[pos + 2..];
            let t = tail.trim_start();
            if let Some(t) = t.strip_prefix("lint:") {
                let t = t.trim_start();
                if let Some(t) = t.strip_prefix("allow(") {
                    if let Some(a) = parse_allow(t, line) {
                        out.push(a);
                    }
                }
            }
            rest = tail;
        }
    }
    out
}

fn parse_allow(t: &str, line: u32) -> Option<Allow> {
    // rule name: [a-z-]+
    let rule_end = t
        .char_indices()
        .find(|&(_, c)| !(c.is_ascii_lowercase() || c == '-'))
        .map(|(i, _)| i)
        .unwrap_or(t.len());
    if rule_end == 0 {
        return None;
    }
    let rule = t[..rule_end].to_string();
    let rest = t[rule_end..].trim_start();
    if let Some(r) = rest.strip_prefix(')') {
        let _ = r;
        return Some(Allow { line, rule, reason: None });
    }
    let rest = rest.strip_prefix(',')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    // scan the quoted reason, honoring backslash escapes
    let mut reason = String::new();
    let mut chars = rest.chars();
    loop {
        match chars.next()? {
            '\\' => {
                reason.push(chars.next()?);
            }
            '"' => break,
            c => reason.push(c),
        }
    }
    let tail = chars.as_str().trim_start();
    if !tail.starts_with(')') {
        return None;
    }
    Some(Allow { line, rule, reason: Some(reason) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        let toks = tokenize(src);
        toks.iter()
            .map(|t| (t.kind, src[t.start..t.end].to_string()))
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r#"
            // a .lock() in a comment
            /* nested /* block */ .lock() */
            let s = "call .lock() here";
            let r = r#x"raw .lock()"#x;
        "#
        .replace("#x", "#");
        let ks = kinds(&src);
        let ids: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Id)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(ids, ["let", "s", "let", "r"]);
    }

    #[test]
    fn lifetime_vs_char() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Life && s == "'a"));
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Char && s == "'x'"));
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Char && s == "'\\n'"));
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        let ks = kinds("let x = 1.max(2) + 3.5;");
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Num && s == "1"));
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Id && s == "max"));
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Num && s == "3.5"));
    }

    #[test]
    fn allow_annotations_parse() {
        // `\u{20}` keeps this file's *raw text* free of the annotation
        // marker so the analyzer's own self-scan does not pick these up
        let src =
            "x(); //\u{20}lint: allow(panic, \"why not\")\ny(); //\u{20}lint: allow(index)\n";
        let allows = collect_allows(src);
        assert_eq!(allows.len(), 2);
        assert_eq!(allows[0].rule, "panic");
        assert_eq!(allows[0].reason.as_deref(), Some("why not"));
        assert_eq!(allows[1].line, 2);
        assert!(allows[1].reason.is_none());
    }
}
