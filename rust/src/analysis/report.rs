//! Finding collection, annotation-based suppression, and rendering
//! (human text + machine JSON).

use super::lexer::Allow;
use crate::util::json::{arr, obj, Value};
use std::collections::HashMap;

/// One lint finding, anchored to a repo-relative path and 1-based line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

/// Collects findings and applies `// lint: allow(...)` suppression. An
/// allow on line L covers findings on L and L+1; a reason-less allow
/// suppresses its target but surfaces as a `bad-annotation` finding so
/// the tree can never silently accumulate unexplained exceptions.
pub struct Report {
    findings: Vec<Finding>,
    /// path -> allows for that file.
    allows: HashMap<String, Vec<Allow>>,
}

impl Report {
    pub fn new() -> Self {
        Report { findings: Vec::new(), allows: HashMap::new() }
    }

    pub fn register_allows(&mut self, path: &str, allows: Vec<Allow>) {
        for a in &allows {
            if a.reason.is_none() {
                self.findings.push(Finding {
                    rule: "bad-annotation".into(),
                    path: path.to_string(),
                    line: a.line,
                    msg: format!("lint allow({}) without a reason", a.rule),
                });
            }
        }
        self.allows.insert(path.to_string(), allows);
    }

    fn allowed(&self, rule: &str, path: &str, line: u32) -> bool {
        let Some(allows) = self.allows.get(path) else { return false };
        allows
            .iter()
            .any(|a| a.rule == rule && (a.line == line || a.line + 1 == line))
    }

    pub fn report(&mut self, rule: &str, path: &str, line: u32, msg: String) {
        if self.allowed(rule, path, line) {
            return;
        }
        self.findings.push(Finding {
            rule: rule.to_string(),
            path: path.to_string(),
            line,
            msg,
        });
    }

    /// (total annotations, annotations missing a reason) across every
    /// registered file — the audited waiver surface of the tree.
    pub fn allow_counts(&self) -> (usize, usize) {
        let total = self.allows.values().map(Vec::len).sum();
        let unreasoned = self
            .allows
            .values()
            .flatten()
            .filter(|a| a.reason.is_none())
            .count();
        (total, unreasoned)
    }

    /// Findings sorted by (path, line, rule) for stable output.
    pub fn into_findings(mut self) -> Vec<Finding> {
        self.findings.sort_by(|a, b| {
            (&a.path, a.line, &a.rule).cmp(&(&b.path, b.line, &b.rule))
        });
        self.findings
    }
}

/// Render findings as `path:line: [rule] message` lines plus a summary.
pub fn render_human(findings: &[Finding], files_scanned: usize) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.msg));
    }
    if findings.is_empty() {
        out.push_str(&format!("lint: clean ({files_scanned} files scanned)\n"));
    } else {
        out.push_str(&format!(
            "lint: {} finding(s) across {files_scanned} files\n",
            findings.len()
        ));
    }
    out
}

/// Machine-readable report body for `kan-edge lint --json`. The allow
/// counts expose the suppression surface so it can be audited over time.
pub fn render_json(
    findings: &[Finding],
    files_scanned: usize,
    allows: usize,
    allows_without_reason: usize,
) -> Value {
    let items = findings
        .iter()
        .map(|f| {
            obj(vec![
                ("rule", Value::Str(f.rule.clone())),
                ("path", Value::Str(f.path.clone())),
                ("line", Value::Int(f.line as i64)),
                ("message", Value::Str(f.msg.clone())),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Value::Str("kan-edge-lint/v1".into())),
        ("files_scanned", Value::Int(files_scanned as i64)),
        ("findings", arr(items)),
        ("clean", Value::Bool(findings.is_empty())),
        ("allows", Value::Int(allows as i64)),
        ("allows_without_reason", Value::Int(allows_without_reason as i64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_covers_same_and_next_line() {
        let mut r = Report::new();
        r.register_allows(
            "a.rs",
            vec![Allow { line: 10, rule: "panic".into(), reason: Some("ok".into()) }],
        );
        r.report("panic", "a.rs", 10, "x".into());
        r.report("panic", "a.rs", 11, "y".into());
        r.report("panic", "a.rs", 12, "z".into());
        r.report("index", "a.rs", 10, "other rule".into());
        let f = r.into_findings();
        assert_eq!(f.len(), 2);
        assert!(f.iter().any(|f| f.rule == "panic" && f.line == 12));
        assert!(f.iter().any(|f| f.rule == "index" && f.line == 10));
    }

    #[test]
    fn reasonless_allow_is_a_finding() {
        let mut r = Report::new();
        r.register_allows(
            "a.rs",
            vec![Allow { line: 3, rule: "alloc".into(), reason: None }],
        );
        r.report("alloc", "a.rs", 3, "suppressed".into());
        let f = r.into_findings();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "bad-annotation");
    }
}
