//! Doc-drift checks: facts extracted from the source tree are compared
//! against what the docs claim, in both directions where the doc side
//! is authoritative-by-table.
//!
//! * wire error codes — `ErrorCode::as_str` in `coordinator/protocol.rs`
//!   vs the "Error codes" table in `docs/PROTOCOL.md`;
//! * Prometheus series — every `kan_edge_*` series named in docs must be
//!   segmentable from the string-literal vocabulary of the source tree
//!   (the exposition builds names by joining literal segments);
//! * config keys — every backticked `section.key` path in the docs must
//!   be parsed by `AppConfig::apply` in `config/mod.rs`.

use super::lexer::TokKind;
use super::report::Report;
use super::ScannedFile;
use std::collections::BTreeSet;
use std::path::Path;

/// Contents of a string literal token (`"x"`, `b"x"`, `r#"x"#` → `x`).
fn str_content(raw: &str) -> Option<&str> {
    let open = raw.find('"')?;
    let close = raw.rfind('"')?;
    if close <= open {
        return None;
    }
    Some(&raw[open + 1..close])
}

fn is_snake(s: &str) -> bool {
    let mut ch = s.chars();
    match ch.next() {
        Some(c) if c.is_ascii_lowercase() => {}
        _ => return false,
    }
    ch.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Run all drift checks. `root` is the repo root; `files` the scanned
/// source set (doc files are read directly — they are not Rust).
pub fn drift_checks(root: &Path, files: &[ScannedFile], report: &mut Report) {
    error_code_drift(root, files, report);
    prom_series_drift(root, files, report);
    config_key_drift(root, files, report);
}

fn read_doc(root: &Path, rel: &str) -> Option<String> {
    std::fs::read_to_string(root.join(rel)).ok()
}

fn find_file<'a>(files: &'a [ScannedFile], rel_src: &str) -> Option<&'a ScannedFile> {
    files.iter().find(|f| f.rel_src == rel_src)
}

// ---- wire error codes ---------------------------------------------------

fn error_code_drift(root: &Path, files: &[ScannedFile], report: &mut Report) {
    let Some(proto) = find_file(files, "coordinator/protocol.rs") else { return };
    let mut code_codes = BTreeSet::new();
    for f in &proto.fns {
        if f.name != "as_str" {
            continue;
        }
        for i in f.body_open..f.body_close {
            if proto.lx.kind(i) == Some(TokKind::Str) {
                if let Some(s) = str_content(proto.lx.s(i)) {
                    code_codes.insert(s.to_string());
                }
            }
        }
    }
    let Some(doc) = read_doc(root, "docs/PROTOCOL.md") else {
        report.report(
            "doc-drift",
            "docs/PROTOCOL.md",
            0,
            "docs/PROTOCOL.md missing (error-code table unverifiable)".into(),
        );
        return;
    };
    // anchor: a line mentioning "error codes", then the next table rows
    let mut doc_codes = BTreeSet::new();
    let mut in_section = false;
    for line in doc.lines() {
        if !in_section {
            if line.to_ascii_lowercase().contains("error codes") {
                in_section = true;
            }
            continue;
        }
        let t = line.trim_start();
        if t.is_empty() {
            continue;
        }
        if !t.starts_with('|') {
            if !doc_codes.is_empty() {
                break;
            }
            // prose between the anchor and the table: keep scanning
            in_section = false;
            continue;
        }
        if let Some(code) = parse_code_row(t) {
            doc_codes.insert(code);
        }
    }
    for c in code_codes.difference(&doc_codes) {
        report.report(
            "doc-drift",
            "docs/PROTOCOL.md",
            0,
            format!("wire error code `{c}` missing from docs/PROTOCOL.md"),
        );
    }
    for c in doc_codes.difference(&code_codes) {
        report.report(
            "doc-drift",
            "docs/PROTOCOL.md",
            0,
            format!("documented error code `{c}` not produced by protocol.rs"),
        );
    }
}

/// `| `code` | ... |` → `code`.
fn parse_code_row(t: &str) -> Option<String> {
    let t = t.strip_prefix('|')?.trim_start();
    let t = t.strip_prefix('`')?;
    let end = t.find('`')?;
    let code = &t[..end];
    (!code.is_empty() && code.chars().all(|c| c.is_ascii_lowercase() || c == '_'))
        .then(|| code.to_string())
}

// ---- Prometheus series --------------------------------------------------

fn prom_series_drift(root: &Path, files: &[ScannedFile], report: &mut Report) {
    // vocabulary: every snake_case string literal in the tree, plus the
    // segment roots the exposition synthesizes structurally
    let mut vocab = BTreeSet::new();
    for file in files {
        for i in 0..file.lx.toks.len() {
            if file.lx.kind(i) == Some(TokKind::Str) {
                if let Some(s) = str_content(file.lx.s(i)) {
                    if is_snake(s) {
                        vocab.insert(s.to_string());
                    }
                }
            }
        }
    }
    for s in ["kan_edge", "model", "node"] {
        vocab.insert(s.to_string());
    }

    let docs_dir = root.join("docs");
    let Ok(entries) = std::fs::read_dir(&docs_dir) else { return };
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".md"))
        .collect();
    names.sort();
    for name in names {
        let Some(text) = read_doc(root, &format!("docs/{name}")) else { continue };
        for (idx, line) in text.lines().enumerate() {
            for series in extract_series(line) {
                if !segmentable(&series, &vocab) {
                    report.report(
                        "doc-drift",
                        &format!("docs/{name}"),
                        idx as u32 + 1,
                        format!(
                            "documented series `{series}` cannot be produced \
                             by the metrics tree"
                        ),
                    );
                }
            }
        }
    }
}

/// `kan_edge_[a-z0-9_]+` occurrences in one doc line, skipping wildcard
/// families written as `kan_edge_foo_*`.
fn extract_series(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let b = line.as_bytes();
    let mut i = 0usize;
    while let Some(pos) = line[i..].find("kan_edge_") {
        let start = i + pos;
        // must not be mid-identifier
        if start > 0 && (b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_') {
            i = start + 1;
            continue;
        }
        let mut end = start;
        while end < b.len()
            && (b[end].is_ascii_lowercase() || b[end].is_ascii_digit() || b[end] == b'_')
        {
            end += 1;
        }
        if end < b.len() && b[end] == b'*' {
            i = end + 1;
            continue;
        }
        out.push(line[start..end].to_string());
        i = end;
    }
    out
}

/// Can `name` be written as vocabulary words joined by single
/// underscores? Dynamic program over byte positions.
fn segmentable(name: &str, vocab: &BTreeSet<String>) -> bool {
    let n = name.len();
    let mut ok = vec![false; n + 1];
    ok[0] = true;
    for i in 0..n {
        if !ok[i] {
            continue;
        }
        let start = if name.as_bytes()[i] == b'_' { i + 1 } else { i };
        for w in vocab {
            if name[start..].starts_with(w.as_str()) {
                ok[start + w.len()] = true;
            }
        }
    }
    ok[n]
}

// ---- config keys --------------------------------------------------------

fn config_key_drift(root: &Path, files: &[ScannedFile], report: &mut Report) {
    let Some(cfg) = find_file(files, "config/mod.rs") else { return };
    let (sections, keys) = parsed_config_keys(cfg);
    if sections.is_empty() {
        return;
    }

    let mut doc_rels: Vec<String> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(root.join("docs")) {
        for e in entries.filter_map(|e| e.ok()) {
            let n = e.file_name().to_string_lossy().into_owned();
            if n.ends_with(".md") {
                doc_rels.push(format!("docs/{n}"));
            }
        }
    }
    doc_rels.sort();
    doc_rels.push("README.md".into());
    for rel in doc_rels {
        let Some(text) = read_doc(root, &rel) else { continue };
        for (idx, line) in text.lines().enumerate() {
            for path in extract_dotted_keys(line) {
                let first = path.split('.').next().unwrap_or("");
                if sections.contains(first) && !keys.contains(&path) {
                    report.report(
                        "doc-drift",
                        &rel,
                        idx as u32 + 1,
                        format!("documented config key `{path}` not parsed by config/mod.rs"),
                    );
                }
            }
        }
    }
}

/// Walk `AppConfig::apply`: `get("name")` followed (eventually) by `{`
/// opens a section scope; `get("name")` that hits `;`/`)` first is a
/// leaf; `get_*(section, "key", ...)` is a leaf under the current
/// scope. Scopes close with their braces.
fn parsed_config_keys(cfg: &ScannedFile) -> (BTreeSet<String>, BTreeSet<String>) {
    let mut sections = BTreeSet::new();
    let mut keys = BTreeSet::new();
    let lx = &cfg.lx;
    for f in &cfg.fns {
        if f.name != "apply" {
            continue;
        }
        let mut stack: Vec<(String, i32)> = Vec::new();
        let mut depth = 0i32;
        let mut i = f.body_open;
        while i < f.body_close {
            let t = lx.s(i);
            if t == "{" {
                depth += 1;
            } else if t == "}" {
                depth -= 1;
                while stack.last().is_some_and(|s| s.1 > depth) {
                    stack.pop();
                }
            }
            if lx.is_id(i, "get")
                && lx.is_punct(i + 1, "(")
                && lx.kind(i + 2) == Some(TokKind::Str)
                && lx.is_punct(i + 3, ")")
            {
                if let Some(name) = str_content(lx.s(i + 2)) {
                    let mut j = i + 4;
                    let mut is_section = false;
                    while j < f.body_close {
                        let tt = lx.s(j);
                        if tt == "{" {
                            is_section = true;
                            break;
                        }
                        if tt == ";" || tt == ")" {
                            break;
                        }
                        j += 1;
                    }
                    if is_section {
                        sections.insert(name.to_string());
                        stack.push((name.to_string(), depth + 1));
                        keys.insert(join_path(&stack, None));
                    } else {
                        keys.insert(join_path(&stack, Some(name)));
                    }
                }
            } else if lx.kind(i) == Some(TokKind::Id)
                && lx.s(i).starts_with("get_")
                && lx.is_punct(i + 1, "(")
            {
                // first string argument is the key name
                let mut j = i + 2;
                while j < f.body_close {
                    if lx.kind(j) == Some(TokKind::Str) {
                        if let Some(name) = str_content(lx.s(j)) {
                            keys.insert(join_path(&stack, Some(name)));
                        }
                        break;
                    }
                    if lx.is_punct(j, ")") {
                        break;
                    }
                    j += 1;
                }
            }
            i += 1;
        }
    }
    (sections, keys)
}

fn join_path(stack: &[(String, i32)], leaf: Option<&str>) -> String {
    let mut parts: Vec<&str> = stack.iter().map(|s| s.0.as_str()).collect();
    if let Some(l) = leaf {
        parts.push(l);
    }
    parts.join(".")
}

/// Backticked dotted paths `a.b` / `a.b.c` on one doc line.
fn extract_dotted_keys(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(open) = rest.find('`') {
        let tail = &rest[open + 1..];
        let Some(close) = tail.find('`') else { break };
        let inner = &tail[..close];
        if inner.contains('.')
            && !inner.is_empty()
            && inner
                .chars()
                .all(|c| c.is_ascii_lowercase() || c == '_' || c == '.')
            && inner.split('.').all(|seg| !seg.is_empty())
            && inner.split('.').count() >= 2
        {
            out.push(inner.to_string());
        }
        rest = &tail[close + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_segmentation() {
        let vocab: BTreeSet<String> =
            ["kan_edge", "requests", "model"].iter().map(|s| s.to_string()).collect();
        assert!(segmentable("kan_edge_model_requests", &vocab));
        assert!(!segmentable("kan_edge_lost_series", &vocab));
    }

    #[test]
    fn series_extraction_skips_wildcards() {
        let s = extract_series("see `kan_edge_node_up` and `kan_edge_cluster_*`");
        assert_eq!(s, ["kan_edge_node_up"]);
    }

    #[test]
    fn dotted_key_extraction() {
        let ks = extract_dotted_keys("set `server.max_batch` (not `x` or `a..b`)");
        assert_eq!(ks, ["server.max_batch"]);
    }

    #[test]
    fn code_row_parse() {
        assert_eq!(parse_code_row("| `bad_request` | malformed |"), Some("bad_request".into()));
        assert_eq!(parse_code_row("| code | meaning |"), None);
    }
}
