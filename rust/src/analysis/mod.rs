//! `kan-edge lint`: repo-native static analysis for the invariants the
//! serving stack depends on but the compiler cannot check.
//!
//! Dependency-free by construction (the offline image carries no
//! rustc internals, no syn): a comment/string-aware token scanner
//! ([`lexer`]) feeds per-function fact extraction ([`facts`]), and four
//! rule families run over the facts:
//!
//! * **lock discipline** ([`rules::lock_rule`]) — every guard
//!   acquisition site, an inter-procedural lock graph across the
//!   coordinator/cluster/registry/obs planes, lock-order cycles, and
//!   guards held across unbounded blocking calls (channel send/recv,
//!   socket I/O, `JoinHandle::join`);
//! * **panic policy** ([`rules::panic_rule`]) — no `unwrap`/`expect`/
//!   `panic!` on the serving path, with the poisoning-recovery idiom
//!   (`util::sync`) carved out as its own `poison` rule, plus the
//!   `index` sub-rule denying direct `[...]` indexing in the
//!   wire-facing files;
//! * **hot-path allocations** ([`rules::alloc_rule`]) — the engine
//!   steady-state functions and kernels must not allocate per row;
//! * **doc drift** ([`drift`]) — wire error codes, Prometheus series
//!   names, and config keys are cross-checked against the docs.
//!
//! Suppression is explicit and audited: `// lint: allow(rule, "reason")`
//! on the finding line or the line above. A reason-less allow is itself
//! a finding (`bad-annotation`) — the tree cannot silently accumulate
//! unexplained exceptions. See `docs/ANALYSIS.md` for the rule
//! catalogue and the annotation grammar.

pub mod drift;
pub mod facts;
pub mod lexer;
pub mod report;
pub mod rules;

pub use report::{render_human, render_json, Finding};

use crate::error::Result;
use lexer::Lexed;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One scanned source file: token stream + structural indexes.
pub struct ScannedFile {
    /// Repo-relative path (`rust/src/coordinator/tcp.rs`).
    pub rel: String,
    /// Path relative to `rust/src` (`coordinator/tcp.rs`) — the rule
    /// families key their policed sets off this.
    pub rel_src: String,
    pub lx: Lexed,
    pub braces: HashMap<usize, usize>,
    pub fns: Vec<facts::FnInfo>,
}

/// Lint outcome: sorted findings plus the scan size and suppression
/// surface for the report.
pub struct LintOutcome {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Total `// lint: allow(...)` annotations in the tree.
    pub allows: usize,
    /// Annotations missing the mandatory reason string (each also
    /// surfaces as a `bad-annotation` finding).
    pub allows_without_reason: usize,
}

impl LintOutcome {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

fn scan_file(root: &Path, path: &Path) -> Result<(ScannedFile, Vec<lexer::Allow>)> {
    let text = std::fs::read_to_string(path)?;
    let allows = lexer::collect_allows(&text);
    let lx = Lexed { toks: lexer::tokenize(&text), text };
    let braces = facts::match_braces(&lx);
    let fns = facts::extract_functions(&lx, &braces);
    let rel = path
        .strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/");
    let rel_src = rel.strip_prefix("rust/src/").unwrap_or(&rel).to_string();
    Ok((ScannedFile { rel, rel_src, lx, braces, fns }, allows))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Run every rule family over the tree rooted at `root` (the repo
/// root: sources are read from `root/rust/src`, docs from `root/docs`).
pub fn run_lint(root: &Path) -> Result<LintOutcome> {
    let src = root.join("rust").join("src");
    let mut paths = Vec::new();
    collect_rs(&src, &mut paths)?;
    let mut files = Vec::with_capacity(paths.len());
    let mut rep = report::Report::new();
    for p in &paths {
        let (file, allows) = scan_file(root, p)?;
        rep.register_allows(&file.rel, allows);
        files.push(file);
    }
    rules::lock_rule(&files, &mut rep);
    rules::panic_rule(&files, &mut rep);
    rules::index_rule(&files, &mut rep);
    rules::alloc_rule(&files, &mut rep);
    drift::drift_checks(root, &files, &mut rep);
    let (allows, allows_without_reason) = rep.allow_counts();
    Ok(LintOutcome {
        findings: rep.into_findings(),
        files_scanned: files.len(),
        allows,
        allows_without_reason,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_check_tree_is_clean() {
        // the shipped tree must pass its own lint — this is the
        // guarantee that every suppression carries a reason and every
        // doc table matches the code it documents
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate lives in <repo>/rust")
            .to_path_buf();
        let out = run_lint(&root).expect("lint scan");
        assert!(out.files_scanned > 40, "expected a full tree scan");
        let rendered = render_human(&out.findings, out.files_scanned);
        assert!(out.clean(), "lint found issues on the shipped tree:\n{rendered}");
    }
}
