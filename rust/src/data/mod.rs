//! Dataset access and synthetic load generation.
//!
//! The evaluation dataset itself is produced by the python build path and
//! loaded via [`crate::kan::checkpoint::Dataset`]; this module adds a
//! deterministic feature-vector generator for serving load tests (it does
//! not need to match the python PRNG — it only has to exercise the same
//! input domain).

use crate::util::Rng;

/// Deterministic generator of feature vectors in the training domain
/// (uniform over [-1, 1]^d, matching `datasets.py`).
#[derive(Debug, Clone)]
pub struct LoadGen {
    rng: Rng,
    pub dim: usize,
}

impl LoadGen {
    pub fn new(seed: u64, dim: usize) -> Self {
        Self { rng: Rng::new(seed), dim }
    }

    pub fn next_vec(&mut self) -> Vec<f32> {
        (0..self.dim).map(|_| self.rng.range(-1.0, 1.0) as f32).collect()
    }

    pub fn batch(&mut self, n: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| self.next_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let mut a = LoadGen::new(9, 17);
        let mut b = LoadGen::new(9, 17);
        for _ in 0..10 {
            let va = a.next_vec();
            let vb = b.next_vec();
            assert_eq!(va, vb);
            assert!(va.iter().all(|&x| (-1.0..1.0).contains(&x)));
            assert_eq!(va.len(), 17);
        }
    }
}
