//! The Sharable-Hemi LUT (SH-LUT) — ASP-KAN-HAQ's shared basis table.
//!
//! After Alignment-Symmetry, every quantized abscissa inside any knot
//! interval has local fraction `u = l / 2^LD`, and the `K+1` active basis
//! values at that abscissa are `C_k(K − t + u)` — independent of the
//! interval. One `2^LD × (K+1)` table therefore serves all `G+K` basis
//! functions. The cardinal spline's mirror symmetry `C_k(s) = C_k(K+1−s)`
//! relates row `l` to row `2^LD − l` with columns reversed, so only rows
//! `0 ..= 2^(LD−1)` need storing: the *hemi* half of the name and the
//! paper's 50 % LUT-size reduction.

use super::asp::AspSpec;
use crate::kan::spline;

/// The shared LUT in both full and hemi (stored) forms, with fixed-point
/// codes as the hardware would hold them.
#[derive(Debug, Clone)]
pub struct ShLut {
    /// B-spline degree.
    pub k: u32,
    /// PowerGap exponent; full table has `2^LD` rows.
    pub ld: u32,
    /// LUT entry precision in bits (paper: 8).
    pub bits: u32,
    /// Stored rows `0 ..= 2^(LD-1)`, each `K+1` fixed-point codes.
    pub hemi: Vec<Vec<u32>>,
}

impl ShLut {
    /// Build the SH-LUT for a quantization spec (entry precision = `bits`).
    pub fn build(spec: &AspSpec, bits: u32) -> Self {
        let lvl = spec.levels_per_interval();
        let half = (lvl / 2) as usize;
        let scale = ((1u64 << bits) - 1) as f64;
        let hemi = (0..=half)
            .map(|l| {
                let u = l as f64 / lvl as f64;
                spline::active_basis(u, spec.k as usize)
                    .into_iter()
                    .map(|v| (v * scale).round().clamp(0.0, scale) as u32)
                    .collect()
            })
            .collect();
        Self { k: spec.k, ld: spec.ld, bits, hemi }
    }

    /// Rows of the full (logical) table, `2^LD`.
    #[inline]
    pub fn full_rows(&self) -> usize {
        1usize << self.ld
    }

    /// Stored entries (the hemi half), what the hardware actually holds.
    #[inline]
    pub fn stored_entries(&self) -> usize {
        self.hemi.len() * (self.k as usize + 1)
    }

    /// Read one logical entry `(l, t)`, resolving the mirror for the upper
    /// half — this models the MUX/DEMUX routing network of Fig 5/6.
    #[inline]
    pub fn lookup(&self, l: u32, t: u32) -> u32 {
        let lvl = self.full_rows() as u32;
        debug_assert!(l < lvl && t <= self.k);
        let half = lvl / 2;
        if l <= half {
            self.hemi[l as usize][t as usize]
        } else {
            self.hemi[(lvl - l) as usize][(self.k - t) as usize]
        }
    }

    /// The `K+1` active basis codes for local offset `l` (one LUT row).
    pub fn row(&self, l: u32) -> Vec<u32> {
        (0..=self.k).map(|t| self.lookup(l, t)).collect()
    }

    /// Dequantize one entry back to `[0, 1]`.
    #[inline]
    pub fn dequant(&self, code: u32) -> f64 {
        code as f64 / ((1u64 << self.bits) - 1) as f64
    }

    /// Dequantized full table, `2^LD` rows × `K+1` columns.
    pub fn full_table_f64(&self) -> Vec<Vec<f64>> {
        (0..self.full_rows() as u32)
            .map(|l| self.row(l).into_iter().map(|c| self.dequant(c)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::asp::AspSpec;

    fn spec(g: u32, k: u32) -> AspSpec {
        AspSpec::build(g, k, 8, -1.0, 1.0).unwrap()
    }

    #[test]
    fn hemi_is_half_plus_one() {
        let s = spec(5, 3);
        let lut = ShLut::build(&s, 8);
        assert_eq!(lut.full_rows(), 32);
        assert_eq!(lut.hemi.len(), 17); // 2^(LD-1) + 1
    }

    #[test]
    fn mirror_reconstruction_matches_direct_evaluation() {
        for (g, k) in [(5u32, 3u32), (8, 3), (16, 2), (32, 3), (64, 1), (7, 4)] {
            let s = spec(g, k);
            let lut = ShLut::build(&s, 8);
            let lvl = lut.full_rows() as u32;
            let scale = 255.0_f64;
            for l in 0..lvl {
                let u = l as f64 / lvl as f64;
                let direct = crate::kan::spline::active_basis(u, k as usize);
                for t in 0..=k {
                    let want = (direct[t as usize] * scale).round() as u32;
                    assert_eq!(
                        lut.lookup(l, t),
                        want,
                        "g={g} k={k} l={l} t={t}: mirror broke"
                    );
                }
            }
        }
    }

    #[test]
    fn partition_of_unity_in_fixed_point() {
        // sum of a row's codes must be ~= 255 (quantized partition of unity)
        let s = spec(5, 3);
        let lut = ShLut::build(&s, 8);
        for l in 0..lut.full_rows() as u32 {
            let sum: u32 = lut.row(l).iter().sum();
            assert!((253..=257).contains(&sum), "row {l} sums to {sum}");
        }
    }

    #[test]
    fn stored_is_about_half_of_full() {
        let s = spec(8, 3);
        let lut = ShLut::build(&s, 8);
        let full_entries = lut.full_rows() * (lut.k as usize + 1);
        assert!(lut.stored_entries() <= full_entries / 2 + (lut.k as usize + 1));
    }
}
