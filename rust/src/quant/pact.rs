//! Conventional-quantization baseline (PACT-style) for the Fig 10 comparison.
//!
//! PACT [16] learns a clipping range `[0, α]` and quantizes it into `2^n`
//! uniform steps. Nothing ties the step to the knot spacing, so quantized
//! abscissae fall at *different* offsets inside different knot intervals:
//! shifting two intervals onto each other does not superimpose their sample
//! points (paper Fig 3, left). Consequently each of the `G+K` basis
//! functions needs its own programmable LUT over its support, its own
//! `2L:1` TG-MUX, and a full n-bit decoder drives the selection — the
//! hardware Fig 10 costs out against ASP-KAN-HAQ.

use crate::kan::spline;

/// PACT-style quantizer for a KAN layer input.
#[derive(Debug, Clone, Copy)]
pub struct PactSpec {
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
    pub lo: f64,
    /// PACT clipping parameter (the upper end of the quantized range).
    pub alpha: f64,
}

impl PactSpec {
    pub fn new(g: u32, k: u32, n_bits: u32, lo: f64, alpha: f64) -> Self {
        Self { g, k, n_bits, lo, alpha }
    }

    /// Number of codes, `2^n` (no relation to `G`).
    #[inline]
    pub fn range(&self) -> u32 {
        1 << self.n_bits
    }

    #[inline]
    pub fn step(&self) -> f64 {
        (self.alpha - self.lo) / self.range() as f64
    }

    #[inline]
    pub fn quantize(&self, x: f64) -> u32 {
        let q = ((x - self.lo) / self.step()).round();
        (q.max(0.0) as u32).min(self.range() - 1)
    }

    #[inline]
    pub fn dequantize(&self, q: u32) -> f64 {
        self.lo + q as f64 * self.step()
    }

    /// Quantized sample points inside one basis' support:
    /// `(K+1)/G` of the full code range, rounded up.
    pub fn per_basis_lut_entries(&self) -> usize {
        (((self.k + 1) as u64 * self.range() as u64 + self.g as u64 - 1)
            / self.g as u64) as usize
    }

    /// Whether the quantization grid aligns with the knot grid (it almost
    /// never does — that is the point of the baseline). Alignment requires
    /// `2^n` to be an integer multiple of `G`.
    pub fn grids_aligned(&self) -> bool {
        self.range() % self.g == 0
    }

    /// Build the per-basis LUTs: `lut[i][e]` = `B_i` at the e-th code in its
    /// support. Misalignment makes these tables genuinely differ between
    /// bases (asserted in tests), which is why they cannot be shared.
    pub fn build_per_basis_luts(&self) -> Vec<Vec<f64>> {
        let entries = self.per_basis_lut_entries();
        let h = (self.alpha - self.lo) / self.g as f64;
        let mut out = vec![vec![0.0; entries]; (self.g + self.k) as usize];
        for (i, lut) in out.iter_mut().enumerate() {
            let zlo = i as f64 - self.k as f64;
            let zhi = i as f64 + 1.0;
            let mut e = 0;
            for q in 0..self.range() {
                let z = (self.dequantize(q) - self.lo) / h;
                if z >= zlo && z < zhi && e < entries {
                    lut[e] = spline::cardinal_bspline(z - i as f64 + self.k as f64, self.k as usize);
                    e += 1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn misaligned_grids_for_non_power_of_two_g() {
        // G in the Fig 10 sweep that don't divide 256
        for g in [5u32, 7, 12, 60] {
            let s = PactSpec::new(g, 3, 8, 0.0, 1.0);
            assert!(!s.grids_aligned(), "G={g} unexpectedly aligned");
        }
        // power-of-two G happens to align — but PACT still pays per-basis
        // LUTs because its *trained* alpha breaks alignment in general.
        assert!(PactSpec::new(8, 3, 8, 0.0, 1.0).grids_aligned());
    }

    #[test]
    fn per_basis_luts_differ_between_bases() {
        // the central bases' tables must not be identical — the sharing
        // obstruction of paper Fig 3
        let s = PactSpec::new(5, 3, 8, 0.0, 1.0);
        let luts = s.build_per_basis_luts();
        let a = &luts[3];
        let b = &luts[4];
        let max_diff = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_diff > 1e-4,
            "per-basis LUTs should differ under misalignment (diff={max_diff})"
        );
    }

    #[test]
    fn entry_count_scales_with_support_fraction() {
        let s = PactSpec::new(8, 3, 8, 0.0, 1.0);
        assert_eq!(s.per_basis_lut_entries(), 128); // (3+1)*256/8
        let s = PactSpec::new(64, 3, 8, 0.0, 1.0);
        assert_eq!(s.per_basis_lut_entries(), 16);
    }

    #[test]
    fn quantize_saturates() {
        let s = PactSpec::new(5, 3, 6, -1.0, 1.0);
        assert_eq!(s.quantize(-9.0), 0);
        assert_eq!(s.quantize(9.0), 63);
    }
}
