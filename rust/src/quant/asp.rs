//! ASP-KAN-HAQ phase 1 + 2: the quantization geometry (paper §3.1).
//!
//! *Alignment-Symmetry* (phase 1) constrains the quantization grid to an
//! integer multiple of the knot grid, `G·L ≤ 2^n` (eq. 4) — zero offset
//! between the grids, so one LUT serves every basis function.
//!
//! *PowerGap* (phase 2) further restricts `L = 2^LD` (eq. 5/6) so global
//! interval index and local offset become bit-field extractions — the
//! hardware trick that splits the n-bit decoder into an (n−D)-bit + D-bit
//! pair and collapses the TG-MUX tree.

use crate::error::{Error, Result};

/// Largest `LD` with `G · 2^LD ≤ 2^n` (eq. 6).
pub fn solve_ld(g: u32, n_bits: u32) -> Result<u32> {
    if g == 0 {
        return Err(Error::Config("grid size G must be >= 1".into()));
    }
    if g > (1u32 << n_bits) {
        return Err(Error::Config(format!(
            "G={g} does not fit in {n_bits}-bit input precision"
        )));
    }
    let mut ld = 0u32;
    while u64::from(g) << (ld + 1) <= 1u64 << n_bits {
        ld += 1;
    }
    Ok(ld)
}

/// Quantization geometry of one KAN layer input under ASP-KAN-HAQ.
///
/// Codes are `0 ..= R-1` with `R = G·2^LD`; code `q` maps to the float value
/// `lo + q·step`. Because `R` divides the knot grid exactly, `q >> LD` is
/// the knot interval and `q & (2^LD - 1)` the in-interval offset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AspSpec {
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
    pub ld: u32,
    pub lo: f64,
    pub hi: f64,
}

impl AspSpec {
    /// Build a spec, solving for the optimal `LD`.
    pub fn build(g: u32, k: u32, n_bits: u32, lo: f64, hi: f64) -> Result<Self> {
        if hi <= lo {
            return Err(Error::Config(format!("empty input range [{lo}, {hi}]")));
        }
        Ok(Self { g, k, n_bits, ld: solve_ld(g, n_bits)?, lo, hi })
    }

    /// Levels per knot interval, `L = 2^LD`.
    #[inline]
    pub fn levels_per_interval(&self) -> u32 {
        1 << self.ld
    }

    /// Number of input codes `R = G·2^LD`.
    #[inline]
    pub fn range(&self) -> u32 {
        self.g * self.levels_per_interval()
    }

    /// Quantization step `δ = (hi − lo) / R`.
    #[inline]
    pub fn step(&self) -> f64 {
        (self.hi - self.lo) / self.range() as f64
    }

    /// Knot spacing `h = (hi − lo) / G`.
    #[inline]
    pub fn knot_spacing(&self) -> f64 {
        (self.hi - self.lo) / self.g as f64
    }

    /// Number of basis functions `G + K`.
    #[inline]
    pub fn num_basis(&self) -> usize {
        (self.g + self.k) as usize
    }

    /// Float → code (round-to-nearest, saturating at the grid edges).
    ///
    /// Non-finite inputs quantize deterministically: `+∞` saturates to
    /// the top code, `-∞` and `NaN` to code 0. (Previously `NaN` fell
    /// into code 0 only by accident of `f64::max` — serving admission
    /// additionally rejects non-finite feature rows outright, see
    /// `coordinator::server`; this is the defense-in-depth layer for
    /// direct callers.)
    #[inline]
    pub fn quantize(&self, x: f64) -> u32 {
        if !x.is_finite() {
            return if x == f64::INFINITY { self.range() - 1 } else { 0 };
        }
        let q = ((x - self.lo) / self.step()).round();
        (q.max(0.0) as u32).min(self.range() - 1)
    }

    /// Code → float on the aligned grid.
    #[inline]
    pub fn dequantize(&self, q: u32) -> f64 {
        self.lo + q as f64 * self.step()
    }

    /// Code → grid coordinate `z ∈ [0, G)`; exact thanks to alignment.
    #[inline]
    pub fn grid_coord(&self, q: u32) -> f64 {
        q as f64 / self.levels_per_interval() as f64
    }

    /// PowerGap bit-field split: code → (global interval `j`, local `l`).
    ///
    /// This *is* the hardware: an (n−D)-bit decoder for `j` and a D-bit
    /// decoder for `l`, instead of one monolithic n-bit decoder.
    #[inline]
    pub fn decompose(&self, q: u32) -> (u32, u32) {
        (q >> self.ld, q & (self.levels_per_interval() - 1))
    }

    /// The active basis indices for a code in interval `j`: `j ..= j+K`.
    #[inline]
    pub fn active_bases(&self, j: u32) -> std::ops::RangeInclusive<usize> {
        (j as usize)..=(j + self.k) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_ld_matches_paper_examples() {
        // 8-bit input: G=5 -> L=2^5=32 (range 160), G=8 -> 32 (256),
        // G=16 -> 16, G=32 -> 8, G=64 -> 4.
        assert_eq!(solve_ld(5, 8).unwrap(), 5);
        assert_eq!(solve_ld(8, 8).unwrap(), 5);
        assert_eq!(solve_ld(16, 8).unwrap(), 4);
        assert_eq!(solve_ld(32, 8).unwrap(), 3);
        assert_eq!(solve_ld(64, 8).unwrap(), 2);
        // exact fit: G = 2^n
        assert_eq!(solve_ld(256, 8).unwrap(), 0);
    }

    #[test]
    fn solve_ld_rejects_oversized_grid() {
        assert!(solve_ld(257, 8).is_err());
        assert!(solve_ld(0, 8).is_err());
    }

    #[test]
    fn eq6_holds() {
        for n in 4..=10u32 {
            for g in 1..=(1u32 << n) {
                let ld = solve_ld(g, n).unwrap();
                assert!(u64::from(g) << ld <= 1u64 << n, "g={g} n={n} ld={ld}");
                assert!(u64::from(g) << (ld + 1) > 1u64 << n, "ld not maximal");
            }
        }
    }

    #[test]
    fn quantize_roundtrip_and_alignment() {
        let spec = AspSpec::build(5, 3, 8, -1.0, 1.0).unwrap();
        assert_eq!(spec.range(), 160);
        // knot boundaries land exactly on codes that are multiples of 2^LD
        for j in 0..spec.g {
            let knot = spec.lo + j as f64 * spec.knot_spacing();
            let q = spec.quantize(knot);
            assert_eq!(q % spec.levels_per_interval(), 0, "knot {j} misaligned");
            assert_eq!(q >> spec.ld, j);
        }
        // saturation
        assert_eq!(spec.quantize(-5.0), 0);
        assert_eq!(spec.quantize(5.0), spec.range() - 1);
    }

    #[test]
    fn quantize_non_finite_is_deterministic() {
        let spec = AspSpec::build(5, 3, 8, -1.0, 1.0).unwrap();
        assert_eq!(spec.quantize(f64::NAN), 0);
        assert_eq!(spec.quantize(-f64::NAN), 0);
        assert_eq!(spec.quantize(f64::NEG_INFINITY), 0);
        assert_eq!(spec.quantize(f64::INFINITY), spec.range() - 1);
    }

    #[test]
    fn decompose_reassembles() {
        let spec = AspSpec::build(7, 3, 8, 0.0, 1.0).unwrap();
        for q in 0..spec.range() {
            let (j, l) = spec.decompose(q);
            assert_eq!(j * spec.levels_per_interval() + l, q);
            assert!(j < spec.g);
            assert!(l < spec.levels_per_interval());
        }
    }

    #[test]
    fn grid_coord_is_exact() {
        let spec = AspSpec::build(5, 3, 8, -2.0, 3.0).unwrap();
        for q in 0..spec.range() {
            let z = spec.grid_coord(q);
            let (j, l) = spec.decompose(q);
            let expect = j as f64 + l as f64 / spec.levels_per_interval() as f64;
            assert_eq!(z, expect);
        }
    }
}
