//! ASP-KAN-HAQ quantization (paper §3.1) and the conventional baseline.
//!
//! * [`asp`] — phase 1 (Alignment-Symmetry) + phase 2 (PowerGap) geometry.
//! * [`shlut`] — the Sharable-Hemi LUT built on top of an [`asp::AspSpec`].
//! * [`pact`] — PACT-style conventional quantization, the Fig 10 baseline.

pub mod asp;
pub mod pact;
pub mod shlut;

pub use asp::{solve_ld, AspSpec};
pub use pact::PactSpec;
pub use shlut::ShLut;
