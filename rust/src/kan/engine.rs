//! Planned execution engine for the digital KAN hot path.
//!
//! [`KanEngine`] executes a compiled [`KanPlan`]: integer-exact spline
//! partial sums (`i64` accumulation of `lut_code · ci'`, one final
//! `lut_scale · coeff_scale` conversion), hidden activations kept in
//! `f64` end-to-end, preallocated [`EngineScratch`] arenas so the
//! steady-state per-sample loop performs **zero heap allocations**, and
//! chunked multi-worker batch execution that is bit-identical regardless
//! of the worker count (rows are independent; each worker owns a
//! disjoint output slice).
//!
//! The scalar reference (`QuantKanLayer::forward_digital`) stays the
//! golden path; the engine agrees with it within float-summation-order
//! tolerance and exactly in argmax on the artifact dataset (enforced by
//! `rust/tests/engine.rs`). Contract details: `docs/ENGINE.md`.

use crate::error::Result;
use crate::kan::checkpoint::Dataset;
use crate::kan::model::{argmax, QuantKanModel};
use crate::kan::plan::{KanPlan, PlanOptions};
use crate::mapping::MappingStrategy;

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Coefficient-tile placement order (see [`PlanOptions::mapping`]).
    pub mapping: MappingStrategy,
    /// Per-code fusion budget (see [`PlanOptions::fused_budget`]).
    pub fused_budget: usize,
    /// Default worker count for the allocating
    /// [`KanEngine::forward_batch`] convenience path. `1` is right when
    /// an outer pool (the serving workers) already provides parallelism;
    /// benches and offline eval raise it.
    pub workers: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        let plan = PlanOptions::default();
        Self {
            mapping: plan.mapping,
            fused_budget: plan.fused_budget,
            workers: 1,
        }
    }
}

/// Preallocated per-worker arenas: one scratch serves any number of
/// sequential samples without touching the allocator.
#[derive(Debug, Clone)]
pub struct EngineScratch {
    /// Quantized codes of the current layer input.
    codes: Vec<u32>,
    /// i64 spline accumulator.
    acc: Vec<i64>,
    /// Current / next activation vectors (f64 end-to-end), swapped
    /// between layers.
    h: Vec<f64>,
    h2: Vec<f64>,
}

/// The compiled, executable form of a [`QuantKanModel`].
#[derive(Debug, Clone)]
pub struct KanEngine {
    plan: KanPlan,
    workers: usize,
}

impl KanEngine {
    /// Compile `model` with a distribution prior for tile ranking (no
    /// calibration data needed).
    pub fn compile(model: &QuantKanModel, opts: EngineOptions) -> Result<Self> {
        Self::compile_inner(model, opts, None)
    }

    /// Compile with calibration rows for empirical tile ranking.
    pub fn compile_with_calib(
        model: &QuantKanModel,
        opts: EngineOptions,
        calib: &[Vec<f32>],
    ) -> Result<Self> {
        Self::compile_inner(model, opts, Some(calib))
    }

    fn compile_inner(
        model: &QuantKanModel,
        opts: EngineOptions,
        calib: Option<&[Vec<f32>]>,
    ) -> Result<Self> {
        let plan_opts = PlanOptions {
            mapping: opts.mapping,
            fused_budget: opts.fused_budget,
        };
        Ok(Self {
            plan: KanPlan::compile(model, &plan_opts, calib)?,
            workers: opts.workers.max(1),
        })
    }

    pub fn plan(&self) -> &KanPlan {
        &self.plan
    }

    pub fn input_dim(&self) -> usize {
        self.plan.input_dim
    }

    pub fn output_dim(&self) -> usize {
        self.plan.output_dim
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Allocate one worker's scratch arenas, sized for this plan.
    pub fn new_scratch(&self) -> EngineScratch {
        let w = self.plan.max_width;
        EngineScratch {
            codes: vec![0u32; w],
            acc: vec![0i64; w],
            h: vec![0.0f64; w],
            h2: vec![0.0f64; w],
        }
    }

    /// Forward one sample into `out` using `s` — the zero-allocation
    /// steady-state path.
    pub fn forward_into(&self, x: &[f32], out: &mut [f64], s: &mut EngineScratch) {
        assert_eq!(x.len(), self.plan.input_dim, "engine input width");
        assert_eq!(out.len(), self.plan.output_dim, "engine output width");
        // widen the input once; hidden activations stay f64 end-to-end
        for (dst, &v) in s.h.iter_mut().zip(x.iter()) {
            *dst = v as f64;
        }
        let mut width = x.len();
        let last = self.plan.layers.len() - 1;
        for (li, layer) in self.plan.layers.iter().enumerate() {
            debug_assert_eq!(width, layer.din);
            for (c, v) in s.codes.iter_mut().zip(&s.h[..width]) {
                *c = layer.spec.quantize(*v);
            }
            let acc = &mut s.acc[..layer.dout];
            if li == last {
                layer.forward_codes(&s.codes[..width], acc, out);
            } else {
                layer.forward_codes(&s.codes[..width], acc, &mut s.h2[..layer.dout]);
                std::mem::swap(&mut s.h, &mut s.h2);
            }
            width = layer.dout;
        }
    }

    /// Forward one sample (allocating convenience wrapper).
    pub fn forward(&self, x: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.plan.output_dim];
        let mut s = self.new_scratch();
        self.forward_into(x, &mut out, &mut s);
        out
    }

    /// Batch forward over caller-owned arenas: `x` is `[batch, din]`
    /// row-major, `out` is `[batch, dout]`, and `scratches.len()` is the
    /// worker count. With one scratch the batch runs inline on the
    /// calling thread; with more, rows are chunked across scoped worker
    /// threads, each writing its disjoint output slice — outputs are
    /// bit-identical for any worker count.
    pub fn forward_batch_with(
        &self,
        x: &[f32],
        batch: usize,
        out: &mut [f64],
        scratches: &mut [EngineScratch],
    ) {
        let din = self.plan.input_dim;
        let dout = self.plan.output_dim;
        assert_eq!(x.len(), batch * din, "engine batch input size");
        assert_eq!(out.len(), batch * dout, "engine batch output size");
        assert!(!scratches.is_empty(), "need at least one scratch");
        let workers = scratches.len().min(batch.max(1));
        if workers <= 1 {
            let s = &mut scratches[0];
            for b in 0..batch {
                self.forward_into(
                    &x[b * din..(b + 1) * din],
                    &mut out[b * dout..(b + 1) * dout],
                    s,
                );
            }
            return;
        }
        let chunk = batch.div_ceil(workers);
        std::thread::scope(|scope| {
            let mut rest_x = x;
            let mut rest_out = &mut *out;
            for s in scratches.iter_mut().take(workers) {
                if rest_x.is_empty() {
                    break;
                }
                let rows = chunk.min(rest_x.len() / din);
                let (cx, rx) = rest_x.split_at(rows * din);
                // move the &mut slice out before splitting so the chunk
                // keeps the outer lifetime (a plain reborrow could not be
                // sent into the scoped thread and reassigned)
                let (co, ro) =
                    std::mem::take(&mut rest_out).split_at_mut(rows * dout);
                rest_x = rx;
                rest_out = ro;
                scope.spawn(move || {
                    for b in 0..rows {
                        self.forward_into(
                            &cx[b * din..(b + 1) * din],
                            &mut co[b * dout..(b + 1) * dout],
                            s,
                        );
                    }
                });
            }
        });
    }

    /// Batch forward (allocating convenience wrapper; uses
    /// [`EngineOptions::workers`] scratches).
    pub fn forward_batch(&self, x: &[f32], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; batch * self.plan.output_dim];
        let mut scratches: Vec<EngineScratch> = (0..self.workers.min(batch.max(1)))
            .map(|_| self.new_scratch())
            .collect();
        self.forward_batch_with(x, batch, &mut out, &mut scratches);
        out
    }

    /// Argmax prediction for one sample.
    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    /// Top-1 accuracy on the artifact test split (single scratch, no
    /// per-row allocation).
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut s = self.new_scratch();
        let mut out = vec![0.0f64; self.plan.output_dim];
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            self.forward_into(row, &mut out, &mut s);
            if argmax(&out) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    fn toy_model(g: u32, k: u32, dims: &[usize]) -> QuantKanModel {
        let layers = dims
            .windows(2)
            .map(|w| toy_layer(g, k, w[0], w[1]))
            .collect();
        QuantKanModel {
            name: "toy".into(),
            dims: dims.to_vec(),
            g,
            k,
            layers,
        }
    }

    #[test]
    fn engine_matches_reference_forward() {
        let model = toy_model(5, 3, &[4, 3, 2]);
        let engine = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let x = [0.3f32, -0.7, 0.95, -0.05];
        let want = model.forward(&x);
        let got = engine.forward(&x);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs())),
                "{a} vs {b}"
            );
        }
    }

    #[test]
    fn fused_and_tile_paths_are_bit_identical() {
        let model = toy_model(5, 3, &[3, 4, 2]);
        let fused = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let tiled = KanEngine::compile(
            &model,
            EngineOptions { fused_budget: 0, ..Default::default() },
        )
        .unwrap();
        assert!(fused.plan().layers[0].uses_fused());
        assert!(!tiled.plan().layers[0].uses_fused());
        let mut lg = crate::data::LoadGen::new(11, 3);
        for _ in 0..50 {
            let x = lg.next_vec();
            let a = fused.forward(&x);
            let b = tiled.forward(&x);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn mapping_order_does_not_change_outputs() {
        let model = toy_model(8, 3, &[2, 3]);
        let sam = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let uni = KanEngine::compile(
            &model,
            EngineOptions {
                mapping: MappingStrategy::Uniform,
                fused_budget: 0,
                workers: 1,
            },
        )
        .unwrap();
        let mut lg = crate::data::LoadGen::new(3, 2);
        for _ in 0..25 {
            let x = lg.next_vec();
            let a = sam.forward(&x);
            let b = uni.forward(&x);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn batch_matches_single_for_any_worker_count() {
        let model = toy_model(5, 3, &[4, 5, 3]);
        let engine = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let mut lg = crate::data::LoadGen::new(7, 4);
        let batch = 23usize;
        let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
        let mut want = vec![0.0f64; batch * 3];
        let mut one = vec![engine.new_scratch()];
        engine.forward_batch_with(&flat, batch, &mut want, &mut one);
        for workers in [2usize, 3, 8, 64] {
            let mut out = vec![0.0f64; batch * 3];
            let mut scratches: Vec<EngineScratch> =
                (0..workers).map(|_| engine.new_scratch()).collect();
            engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn compile_with_calib_ranks_and_still_matches() {
        let model = toy_model(5, 3, &[2, 2]);
        let mut lg = crate::data::LoadGen::new(21, 2);
        let calib = lg.batch(64);
        let engine =
            KanEngine::compile_with_calib(&model, EngineOptions::default(), &calib)
                .unwrap();
        for row in calib.iter().take(10) {
            let want = model.forward(row);
            let got = engine.forward(row);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }
}
