//! Planned execution engine for the digital KAN hot path.
//!
//! [`KanEngine`] executes a compiled [`KanPlan`]: integer-exact spline
//! partial sums (`i64` accumulation of `lut_code · ci'`, one final
//! `lut_scale · coeff_scale` conversion), hidden activations kept in
//! `f64` end-to-end, preallocated [`EngineScratch`] arenas so the
//! steady-state per-sample loop performs **zero heap allocations**, and
//! chunked multi-worker batch execution that is bit-identical regardless
//! of the worker count (rows are independent; each worker owns a
//! disjoint output slice).
//!
//! Batches execute **batch-major**: rows are cut into micro-blocks of
//! [`EngineOptions::block`] rows, each layer gathers the block's
//! quantized codes into a column-major (structure-of-arrays) arena, and
//! [`crate::kan::plan::LayerPlan::accumulate_batch`] groups the rows of
//! every input column by shared code so each `(input, interval)`
//! coefficient tile is materialized once and amortized across all rows
//! that hit it, through the fixed-width kernels of
//! [`crate::kan::kernels`]. Integer accumulation is order-independent,
//! so the regrouped outputs are bit-identical to the row-major
//! single-sample path ([`KanEngine::forward_into`]) — a contract
//! `rust/tests/engine.rs` enforces per bit.
//!
//! The scalar reference (`QuantKanLayer::forward_digital`) stays the
//! golden path; the engine agrees with it within float-summation-order
//! tolerance and exactly in argmax on the artifact dataset (enforced by
//! `rust/tests/engine.rs`). Contract details: `docs/ENGINE.md`; tuning:
//! `docs/PERFORMANCE.md`.

use crate::error::Result;
use crate::kan::checkpoint::Dataset;
use crate::kan::model::{argmax, QuantKanModel};
use crate::kan::plan::{KanPlan, PlanOptions};
use crate::mapping::MappingStrategy;
use crate::util::json::{arr, obj, Value};

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Coefficient-tile placement order (see [`PlanOptions::mapping`]).
    pub mapping: MappingStrategy,
    /// Per-code fusion budget (see [`PlanOptions::fused_budget`]).
    pub fused_budget: usize,
    /// Default worker count for the allocating
    /// [`KanEngine::forward_batch`] convenience path. `1` is right when
    /// an outer pool (the serving workers) already provides parallelism;
    /// benches and offline eval raise it.
    pub workers: usize,
    /// Batch-major micro-block size: rows per structure-of-arrays block.
    /// Larger blocks amortize more tile loads per column but grow the
    /// scratch arenas; clamped to `1..=`[`MAX_BLOCK`]. `kan-edge
    /// tune-engine` sweeps this (`docs/PERFORMANCE.md`).
    pub block: usize,
    /// Minimum rows for the grouped batch-major path; blocks with fewer
    /// rows (batch tails, tiny batches) run the row-major
    /// [`KanEngine::forward_into`] loop, which skips the counting-sort
    /// setup. Values above [`MAX_BLOCK`] force row-major execution
    /// everywhere — the autotuner's baseline candidate.
    pub group_threshold: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        let plan = PlanOptions::default();
        Self {
            mapping: plan.mapping,
            fused_budget: plan.fused_budget,
            workers: 1,
            block: 64,
            group_threshold: 2,
        }
    }
}

/// Upper bound on [`EngineOptions::block`]: bounds the per-scratch arena
/// footprint (`block · max_width` entries of u32 + i64 + 2·f64 ≈ 28 B
/// each) and lets a `group_threshold` above it mean "always row-major".
pub const MAX_BLOCK: usize = 1024;

/// Preallocated per-worker arenas: one scratch serves any number of
/// sequential samples without touching the allocator.
#[derive(Debug, Clone)]
pub struct EngineScratch {
    /// Quantized codes of the current layer input (row-major path).
    codes: Vec<u32>,
    /// i64 spline accumulator (row-major path).
    acc: Vec<i64>,
    /// Current / next activation vectors (f64 end-to-end), swapped
    /// between layers.
    h: Vec<f64>,
    h2: Vec<f64>,
    /// Batch-major arenas (`block · max_width` each): column-major codes
    /// of the current block (`cols[i · n + r]`), per-row i64
    /// accumulators, and the current / next block activations.
    cols: Vec<u32>,
    bacc: Vec<i64>,
    bh: Vec<f64>,
    bh2: Vec<f64>,
    /// Counting-sort bucket cursors (`max layer range + 1`) and the
    /// grouped row permutation (`block`) for the SoA gather.
    starts: Vec<u32>,
    order: Vec<u32>,
    /// Staging row (`max_width`) for one materialized LUT×tile product.
    tmp: Vec<i64>,
    /// Opt-in profiling counters (see [`EngineProfile`]). `None` — the
    /// default — costs one branch per layer and nothing else; counters
    /// are plain per-scratch integers, never atomics, and the update
    /// reads the already-quantized codes, so profiling can not change
    /// an output bit.
    profile: Option<EngineProfile>,
}

impl EngineScratch {
    /// The profiling counters accumulated by this scratch, if enabled.
    pub fn profile(&self) -> Option<&EngineProfile> {
        self.profile.as_ref()
    }

    /// Take the accumulated counters out, leaving zeroed counters in
    /// place (the merge-then-reset idiom of the serving accumulator).
    pub fn take_profile(&mut self) -> Option<EngineProfile> {
        let p = self.profile.as_mut()?;
        let taken = p.clone();
        p.reset();
        Some(taken)
    }
}

/// Per-layer engine profiling counters.
#[derive(Debug, Clone, Default)]
pub struct LayerProfile {
    /// Codes served by the tiled path (each code touches one
    /// `(input, interval)` coefficient tile).
    pub tiles_touched: u64,
    /// Codes served by the per-code fused-row fast path.
    pub fused_hits: u64,
    /// LUT×tile products actually materialized on the tiled path. Under
    /// batch-major grouping rows sharing a code reuse one product, so
    /// `tile_loads ≤ tiles_touched` and the ratio is the measured
    /// amortization; in row-major execution the two counters advance in
    /// lockstep. The fused path loads no tiles, so fused layers keep
    /// this at 0.
    pub tile_loads: u64,
    /// Live interval-occupancy histogram, `din · G` buckets in the same
    /// layout as the SAM calibration prior
    /// ([`crate::kan::plan::LayerPlan::prior`]).
    pub interval_counts: Vec<u64>,
}

/// Engine profiling counters for one plan: samples executed plus one
/// [`LayerProfile`] per layer. Compare `interval_counts` against the
/// stored calibration prior with [`crate::obs::rank_correlation`] to get
/// the per-layer "mapping drift" statistic (`docs/OBSERVABILITY.md`).
#[derive(Debug, Clone)]
pub struct EngineProfile {
    /// Samples (single-row forwards) executed while profiling.
    pub samples: u64,
    pub layers: Vec<LayerProfile>,
}

impl EngineProfile {
    /// Zeroed counters shaped for `plan`.
    pub fn new(plan: &KanPlan) -> EngineProfile {
        EngineProfile {
            samples: 0,
            layers: plan
                .layers
                .iter()
                .map(|l| LayerProfile {
                    interval_counts: vec![0u64; l.din * l.intervals()],
                    ..LayerProfile::default()
                })
                .collect(),
        }
    }

    /// Accumulate `other` into `self` (shapes must match).
    pub fn merge(&mut self, other: &EngineProfile) {
        self.samples += other.samples;
        for (dst, src) in self.layers.iter_mut().zip(&other.layers) {
            dst.tiles_touched += src.tiles_touched;
            dst.fused_hits += src.fused_hits;
            dst.tile_loads += src.tile_loads;
            for (d, s) in dst.interval_counts.iter_mut().zip(&src.interval_counts) {
                *d += *s;
            }
        }
    }

    /// Zero all counters in place.
    pub fn reset(&mut self) {
        self.samples = 0;
        for l in &mut self.layers {
            l.tiles_touched = 0;
            l.fused_hits = 0;
            l.tile_loads = 0;
            l.interval_counts.fill(0);
        }
    }

    /// Render for the metrics plane: per layer the path counters plus
    /// `mapping_drift_rankcorr`, the Spearman correlation between the
    /// live occupancy histogram and the SAM calibration prior stored in
    /// `plan` (1.0 = calibration ranking still matches traffic, ~0 =
    /// unrelated; 0.0 also before any sample has been profiled).
    pub fn to_value(&self, plan: &KanPlan) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .zip(&plan.layers)
            .map(|(lp, pl)| {
                let live: Vec<f64> =
                    lp.interval_counts.iter().map(|&c| c as f64).collect();
                obj(vec![
                    ("tiles_touched", Value::Int(lp.tiles_touched as i64)),
                    ("fused_hits", Value::Int(lp.fused_hits as i64)),
                    ("tile_loads", Value::Int(lp.tile_loads as i64)),
                    (
                        "mapping_drift_rankcorr",
                        Value::Float(crate::obs::rank_correlation(pl.prior(), &live)),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("samples", Value::Int(self.samples as i64)),
            ("layers", arr(layers)),
        ])
    }
}

/// The compiled, executable form of a [`QuantKanModel`].
#[derive(Debug, Clone)]
pub struct KanEngine {
    plan: KanPlan,
    workers: usize,
    /// Batch-major micro-block rows (sanitized [`EngineOptions::block`]).
    block: usize,
    /// Minimum block rows for the grouped path
    /// ([`EngineOptions::group_threshold`]).
    group_threshold: usize,
    /// Widest quantizer range across the layers (counting-sort buckets).
    max_range: usize,
}

impl KanEngine {
    /// Compile `model` with a distribution prior for tile ranking (no
    /// calibration data needed).
    pub fn compile(model: &QuantKanModel, opts: EngineOptions) -> Result<Self> {
        Self::compile_inner(model, opts, None)
    }

    /// Compile with calibration rows for empirical tile ranking.
    pub fn compile_with_calib(
        model: &QuantKanModel,
        opts: EngineOptions,
        calib: &[Vec<f32>],
    ) -> Result<Self> {
        Self::compile_inner(model, opts, Some(calib))
    }

    fn compile_inner(
        model: &QuantKanModel,
        opts: EngineOptions,
        calib: Option<&[Vec<f32>]>,
    ) -> Result<Self> {
        let plan_opts = PlanOptions {
            mapping: opts.mapping,
            fused_budget: opts.fused_budget,
        };
        let plan = KanPlan::compile(model, &plan_opts, calib)?;
        let max_range = plan.layers.iter().map(|l| l.range()).max().unwrap_or(1);
        Ok(Self {
            plan,
            workers: opts.workers.max(1),
            block: opts.block.clamp(1, MAX_BLOCK),
            group_threshold: opts.group_threshold.max(2),
            max_range,
        })
    }

    pub fn plan(&self) -> &KanPlan {
        &self.plan
    }

    pub fn input_dim(&self) -> usize {
        self.plan.input_dim
    }

    pub fn output_dim(&self) -> usize {
        self.plan.output_dim
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Sanitized batch-major micro-block size this engine executes with.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Minimum block rows for the grouped batch-major path.
    pub fn group_threshold(&self) -> usize {
        self.group_threshold
    }

    /// Allocate one worker's scratch arenas, sized for this plan.
    pub fn new_scratch(&self) -> EngineScratch {
        let w = self.plan.max_width;
        let b = self.block;
        EngineScratch {
            codes: vec![0u32; w],
            acc: vec![0i64; w],
            h: vec![0.0f64; w],
            h2: vec![0.0f64; w],
            cols: vec![0u32; w * b],
            bacc: vec![0i64; w * b],
            bh: vec![0.0f64; w * b],
            bh2: vec![0.0f64; w * b],
            starts: vec![0u32; self.max_range + 1],
            order: vec![0u32; b],
            tmp: vec![0i64; w],
            profile: None,
        }
    }

    /// Like [`Self::new_scratch`] but with profiling counters attached:
    /// every forward through this scratch also updates per-layer tile /
    /// fused-path counts and the interval-occupancy histogram.
    pub fn new_scratch_profiled(&self) -> EngineScratch {
        let mut s = self.new_scratch();
        s.profile = Some(EngineProfile::new(&self.plan));
        s
    }

    /// Forward one sample into `out` using `s` — the zero-allocation
    /// steady-state path.
    pub fn forward_into(&self, x: &[f32], out: &mut [f64], s: &mut EngineScratch) {
        assert_eq!(x.len(), self.plan.input_dim, "engine input width");
        assert_eq!(out.len(), self.plan.output_dim, "engine output width");
        // widen the input once; hidden activations stay f64 end-to-end
        for (dst, &v) in s.h.iter_mut().zip(x.iter()) {
            *dst = v as f64;
        }
        let mut width = x.len();
        let last = self.plan.layers.len() - 1;
        if let Some(p) = s.profile.as_mut() {
            p.samples += 1;
        }
        for (li, layer) in self.plan.layers.iter().enumerate() {
            debug_assert_eq!(width, layer.din);
            for (c, v) in s.codes.iter_mut().zip(&s.h[..width]) {
                *c = layer.spec.quantize(*v);
            }
            // profiling reads the already-quantized codes and writes only
            // its own per-scratch counters — it cannot perturb the
            // integer dataflow below (bit-parity enforced in tests)
            if let Some(p) = s.profile.as_mut() {
                let lp = &mut p.layers[li];
                let g = layer.intervals();
                for (i, &q) in s.codes[..width].iter().enumerate() {
                    lp.interval_counts[i * g + (q >> layer.spec.ld) as usize] += 1;
                }
                if layer.uses_fused() {
                    lp.fused_hits += width as u64;
                } else {
                    // row-major: every code materializes its own product
                    lp.tiles_touched += width as u64;
                    lp.tile_loads += width as u64;
                }
            }
            let acc = &mut s.acc[..layer.dout];
            if li == last {
                layer.forward_codes(&s.codes[..width], acc, out);
            } else {
                layer.forward_codes(&s.codes[..width], acc, &mut s.h2[..layer.dout]);
                std::mem::swap(&mut s.h, &mut s.h2);
            }
            width = layer.dout;
        }
    }

    /// Forward one sample (allocating convenience wrapper).
    pub fn forward(&self, x: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.plan.output_dim];
        let mut s = self.new_scratch();
        self.forward_into(x, &mut out, &mut s);
        out
    }

    /// Forward a contiguous run of rows on one scratch: cut into
    /// micro-blocks of [`Self::block`] rows, each executed batch-major
    /// through [`Self::forward_block`] when it reaches
    /// [`Self::group_threshold`] rows, row-major otherwise (the
    /// counting-sort setup is not worth it for a short tail). Both paths
    /// produce bit-identical rows, so the dispatch is invisible in the
    /// outputs.
    fn forward_rows(&self, x: &[f32], rows: usize, out: &mut [f64], s: &mut EngineScratch) {
        let din = self.plan.input_dim;
        let dout = self.plan.output_dim;
        debug_assert_eq!(x.len(), rows * din);
        debug_assert_eq!(out.len(), rows * dout);
        let mut done = 0usize;
        while done < rows {
            let n = self.block.min(rows - done);
            let cx = &x[done * din..(done + n) * din];
            let co = &mut out[done * dout..(done + n) * dout];
            if n < self.group_threshold {
                for b in 0..n {
                    self.forward_into(
                        &cx[b * din..(b + 1) * din],
                        &mut co[b * dout..(b + 1) * dout],
                        s,
                    );
                }
            } else {
                self.forward_block(cx, n, co, s);
            }
            done += n;
        }
    }

    /// Batch-major execution of one micro-block of `n` rows.
    ///
    /// Per layer: quantize the block's activations into the column-major
    /// `cols` arena (the SoA gather — `cols[i·n + r]` so each input
    /// column is contiguous for the grouping sort), hand the columns to
    /// [`crate::kan::plan::LayerPlan::accumulate_batch`] for the grouped
    /// integer accumulation, then finish each row's float conversion and
    /// residual in row order. Nothing here allocates; the arenas were
    /// sized by [`Self::new_scratch`].
    fn forward_block(&self, x: &[f32], n: usize, out: &mut [f64], s: &mut EngineScratch) {
        debug_assert!((1..=self.block).contains(&n));
        assert!(
            s.bh.len() >= n * self.plan.max_width
                && s.order.len() >= n
                && s.starts.len() > self.max_range,
            "scratch arenas too small for this engine (use KanEngine::new_scratch)"
        );
        // widen the block's inputs once; activations stay f64 end-to-end
        for (dst, &v) in s.bh.iter_mut().zip(x.iter()) {
            *dst = v as f64;
        }
        let mut width = self.plan.input_dim;
        let last = self.plan.layers.len() - 1;
        if let Some(p) = s.profile.as_mut() {
            p.samples += n as u64;
        }
        for (li, layer) in self.plan.layers.iter().enumerate() {
            debug_assert_eq!(width, layer.din);
            // SoA gather: quantize row-major activations into
            // column-major codes
            for r in 0..n {
                let row = &s.bh[r * width..][..width];
                for (i, &h) in row.iter().enumerate() {
                    s.cols[i * n + r] = layer.spec.quantize(h);
                }
            }
            // profiling reads the already-quantized codes and writes only
            // its own per-scratch counters (bit-parity enforced in tests);
            // tile_loads is added below from the actual grouping outcome
            if let Some(p) = s.profile.as_mut() {
                let lp = &mut p.layers[li];
                let g = layer.intervals();
                for i in 0..width {
                    for &q in &s.cols[i * n..][..n] {
                        lp.interval_counts[i * g + (q >> layer.spec.ld) as usize] += 1;
                    }
                }
                if layer.uses_fused() {
                    lp.fused_hits += (n * width) as u64;
                } else {
                    lp.tiles_touched += (n * width) as u64;
                }
            }
            let dout = layer.dout;
            let loads = layer.accumulate_batch(
                &s.cols[..width * n],
                n,
                &mut s.starts,
                &mut s.order,
                &mut s.tmp,
                &mut s.bacc[..n * dout],
            );
            if let Some(p) = s.profile.as_mut() {
                p.layers[li].tile_loads += loads;
            }
            if li == last {
                for r in 0..n {
                    layer.finish_batch_row(
                        &s.cols[..width * n],
                        n,
                        r,
                        &s.bacc[r * dout..][..dout],
                        &mut out[r * dout..][..dout],
                    );
                }
            } else {
                for r in 0..n {
                    layer.finish_batch_row(
                        &s.cols[..width * n],
                        n,
                        r,
                        &s.bacc[r * dout..][..dout],
                        &mut s.bh2[r * dout..][..dout],
                    );
                }
                std::mem::swap(&mut s.bh, &mut s.bh2);
            }
            width = dout;
        }
    }

    /// Batch forward over caller-owned arenas: `x` is `[batch, din]`
    /// row-major, `out` is `[batch, dout]`, and `scratches.len()` is the
    /// worker count. With one scratch the batch runs inline on the
    /// calling thread; with more, rows are chunked across scoped worker
    /// threads, each writing its disjoint output slice. Each worker's
    /// run executes batch-major (see [`Self::forward_rows`]); outputs
    /// are bit-identical for any worker count, any batch size, and any
    /// block/threshold configuration.
    pub fn forward_batch_with(
        &self,
        x: &[f32],
        batch: usize,
        out: &mut [f64],
        scratches: &mut [EngineScratch],
    ) {
        let din = self.plan.input_dim;
        let dout = self.plan.output_dim;
        assert_eq!(x.len(), batch * din, "engine batch input size");
        assert_eq!(out.len(), batch * dout, "engine batch output size");
        assert!(!scratches.is_empty(), "need at least one scratch");
        let workers = scratches.len().min(batch.max(1));
        if workers <= 1 {
            self.forward_rows(x, batch, out, &mut scratches[0]);
            return;
        }
        let chunk = batch.div_ceil(workers);
        std::thread::scope(|scope| {
            let mut rest_x = x;
            let mut rest_out = &mut *out;
            for s in scratches.iter_mut().take(workers) {
                if rest_x.is_empty() {
                    break;
                }
                let rows = chunk.min(rest_x.len() / din);
                let (cx, rx) = rest_x.split_at(rows * din);
                // move the &mut slice out before splitting so the chunk
                // keeps the outer lifetime (a plain reborrow could not be
                // sent into the scoped thread and reassigned)
                let (co, ro) =
                    std::mem::take(&mut rest_out).split_at_mut(rows * dout);
                rest_x = rx;
                rest_out = ro;
                scope.spawn(move || self.forward_rows(cx, rows, co, s));
            }
        });
    }

    /// Batch forward (allocating convenience wrapper; uses
    /// [`EngineOptions::workers`] scratches).
    pub fn forward_batch(&self, x: &[f32], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; batch * self.plan.output_dim];
        let mut scratches: Vec<EngineScratch> = (0..self.workers.min(batch.max(1)))
            .map(|_| self.new_scratch())
            .collect();
        self.forward_batch_with(x, batch, &mut out, &mut scratches);
        out
    }

    /// Argmax prediction for one sample.
    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    /// Top-1 accuracy on the artifact test split (single scratch, no
    /// per-row allocation).
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut s = self.new_scratch();
        let mut out = vec![0.0f64; self.plan.output_dim];
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            self.forward_into(row, &mut out, &mut s);
            if argmax(&out) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    fn toy_model(g: u32, k: u32, dims: &[usize]) -> QuantKanModel {
        let layers = dims
            .windows(2)
            .map(|w| toy_layer(g, k, w[0], w[1]))
            .collect();
        QuantKanModel {
            name: "toy".into(),
            dims: dims.to_vec(),
            g,
            k,
            layers,
        }
    }

    #[test]
    fn engine_matches_reference_forward() {
        let model = toy_model(5, 3, &[4, 3, 2]);
        let engine = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let x = [0.3f32, -0.7, 0.95, -0.05];
        let want = model.forward(&x);
        let got = engine.forward(&x);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs())),
                "{a} vs {b}"
            );
        }
    }

    #[test]
    fn fused_and_tile_paths_are_bit_identical() {
        let model = toy_model(5, 3, &[3, 4, 2]);
        let fused = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let tiled = KanEngine::compile(
            &model,
            EngineOptions { fused_budget: 0, ..Default::default() },
        )
        .unwrap();
        assert!(fused.plan().layers[0].uses_fused());
        assert!(!tiled.plan().layers[0].uses_fused());
        let mut lg = crate::data::LoadGen::new(11, 3);
        for _ in 0..50 {
            let x = lg.next_vec();
            let a = fused.forward(&x);
            let b = tiled.forward(&x);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn mapping_order_does_not_change_outputs() {
        let model = toy_model(8, 3, &[2, 3]);
        let sam = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let uni = KanEngine::compile(
            &model,
            EngineOptions {
                mapping: MappingStrategy::Uniform,
                fused_budget: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let mut lg = crate::data::LoadGen::new(3, 2);
        for _ in 0..25 {
            let x = lg.next_vec();
            let a = sam.forward(&x);
            let b = uni.forward(&x);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn batch_matches_single_for_any_worker_count() {
        let model = toy_model(5, 3, &[4, 5, 3]);
        let engine = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let mut lg = crate::data::LoadGen::new(7, 4);
        let batch = 23usize;
        let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
        let mut want = vec![0.0f64; batch * 3];
        let mut one = vec![engine.new_scratch()];
        engine.forward_batch_with(&flat, batch, &mut want, &mut one);
        for workers in [2usize, 3, 8, 64] {
            let mut out = vec![0.0f64; batch * 3];
            let mut scratches: Vec<EngineScratch> =
                (0..workers).map(|_| engine.new_scratch()).collect();
            engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn profiled_scratch_is_bit_identical_and_counts() {
        let model = toy_model(5, 3, &[4, 3, 2]);
        let engine = KanEngine::compile(&model, EngineOptions::default()).unwrap();
        let mut plain = engine.new_scratch();
        let mut prof = engine.new_scratch_profiled();
        let mut lg = crate::data::LoadGen::new(5, 4);
        let mut a = vec![0.0f64; 2];
        let mut b = vec![0.0f64; 2];
        for _ in 0..40 {
            let x = lg.next_vec();
            engine.forward_into(&x, &mut a, &mut plain);
            engine.forward_into(&x, &mut b, &mut prof);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
        let p = prof.profile().unwrap();
        assert_eq!(p.samples, 40);
        assert_eq!(p.layers.len(), 2);
        let l0 = &p.layers[0];
        // every sample quantizes din codes, each landing in one interval
        assert_eq!(l0.interval_counts.iter().sum::<u64>(), 40 * 4);
        // the toy model fuses by default, so all codes hit the fast path
        assert_eq!(l0.fused_hits, 40 * 4);
        assert_eq!(l0.tiles_touched, 0);
        // the rendered report carries one drift statistic per layer
        let v = p.to_value(engine.plan());
        let layers = v.get("layers").and_then(|l| l.as_array()).unwrap();
        assert_eq!(layers.len(), 2);
        for l in layers {
            let d = l.get("mapping_drift_rankcorr").and_then(|x| x.as_f64()).unwrap();
            assert!((-1.0..=1.0).contains(&d), "{d}");
        }
        // take_profile hands the counters out and zeroes the scratch
        let taken = prof.take_profile().unwrap();
        assert_eq!(taken.samples, 40);
        assert_eq!(prof.profile().unwrap().samples, 0);
        assert_eq!(
            prof.profile().unwrap().layers[0].interval_counts.iter().sum::<u64>(),
            0
        );
    }

    #[test]
    fn tiled_path_counts_tiles_not_fused() {
        let model = toy_model(5, 3, &[3, 2]);
        let engine = KanEngine::compile(
            &model,
            EngineOptions { fused_budget: 0, ..Default::default() },
        )
        .unwrap();
        let mut s = engine.new_scratch_profiled();
        let mut out = vec![0.0f64; 2];
        let mut lg = crate::data::LoadGen::new(9, 3);
        for _ in 0..10 {
            let x = lg.next_vec();
            engine.forward_into(&x, &mut out, &mut s);
        }
        let p = s.profile().unwrap();
        assert_eq!(p.layers[0].tiles_touched, 10 * 3);
        assert_eq!(p.layers[0].fused_hits, 0);
        // row-major execution materializes one product per code
        assert_eq!(p.layers[0].tile_loads, 10 * 3);
    }

    #[test]
    fn batch_major_block_is_bit_identical_to_row_major() {
        let model = toy_model(5, 3, &[4, 5, 3]);
        let mut lg = crate::data::LoadGen::new(17, 4);
        let batch = 41usize;
        let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
        // golden: the row-major single-sample path
        let row_major = KanEngine::compile(
            &model,
            EngineOptions { group_threshold: MAX_BLOCK + 1, ..Default::default() },
        )
        .unwrap();
        let mut want = vec![0.0f64; batch * 3];
        let mut s = row_major.new_scratch();
        for b in 0..batch {
            let dst = &mut want[b * 3..(b + 1) * 3];
            row_major.forward_into(&flat[b * 4..(b + 1) * 4], dst, &mut s);
        }
        // every block geometry — fused and tiled — must reproduce it
        for budget in [0usize, 1 << 22] {
            for (block, threshold) in [(1, 2), (7, 2), (64, 2), (64, 9), (1024, 2)] {
                let engine = KanEngine::compile(
                    &model,
                    EngineOptions {
                        fused_budget: budget,
                        block,
                        group_threshold: threshold,
                        ..Default::default()
                    },
                )
                .unwrap();
                let mut out = vec![0.0f64; batch * 3];
                let mut scratches = vec![engine.new_scratch()];
                engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
                for (a, b) in out.iter().zip(&want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "budget={budget} block={block} threshold={threshold}"
                    );
                }
            }
        }
    }

    #[test]
    fn batch_major_grouping_amortizes_tile_loads() {
        // identical rows ⇒ every column of a block collapses to ONE code
        // group ⇒ one materialized product per (input, layer, block)
        let model = toy_model(5, 3, &[3, 2]);
        let engine = KanEngine::compile(
            &model,
            EngineOptions { fused_budget: 0, block: 64, ..Default::default() },
        )
        .unwrap();
        let batch = 64usize;
        let row = [0.25f32, -0.5, 0.75];
        let flat: Vec<f32> = row.iter().copied().cycle().take(batch * 3).collect();
        let mut out = vec![0.0f64; batch * 2];
        let mut scratches = vec![engine.new_scratch_profiled()];
        engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        let p = scratches[0].profile().unwrap();
        assert_eq!(p.samples, 64);
        assert_eq!(p.layers[0].tiles_touched, 64 * 3);
        // one block, three input columns, one distinct code each
        assert_eq!(p.layers[0].tile_loads, 3);
        // outputs of identical rows are identical
        for r in 1..batch {
            for o in 0..2 {
                assert_eq!(out[r * 2 + o].to_bits(), out[o].to_bits());
            }
        }
    }

    #[test]
    fn compile_with_calib_ranks_and_still_matches() {
        let model = toy_model(5, 3, &[2, 2]);
        let mut lg = crate::data::LoadGen::new(21, 2);
        let calib = lg.batch(64);
        let engine =
            KanEngine::compile_with_calib(&model, EngineOptions::default(), &calib)
                .unwrap();
        for row in calib.iter().take(10) {
            let want = model.forward(row);
            let got = engine.forward(row);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }
}
