//! Whole-model quantized KAN inference (digital reference path) and
//! accuracy evaluation against the artifact dataset.

use std::path::Path;

use crate::error::Result;
use crate::kan::checkpoint::{Dataset, KanCheckpoint};
use crate::kan::engine::{EngineOptions, KanEngine};
use crate::kan::layer::QuantKanLayer;

/// A quantized KAN model: a stack of [`QuantKanLayer`]s.
#[derive(Debug, Clone)]
pub struct QuantKanModel {
    pub name: String,
    pub dims: Vec<usize>,
    pub g: u32,
    pub k: u32,
    pub layers: Vec<QuantKanLayer>,
}

impl QuantKanModel {
    pub fn from_checkpoint(ckpt: &KanCheckpoint) -> Self {
        let layers = ckpt
            .layers
            .iter()
            .map(|l| QuantKanLayer::from_checkpoint(l, ckpt.g, ckpt.k, ckpt.n_bits))
            .collect();
        Self {
            name: ckpt.name.clone(),
            dims: ckpt.dims.clone(),
            g: ckpt.g,
            k: ckpt.k,
            layers,
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self::from_checkpoint(&KanCheckpoint::load(path)?))
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Compile this model into the planned execution engine
    /// ([`KanEngine`], the serving hot path; see `docs/ENGINE.md`).
    pub fn compile(&self, opts: EngineOptions) -> Result<KanEngine> {
        KanEngine::compile(self, opts)
    }

    /// Digital-reference forward for one sample.
    ///
    /// Hidden activations stay `f64` end-to-end: truncating them through
    /// `f32` between layers is a double rounding that can flip a
    /// quantization code right at a level boundary (regression test
    /// below).
    pub fn forward(&self, x: &[f32]) -> Vec<f64> {
        // one sample is a batch of one: a single per-layer loop to keep
        // the two paths from ever drifting numerically
        self.forward_batch(x, 1)
    }

    /// Batch forward, `x` row-major `[batch, din]`. Hidden activations
    /// stay `f64` between layers (see [`QuantKanModel::forward`]).
    pub fn forward_batch(&self, x: &[f32], batch: usize) -> Vec<f64> {
        if self.layers.is_empty() {
            return Vec::new();
        }
        let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for layer in &self.layers {
            let mut out = vec![0.0; batch * layer.dout];
            let mut xq = vec![0u32; layer.din];
            for b in 0..batch {
                let row = &h[b * layer.din..(b + 1) * layer.din];
                for (dst, &v) in xq.iter_mut().zip(row) {
                    *dst = layer.spec.quantize(v);
                }
                layer.forward_digital(&xq, &mut out[b * layer.dout..(b + 1) * layer.dout]);
            }
            h = out;
        }
        h
    }

    /// Argmax prediction for one sample.
    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    /// Top-1 accuracy on the artifact test split.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            if self.predict(row) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn model_batch_matches_single() {
        let model = QuantKanModel {
            name: "toy".into(),
            dims: vec![3, 4, 2],
            g: 5,
            k: 3,
            layers: vec![toy_layer(5, 3, 3, 4), toy_layer(5, 3, 4, 2)],
        };
        let x = [0.3f32, -0.7, 0.95, -0.05, 0.0, 0.5];
        let batch = model.forward_batch(&x, 2);
        for b in 0..2 {
            let single = model.forward(&x[b * 3..(b + 1) * 3]);
            for o in 0..2 {
                assert_eq!(batch[b * 2 + o].to_bits(), single[o].to_bits());
            }
        }
    }

    /// Find an f64 activation near a quantization-level boundary of
    /// `spec` whose truncation through f32 lands on the other side —
    /// the double rounding the pre-fix inter-layer path performed.
    fn double_rounding_victim(spec: &crate::quant::AspSpec) -> Option<f64> {
        let step = spec.step();
        for q in 1..spec.range() - 2 {
            // boundary midpoint between codes q and q+1; keep it well
            // positive so the residual (ReLU) path can reproduce it
            let m = spec.lo + (q as f64 + 0.5) * step;
            if m <= 0.05 {
                continue;
            }
            let m32 = (m as f32) as f64;
            if m32 == m {
                continue;
            }
            // nudge across the boundary from the f32 image: v quantizes
            // differently from (v as f32) as f64
            let eps = step * 1e-9;
            let v = if m32 > m { m - eps } else { m + eps };
            if spec.quantize(v) != spec.quantize((v as f32) as f64) {
                return Some(v);
            }
        }
        None
    }

    #[test]
    fn hidden_activations_stay_f64_across_layers() {
        // layer 0: spline path zeroed, residual weight chosen so its
        // output is exactly a boundary-straddling value for layer 1
        let mut l0 = toy_layer(5, 3, 1, 1);
        for c in &mut l0.coeff_q {
            *c = 0;
        }
        let l1 = toy_layer(5, 3, 1, 1);
        let v = double_rounding_victim(&l1.spec).expect("no boundary victim exists");
        let x = 0.5f32;
        let xhat = l0.spec.dequantize(l0.spec.quantize(x as f64));
        assert!(xhat > 0.0);
        l0.wb[0] = v / xhat;
        // what layer 0 actually emits (1 ulp of v at most — still inside
        // the straddling window, re-checked here)
        let h = xhat * l0.wb[0];
        let q_f64 = l1.spec.quantize(h);
        let q_f32 = l1.spec.quantize((h as f32) as f64);
        assert_ne!(q_f64, q_f32, "victim did not survive the wb round trip");

        let model = QuantKanModel {
            name: "boundary".into(),
            dims: vec![1, 1, 1],
            g: 5,
            k: 3,
            layers: vec![l0, l1.clone()],
        };
        let got = model.forward(&[x]);
        let mut want = vec![0.0f64; 1];
        l1.forward_digital(&[q_f64], &mut want);
        assert_eq!(got[0].to_bits(), want[0].to_bits(), "f64 path regressed");
        // the old f32-truncating path lands on the flipped code
        let mut old = vec![0.0f64; 1];
        l1.forward_digital(&[q_f32], &mut old);
        assert_ne!(got[0].to_bits(), old[0].to_bits());
    }
}
