//! Whole-model quantized KAN inference (digital reference path) and
//! accuracy evaluation against the artifact dataset.

use std::path::Path;

use crate::error::Result;
use crate::kan::checkpoint::{Dataset, KanCheckpoint};
use crate::kan::layer::QuantKanLayer;

/// A quantized KAN model: a stack of [`QuantKanLayer`]s.
#[derive(Debug, Clone)]
pub struct QuantKanModel {
    pub name: String,
    pub dims: Vec<usize>,
    pub g: u32,
    pub k: u32,
    pub layers: Vec<QuantKanLayer>,
}

impl QuantKanModel {
    pub fn from_checkpoint(ckpt: &KanCheckpoint) -> Self {
        let layers = ckpt
            .layers
            .iter()
            .map(|l| QuantKanLayer::from_checkpoint(l, ckpt.g, ckpt.k, ckpt.n_bits))
            .collect();
        Self {
            name: ckpt.name.clone(),
            dims: ckpt.dims.clone(),
            g: ckpt.g,
            k: ckpt.k,
            layers,
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self::from_checkpoint(&KanCheckpoint::load(path)?))
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Digital-reference forward for one sample.
    pub fn forward(&self, x: &[f32]) -> Vec<f64> {
        let mut h: Vec<f32> = x.to_vec();
        let mut out = Vec::new();
        for layer in &self.layers {
            let xq = layer.quantize_input(&h);
            out = vec![0.0; layer.dout];
            layer.forward_digital(&xq, &mut out);
            h = out.iter().map(|&v| v as f32).collect();
        }
        out
    }

    /// Batch forward, `x` row-major `[batch, din]`.
    pub fn forward_batch(&self, x: &[f32], batch: usize) -> Vec<f64> {
        let mut h: Vec<f32> = x.to_vec();
        let mut out = Vec::new();
        for layer in &self.layers {
            out = layer.forward_digital_batch(&h, batch);
            h = out.iter().map(|&v| v as f32).collect();
        }
        out
    }

    /// Argmax prediction for one sample.
    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.forward(x))
    }

    /// Top-1 accuracy on the artifact test split.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            if self.predict(row) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
