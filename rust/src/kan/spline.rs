//! Uniform B-spline math: the cardinal B-spline and the KAN basis.
//!
//! The original KAN paper builds its learnable activations on a *uniform
//! extended* knot grid, which makes every basis function a shifted copy of
//! the cardinal B-spline `C_k`. That translation invariance is the property
//! ASP-KAN-HAQ exploits to share one LUT across all `G + K` basis functions
//! (paper §2.1 / §3.1); it is also why this module only ever needs `C_k`.

/// Cardinal B-spline `C_k(s)` of degree `k`, support `[0, k+1]`.
///
/// Cox–de Boor recursion on integer knots. `O(k^2)` per evaluation; the hot
/// path never calls this (it reads LUTs), so clarity wins over speed here.
pub fn cardinal_bspline(s: f64, k: usize) -> f64 {
    if !(0.0..(k as f64 + 1.0)).contains(&s) {
        return 0.0;
    }
    // degree-0 indicator pieces N_j^0, j = 0..k
    let mut n: Vec<f64> = (0..=k)
        .map(|j| {
            let j = j as f64;
            if s >= j && s < j + 1.0 {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    for d in 1..=k {
        for j in 0..=(k - d) {
            let jf = j as f64;
            let df = d as f64;
            n[j] = (s - jf) / df * n[j] + (jf + df + 1.0 - s) / df * n[j + 1];
        }
    }
    n[0]
}

/// All `g + k` basis values at grid coordinate `z ∈ [0, g]`.
///
/// Basis `i` is the cardinal spline translated so its support covers grid
/// intervals `[i-k, i]`: `B_i(z) = C_k(z - i + k)`.
pub fn basis_functions(z: f64, g: usize, k: usize) -> Vec<f64> {
    (0..g + k)
        .map(|i| cardinal_bspline(z - i as f64 + k as f64, k))
        .collect()
}

/// The `k + 1` *active* basis values for a point with local fraction
/// `u ∈ [0, 1)` inside any knot interval: `active[t] = C_k(k - t + u)`.
///
/// By translation invariance these do not depend on which interval — this
/// is the row the SH-LUT stores.
pub fn active_basis(u: f64, k: usize) -> Vec<f64> {
    (0..=k).map(|t| cardinal_bspline((k - t) as f64 + u, k)).collect()
}

/// Evaluate a full spline `sum_i c_i B_i(z)` directly (reference path).
pub fn spline_value(z: f64, coeff: &[f64], g: usize, k: usize) -> f64 {
    debug_assert_eq!(coeff.len(), g + k);
    // only bases j..j+k are non-zero at z in interval j
    let j = (z.floor() as isize).clamp(0, g as isize - 1) as usize;
    let u = z - j as f64;
    let mut acc = 0.0;
    for t in 0..=k {
        let i = j + t;
        if i < coeff.len() {
            acc += coeff[i] * cardinal_bspline((k - t) as f64 + u, k);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree0_is_indicator() {
        assert_eq!(cardinal_bspline(0.5, 0), 1.0);
        assert_eq!(cardinal_bspline(1.5, 0), 0.0);
        assert_eq!(cardinal_bspline(-0.1, 0), 0.0);
    }

    #[test]
    fn cubic_known_values() {
        // C_3 peaks at s = 2 with value 2/3; C_3(1) = C_3(3) = 1/6.
        assert!((cardinal_bspline(2.0, 3) - 2.0 / 3.0).abs() < 1e-12);
        assert!((cardinal_bspline(1.0, 3) - 1.0 / 6.0).abs() < 1e-12);
        assert!((cardinal_bspline(3.0, 3) - 1.0 / 6.0).abs() < 1e-12);
        assert_eq!(cardinal_bspline(4.0, 3), 0.0);
    }

    #[test]
    fn symmetry_about_midpoint() {
        // C_k(s) = C_k(k+1-s): the property behind the Sharable-Hemi LUT.
        for k in 1..=4usize {
            for i in 0..100 {
                let s = (k as f64 + 1.0) * i as f64 / 100.0;
                let a = cardinal_bspline(s, k);
                let b = cardinal_bspline(k as f64 + 1.0 - s, k);
                // mirror point lands exactly on a knot for s=0; half-open
                // interval makes C(k+1)=0 vs C(0)=0 consistent.
                assert!((a - b).abs() < 1e-9, "k={k} s={s}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn partition_of_unity() {
        for k in 0..=4usize {
            let g = 6;
            for i in 0..60 {
                let z = 0.05 + g as f64 * i as f64 / 61.0;
                let sum: f64 = basis_functions(z, g, k).iter().sum();
                // interior points only (z in [k.., g] edge effects excluded
                // by the extended grid construction)
                assert!((sum - 1.0).abs() < 1e-9, "k={k} z={z} sum={sum}");
            }
        }
    }

    #[test]
    fn active_basis_matches_full_basis() {
        let (g, k) = (5usize, 3usize);
        let z: f64 = 2.37;
        let j = z.floor() as usize;
        let u = z - j as f64;
        let full = basis_functions(z, g, k);
        let act = active_basis(u, k);
        for t in 0..=k {
            assert!((full[j + t] - act[t]).abs() < 1e-12);
        }
        // everything outside the active window is zero
        for (i, v) in full.iter().enumerate() {
            if i < j || i > j + k {
                assert_eq!(*v, 0.0, "basis {i} should be inactive at z={z}");
            }
        }
    }

    #[test]
    fn spline_value_matches_inner_product() {
        let (g, k) = (7usize, 3usize);
        let coeff: Vec<f64> = (0..g + k).map(|i| (i as f64 * 0.7).sin()).collect();
        for i in 0..50 {
            let z = g as f64 * i as f64 / 50.0;
            let direct = spline_value(z, &coeff, g, k);
            let full: f64 = basis_functions(z, g, k)
                .iter()
                .zip(&coeff)
                .map(|(b, c)| b * c)
                .sum();
            assert!((direct - full).abs() < 1e-9);
        }
    }
}
