//! KAN layer forward passes on the rust side.
//!
//! Two paths exist deliberately:
//!
//! * [`QuantKanLayer::forward_digital`] — the *digital reference*: exact
//!   integer LUT lookups + f64 MAC. Bit-identical to what ideal hardware
//!   (or the PJRT graph) computes, used as the golden output the ACIM
//!   simulator is compared against.
//! * `acim::tile` executes the same layer through the analog pipeline
//!   (IR-drop, device variation, ADC) — the layer exposes its integer
//!   dataflow ([`QuantKanLayer::spline_rows`]) so the crossbar can be
//!   programmed from it.

use crate::kan::checkpoint::KanLayerCheckpoint;
use crate::quant::{AspSpec, ShLut};

/// A quantized KAN layer materialized from a checkpoint.
#[derive(Debug, Clone)]
pub struct QuantKanLayer {
    pub spec: AspSpec,
    pub lut: ShLut,
    pub din: usize,
    pub dout: usize,
    /// int8 ci' codes, `[din][G+K][dout]` flattened.
    pub coeff_q: Vec<i32>,
    pub coeff_scale: f64,
    /// Residual weights `[din][dout]` flattened.
    pub wb: Vec<f64>,
}

impl QuantKanLayer {
    pub fn from_checkpoint(l: &KanLayerCheckpoint, g: u32, k: u32, n_bits: u32) -> Self {
        let spec = AspSpec { g, k, n_bits, ld: l.ld, lo: l.lo, hi: l.hi };
        // rebuild the SH-LUT from the checkpoint rows (hardware programs the
        // stored hemi half; `ShLut::lookup` provides the mirror network)
        let lut = ShLut { k, ld: l.ld, bits: n_bits, hemi: l.sh_lut.clone() };
        Self {
            spec,
            lut,
            din: l.din,
            dout: l.dout,
            coeff_q: l.coeff_q.clone(),
            coeff_scale: l.coeff_scale,
            wb: l.wb.clone(),
        }
    }

    #[inline]
    fn coeff(&self, i: usize, gidx: usize, o: usize) -> i32 {
        let nb = self.spec.num_basis();
        self.coeff_q[(i * nb + gidx) * self.dout + o]
    }

    /// Quantize a float input vector to layer codes.
    pub fn quantize_input(&self, x: &[f32]) -> Vec<u32> {
        debug_assert_eq!(x.len(), self.din);
        x.iter().map(|&v| self.spec.quantize(v as f64)).collect()
    }

    /// Digital-reference forward for one sample: codes → float outputs.
    ///
    /// Follows the hardware dataflow exactly (decode → SH-LUT → MAC over
    /// int8 ci' → scale), with an ideal (error-free) MAC.
    pub fn forward_digital(&self, xq: &[u32], out: &mut [f64]) {
        debug_assert_eq!(xq.len(), self.din);
        debug_assert_eq!(out.len(), self.dout);
        out.fill(0.0);
        let kk = self.spec.k as usize;
        let lut_scale = 1.0 / ((1u64 << self.lut.bits) - 1) as f64;
        for (i, &q) in xq.iter().enumerate() {
            let (j, l) = self.spec.decompose(q);
            // spline path: K+1 active bases via the shared LUT
            for t in 0..=kk {
                let b = self.lut.lookup(l, t as u32) as f64 * lut_scale;
                if b == 0.0 {
                    continue;
                }
                let gidx = j as usize + t;
                for o in 0..self.dout {
                    out[o] += b * self.coeff(i, gidx, o) as f64 * self.coeff_scale;
                }
            }
            // residual path: w_b · ReLU(x̂)
            let x = self.spec.dequantize(q);
            if x > 0.0 {
                for o in 0..self.dout {
                    out[o] += x * self.wb[i * self.dout + o];
                }
            }
        }
    }

    /// The crossbar view of the spline path: one row per `(input i, basis
    /// g)` pair, each row holding the `dout` ci' codes programmed on that
    /// word line. Row activation for input `xq`: row `(i, g)` carries the
    /// LUT value of basis `g` for `xq[i]` (zero when inactive).
    pub fn spline_rows(&self) -> usize {
        self.din * self.spec.num_basis()
    }

    /// int8 codes of crossbar row `(i, gidx)`.
    pub fn row_weights(&self, row: usize) -> &[i32] {
        let start = row * self.dout;
        &self.coeff_q[start..start + self.dout]
    }

    /// Word-line drive values (LUT codes, 0..2^bits-1) for one quantized
    /// input vector: the `B(X)` vector the TM-DV-IG turns into pulses.
    pub fn wordline_drives(&self, xq: &[u32]) -> Vec<u32> {
        let nb = self.spec.num_basis();
        let mut drives = vec![0u32; self.din * nb];
        let kk = self.spec.k as usize;
        for (i, &q) in xq.iter().enumerate() {
            let (j, l) = self.spec.decompose(q);
            for t in 0..=kk {
                drives[i * nb + j as usize + t] = self.lut.lookup(l, t as u32);
            }
        }
        drives
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::kan::spline;
    use crate::quant::AspSpec;

    /// Build a small layer directly (not via checkpoint) for unit tests.
    pub(crate) fn toy_layer(g: u32, k: u32, din: usize, dout: usize) -> QuantKanLayer {
        let spec = AspSpec::build(g, k, 8, -1.0, 1.0).unwrap();
        let lut = ShLut::build(&spec, 8);
        let nb = spec.num_basis();
        let coeff_q: Vec<i32> = (0..din * nb * dout)
            .map(|i| ((i as i64 * 37 + 11) % 255 - 127) as i32)
            .collect();
        let wb: Vec<f64> = (0..din * dout).map(|i| (i as f64 * 0.1).sin()).collect();
        QuantKanLayer {
            spec,
            lut,
            din,
            dout,
            coeff_q,
            coeff_scale: 0.01,
            wb,
        }
    }

    use crate::quant::ShLut;

    #[test]
    fn digital_forward_matches_float_spline_within_lut_quantization() {
        let layer = toy_layer(5, 3, 4, 3);
        let x = [0.3f32, -0.7, 0.95, -0.05];
        let xq = layer.quantize_input(&x);
        let mut got = vec![0.0; 3];
        layer.forward_digital(&xq, &mut got);

        // reference: exact float basis at the dequantized abscissae
        let mut want = vec![0.0f64; 3];
        let nb = layer.spec.num_basis();
        for (i, &q) in xq.iter().enumerate() {
            let z = layer.spec.grid_coord(q);
            let basis = spline::basis_functions(z, 5, 3);
            for o in 0..3 {
                for gidx in 0..nb {
                    want[o] += basis[gidx]
                        * layer.coeff(i, gidx, o) as f64
                        * layer.coeff_scale;
                }
            }
            let xd = layer.spec.dequantize(q);
            if xd > 0.0 {
                for o in 0..3 {
                    want[o] += xd * layer.wb[i * 3 + o];
                }
            }
        }
        for o in 0..3 {
            // 8-bit LUT quantization bounds the error: K+1 active bases,
            // each off by <= 0.5/255, times |ci'|<=127 * scale per input.
            let tol = 4.0 * (0.5 / 255.0) * 127.0 * 0.01 * 4.0;
            assert!(
                (got[o] - want[o]).abs() < tol,
                "o={o}: {} vs {} (tol {tol})",
                got[o],
                want[o]
            );
        }
    }

    #[test]
    fn wordline_drives_has_k_plus_1_active() {
        let layer = toy_layer(8, 3, 2, 1);
        let xq = layer.quantize_input(&[0.12, -0.9]);
        let drives = layer.wordline_drives(&xq);
        let nb = layer.spec.num_basis();
        for i in 0..2 {
            let active = drives[i * nb..(i + 1) * nb]
                .iter()
                .filter(|&&d| d > 0)
                .count();
            // at most K+1 active (K+1 minus any zero LUT entries)
            assert!(active <= 4, "input {i}: {active} active drives");
            assert!(active >= 1);
            // quantized partition of unity: active codes sum to ~255
            let sum: u32 = drives[i * nb..(i + 1) * nb].iter().sum();
            assert!((250..=260).contains(&sum), "sum={sum}");
        }
    }

}
