//! Plan compilation for the digital KAN hot path (`docs/ENGINE.md`).
//!
//! A [`KanPlan`] is a [`super::model::QuantKanModel`] reorganized for
//! execution speed while staying *integer-exact* with respect to the
//! hardware dataflow: the spline path of every layer is a pure integer
//! sum `Σ lut_code · ci'` accumulated in `i64`, converted to float once
//! per output with a single `lut_scale · coeff_scale` multiply — the
//! same partial sums the ACIM crossbar produces, instead of the per-term
//! f64 multiply chain of the scalar reference
//! (`QuantKanLayer::forward_digital`).
//!
//! Per layer the plan holds:
//!
//! * the mirror-resolved **full LUT table** (`2^LD × (K+1)` codes) so the
//!   hot loop never branches through the hemi MUX model;
//! * **fused coefficient tiles**: for every `(input, interval)` pair the
//!   `(K+1) × dout` block of ci' codes a lookup touches, stored
//!   contiguously as `i16` and placed hot-first by a SAM-style
//!   activation-probability ranking (reusing [`crate::mapping::sam`]),
//!   so the K+1 active rows of hot intervals share cache lines;
//! * optionally (small layers) **per-code fused rows**:
//!   `fused[i][q][o] = Σ_t lut(l,t) · ci'(i, j+t, o)` precomputed as
//!   `i32` — the same integer sum, cached per input code, turning the
//!   inner loop into a gather-add;
//! * the dequantized abscissa per code for the residual `w_b · ReLU(x̂)`
//!   path (f64, exactly as the reference computes it).

use crate::error::{Error, Result};
use crate::kan::kernels;
use crate::kan::layer::QuantKanLayer;
use crate::kan::model::QuantKanModel;
use crate::mapping::{build_mapping, MappingStrategy};
use crate::quant::AspSpec;

/// Plan-compilation knobs (see [`super::engine::EngineOptions`] for the
/// execution-side knobs).
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Tile placement order: [`MappingStrategy::Sam`] packs tiles
    /// hot-first (default), `Uniform` keeps checkpoint order,
    /// `WorstCase` is the ablation order.
    pub mapping: MappingStrategy,
    /// Per-layer budget (in `i32` entries, `din · R · dout`) under which
    /// the per-code fused rows are precomputed. `0` disables fusion and
    /// always executes from the tiles.
    pub fused_budget: usize,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self {
            mapping: MappingStrategy::Sam,
            // 4M i32 entries = 16 MiB per layer: generous for edge-sized
            // models, a guard for pathological ones
            fused_budget: 1 << 22,
        }
    }
}

/// One layer of a compiled plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub spec: AspSpec,
    pub din: usize,
    pub dout: usize,
    /// `K + 1` active taps per lookup.
    taps: usize,
    /// Knot intervals per input (`G`).
    g: usize,
    /// `2^LD` (mask for the local-offset bit field is `levels - 1`).
    levels: usize,
    /// Mirror-resolved full LUT, `[2^LD][K+1]`, row-major.
    lut_rows: Vec<i32>,
    /// Fused coefficient tiles: for each `(input, interval)` a contiguous
    /// `(K+1) · dout` block, placed by `tile_off`.
    tiles: Vec<i16>,
    /// Tile start for `(input i, interval j)` at `tile_off[i * g + j]`.
    tile_off: Vec<u32>,
    /// Per-code fused partial rows `[din][R][dout]` when within budget.
    fused: Option<Vec<i32>>,
    /// Residual weights `[din][dout]` (checkpoint order).
    wb: Vec<f64>,
    /// Dequantized abscissa per code, `deq[q] = lo + q·δ`.
    deq: Vec<f64>,
    /// The single integer→float conversion: `lut_scale · coeff_scale`.
    out_scale: f64,
    /// The `din · G` interval-activation probabilities this layer's tile
    /// placement was ranked by (empirical calibration occupancy or the
    /// Gaussian prior) — kept so live occupancy histograms can be
    /// compared against exactly the ranking input ("mapping drift",
    /// `docs/OBSERVABILITY.md`).
    prior: Vec<f64>,
}

impl LayerPlan {
    fn compile(layer: &QuantKanLayer, opts: &PlanOptions, probs: &[f64]) -> Result<Self> {
        let spec = layer.spec;
        let taps = spec.k as usize + 1;
        let g = spec.g as usize;
        let levels = spec.levels_per_interval() as usize;
        let range = spec.range() as usize;
        let (din, dout) = (layer.din, layer.dout);

        if layer.lut.bits > 30 {
            return Err(Error::Config(format!(
                "LUT precision {} bits too wide for the integer engine",
                layer.lut.bits
            )));
        }

        // mirror-resolve the stored hemi half into the full logical table
        let mut lut_rows = vec![0i32; levels * taps];
        for l in 0..levels {
            for t in 0..taps {
                lut_rows[l * taps + t] = layer.lut.lookup(l as u32, t as u32) as i32;
            }
        }

        // fused tiles: tile (i, j) = ci' rows j ..= j+K for input i,
        // (K+1) x dout, narrowed to i16 (ci' are int8 codes by contract)
        let n_tiles = din * g;
        let tile_size = taps * dout;
        debug_assert_eq!(probs.len(), n_tiles);
        // mapping[slot] = logical tile stored at that slot; SAM ranks
        // hot tiles into the low slots so they pack at the front of the
        // arena and share cache lines
        let perm = build_mapping(probs, n_tiles.max(1), opts.mapping);
        let mut tiles = vec![0i16; n_tiles * tile_size];
        let mut tile_off = vec![0u32; n_tiles];
        for (slot, &logical) in perm.iter().enumerate() {
            let base = slot * tile_size;
            let i = logical / g;
            let j = logical % g;
            for t in 0..taps {
                for o in 0..dout {
                    let c = layer.coeff_q[(i * spec.num_basis() + j + t) * dout + o];
                    if c < i16::MIN as i32 || c > i16::MAX as i32 {
                        return Err(Error::Config(format!(
                            "coefficient {c} at (input {i}, basis {}, out {o}) \
                             exceeds the engine's int16 range",
                            j + t
                        )));
                    }
                    tiles[base + t * dout + o] = c as i16;
                }
            }
            tile_off[logical] = u32::try_from(base).map_err(|_| {
                Error::Config("coefficient arena exceeds u32 addressing".into())
            })?;
        }

        // per-code fused rows when the layer is small enough; the i32
        // row entries must be able to hold Σ_t lut·ci' (fine for the
        // paper's 8-bit LUTs, skipped for exotic precisions)
        let fused_entries = din * range * dout;
        let fused_fits_i32 =
            ((1u64 << layer.lut.bits) - 1) * (i16::MAX as u64 + 1) * taps as u64
                <= i32::MAX as u64;
        let fused = if opts.fused_budget > 0
            && fused_entries <= opts.fused_budget
            && fused_fits_i32
        {
            let mut f = vec![0i32; fused_entries];
            for i in 0..din {
                for q in 0..range as u32 {
                    let (j, l) = spec.decompose(q);
                    let base = (i * range + q as usize) * dout;
                    for t in 0..taps {
                        let b = lut_rows[l as usize * taps + t] as i64;
                        if b == 0 {
                            continue;
                        }
                        for o in 0..dout {
                            let c = layer.coeff_q
                                [(i * spec.num_basis() + j as usize + t) * dout + o]
                                as i64;
                            // |b·c| <= (2^bits-1)·2^15 and Σ_t b <= 2^bits,
                            // so the per-code row fits i32 comfortably
                            f[base + o] += (b * c) as i32;
                        }
                    }
                }
            }
            Some(f)
        } else {
            None
        };

        let deq = (0..range as u32).map(|q| spec.dequantize(q)).collect();
        let lut_scale = 1.0 / ((1u64 << layer.lut.bits) - 1) as f64;

        Ok(Self {
            spec,
            din,
            dout,
            taps,
            g,
            levels,
            lut_rows,
            tiles,
            tile_off,
            fused,
            wb: layer.wb.clone(),
            deq,
            out_scale: lut_scale * layer.coeff_scale,
            prior: probs.to_vec(),
        })
    }

    /// Whether this layer executes from the per-code fused rows.
    pub fn uses_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Knot intervals per input (`G`) — the per-input bucket count of
    /// the occupancy histograms.
    pub fn intervals(&self) -> usize {
        self.g
    }

    /// The `din · G` calibration-time interval probabilities the tile
    /// placement was ranked by (see the `prior` field).
    pub fn prior(&self) -> &[f64] {
        &self.prior
    }

    /// Quantizer range `R = G · 2^LD` — the number of distinct input
    /// codes, i.e. the bucket count of the batch-major counting sort.
    pub fn range(&self) -> usize {
        self.deq.len()
    }

    /// Integer-exact forward for pre-quantized codes.
    ///
    /// `acc` is the i64 spline accumulator (len `dout`), `out` receives
    /// the float outputs (len `dout`). The spline partial sum is exact
    /// integer arithmetic; f64 enters only at the final `out_scale`
    /// conversion and in the residual path.
    pub fn forward_codes(&self, codes: &[u32], acc: &mut [i64], out: &mut [f64]) {
        debug_assert_eq!(codes.len(), self.din);
        debug_assert_eq!(acc.len(), self.dout);
        debug_assert_eq!(out.len(), self.dout);
        let dout = self.dout;
        let taps = self.taps;
        acc.fill(0);
        if let Some(fused) = &self.fused {
            let rdout = self.deq.len() * dout;
            for (i, &q) in codes.iter().enumerate() {
                let row = &fused[i * rdout + q as usize * dout..][..dout];
                for (a, &f) in acc.iter_mut().zip(row) {
                    *a += f as i64;
                }
            }
        } else {
            for (i, &q) in codes.iter().enumerate() {
                let j = (q >> self.spec.ld) as usize;
                let l = q as usize & (self.levels - 1);
                let lut = &self.lut_rows[l * taps..][..taps];
                let tile =
                    &self.tiles[self.tile_off[i * self.g + j] as usize..][..taps * dout];
                for (t, &b) in lut.iter().enumerate() {
                    if b == 0 {
                        continue;
                    }
                    let b = b as i64;
                    let row = &tile[t * dout..][..dout];
                    for (a, &c) in acc.iter_mut().zip(row) {
                        *a += b * c as i64;
                    }
                }
            }
        }
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = a as f64 * self.out_scale;
        }
        // residual path: w_b · ReLU(x̂), float exactly like the reference
        for (i, &q) in codes.iter().enumerate() {
            let x = self.deq[q as usize];
            if x > 0.0 {
                let w = &self.wb[i * dout..][..dout];
                for (o, &wv) in out.iter_mut().zip(w) {
                    *o += x * wv;
                }
            }
        }
    }

    /// Batch-major integer spline accumulation over a block of rows.
    ///
    /// `codes` holds the block's quantized inputs **column-major**
    /// (`codes[i · rows + r]` is input `i` of row `r` — the SoA gather
    /// the engine performs per layer), `acc` the per-row `i64`
    /// accumulators (`[rows][dout]` row-major, zeroed here). `start`
    /// (len > `R`), `order` (len ≥ `rows`) and `tmp` (len ≥ `dout`) are
    /// caller-owned scratch so the steady state allocates nothing.
    ///
    /// Per input column the rows are grouped by their full code `q` with
    /// a counting sort over the `R` buckets; `q` orders by interval
    /// first (`q = j·2^LD + l`), so walking the buckets in code order
    /// also walks each `(input, interval)` coefficient tile once, while
    /// it is hot. For the tiled path each distinct code's `Σ_t lut·ci'`
    /// product is materialized once — into `acc` directly for single-row
    /// groups, into `tmp` and then broadcast for larger groups — so
    /// duplicated codes amortize both the tile loads and the multiplies.
    /// The fused path needs no grouping: iterating column-major already
    /// keeps each input's `R × dout` fused slab cache-resident across
    /// every row of the block.
    ///
    /// Because every per-row contribution is an exact integer sum
    /// accumulated in `i64`, regrouping changes nothing: the returned
    /// accumulators are bit-identical to `rows` independent
    /// [`Self::forward_codes`] calls.
    ///
    /// Returns the number of LUT×tile products materialized (the
    /// `tile_loads` profiling counter); `0` on the fused path, which
    /// loads no tiles.
    pub fn accumulate_batch(
        &self,
        codes: &[u32],
        rows: usize,
        start: &mut [u32],
        order: &mut [u32],
        tmp: &mut [i64],
        acc: &mut [i64],
    ) -> u64 {
        let dout = self.dout;
        let taps = self.taps;
        let range = self.deq.len();
        debug_assert_eq!(codes.len(), self.din * rows);
        debug_assert!(start.len() > range);
        debug_assert!(order.len() >= rows);
        debug_assert!(tmp.len() >= dout);
        debug_assert_eq!(acc.len(), rows * dout);
        acc.fill(0);
        let mut loads = 0u64;
        for i in 0..self.din {
            let col = &codes[i * rows..][..rows];
            if let Some(fused) = &self.fused {
                let base = i * range * dout;
                for (r, &q) in col.iter().enumerate() {
                    let row = &fused[base + q as usize * dout..][..dout];
                    kernels::add_i32(&mut acc[r * dout..][..dout], row);
                }
                continue;
            }
            // counting sort of the block's rows by code: histogram into
            // start[q+1], prefix-sum, then scatter row ids; afterwards
            // start[q] is the END of bucket q and buckets are walked
            // with a running `begin` cursor
            let start = &mut start[..range + 1];
            start.fill(0);
            for &q in col {
                start[q as usize + 1] += 1;
            }
            for k in 1..=range {
                start[k] += start[k - 1];
            }
            for (r, &q) in col.iter().enumerate() {
                let slot = start[q as usize];
                order[slot as usize] = r as u32;
                start[q as usize] = slot + 1;
            }
            let mut begin = 0usize;
            for q in 0..range {
                let end = start[q] as usize;
                if end == begin {
                    continue;
                }
                let group = &order[begin..end];
                begin = end;
                loads += 1;
                let j = q >> self.spec.ld;
                let l = q & (self.levels - 1);
                let lut = &self.lut_rows[l * taps..][..taps];
                let tile =
                    &self.tiles[self.tile_off[i * self.g + j] as usize..][..taps * dout];
                if let [r] = *group {
                    // single-row group: accumulate straight into the row
                    let a = &mut acc[r as usize * dout..][..dout];
                    for (t, &b) in lut.iter().enumerate() {
                        if b == 0 {
                            continue;
                        }
                        kernels::axpy_i16(a, &tile[t * dout..][..dout], b as i64);
                    }
                } else {
                    // materialize the LUT×tile product once, broadcast it
                    let tmp = &mut tmp[..dout];
                    tmp.fill(0);
                    for (t, &b) in lut.iter().enumerate() {
                        if b == 0 {
                            continue;
                        }
                        kernels::axpy_i16(tmp, &tile[t * dout..][..dout], b as i64);
                    }
                    for &r in group {
                        kernels::add_i64(&mut acc[r as usize * dout..][..dout], tmp);
                    }
                }
            }
        }
        loads
    }

    /// Per-row float finish of a batch-major block: the single
    /// `out_scale` integer→float conversion plus the residual
    /// `w_b · ReLU(x̂)` path, in exactly the operation order of
    /// [`Self::forward_codes`] (conversion first, then residual inputs
    /// ascending) so the result is bit-identical to the row-major path.
    ///
    /// `codes` is the same column-major block passed to
    /// [`Self::accumulate_batch`], `acc` the finished accumulator row
    /// (`dout`) for row `r`, `out` that row's output slice.
    pub fn finish_batch_row(
        &self,
        codes: &[u32],
        rows: usize,
        r: usize,
        acc: &[i64],
        out: &mut [f64],
    ) {
        let dout = self.dout;
        debug_assert_eq!(codes.len(), self.din * rows);
        debug_assert_eq!(acc.len(), dout);
        debug_assert_eq!(out.len(), dout);
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = a as f64 * self.out_scale;
        }
        for i in 0..self.din {
            let x = self.deq[codes[i * rows + r] as usize];
            if x > 0.0 {
                let w = &self.wb[i * dout..][..dout];
                for (o, &wv) in out.iter_mut().zip(w) {
                    *o += x * wv;
                }
            }
        }
    }
}

/// A compiled model: per-layer plans plus the scratch geometry.
#[derive(Debug, Clone)]
pub struct KanPlan {
    pub layers: Vec<LayerPlan>,
    /// Widest activation vector across the stack (scratch size).
    pub max_width: usize,
    pub input_dim: usize,
    pub output_dim: usize,
}

impl KanPlan {
    /// Compile a model. `calib` (when given) supplies rows for empirical
    /// interval-occupancy estimation; otherwise a centered-Gaussian prior
    /// over each layer's grid ranks the tiles (Fig 8 shape).
    pub fn compile(
        model: &QuantKanModel,
        opts: &PlanOptions,
        calib: Option<&[Vec<f32>]>,
    ) -> Result<Self> {
        if model.layers.is_empty() {
            return Err(Error::Config(format!(
                "model '{}' has no layers to compile",
                model.name
            )));
        }
        let probs = interval_probabilities(model, calib);
        let layers = model
            .layers
            .iter()
            .zip(&probs)
            .map(|(l, p)| LayerPlan::compile(l, opts, p))
            .collect::<Result<Vec<_>>>()?;
        let max_width = model.dims.iter().copied().max().unwrap_or(1).max(1);
        Ok(Self {
            layers,
            max_width,
            input_dim: model.input_dim(),
            output_dim: model.output_dim(),
        })
    }
}

/// Cap on calibration rows used for tile ranking: compile-time cost only,
/// and occupancy estimates saturate long before this.
const MAX_CALIB_ROWS: usize = 512;

/// Per-layer `din · G` interval-activation probabilities for tile ranking.
///
/// With calibration rows: empirical interval occupancy, propagated layer
/// to layer through the golden reference forward (hidden activations kept
/// in f64). Without: the analytic probability of a centered Gaussian
/// (`μ = grid center`, `σ = span/4`) landing in each interval — same for
/// every input of the layer, which still ranks central intervals hot.
fn interval_probabilities(
    model: &QuantKanModel,
    calib: Option<&[Vec<f32>]>,
) -> Vec<Vec<f64>> {
    match calib {
        Some(rows)
            if rows.iter().any(|r| r.len() == model.input_dim()) =>
        {
            let mut acts: Vec<Vec<f64>> = rows
                .iter()
                .filter(|r| r.len() == model.input_dim())
                .take(MAX_CALIB_ROWS)
                .map(|r| r.iter().map(|&v| v as f64).collect())
                .collect();
            let mut all = Vec::with_capacity(model.layers.len());
            for layer in &model.layers {
                let g = layer.spec.g as usize;
                let mut counts = vec![0.0f64; layer.din * g];
                let mut next = Vec::with_capacity(acts.len());
                for row in &acts {
                    let xq: Vec<u32> =
                        row.iter().map(|&v| layer.spec.quantize(v)).collect();
                    for (i, &q) in xq.iter().enumerate() {
                        counts[i * g + (q >> layer.spec.ld) as usize] += 1.0;
                    }
                    let mut out = vec![0.0f64; layer.dout];
                    layer.forward_digital(&xq, &mut out);
                    next.push(out);
                }
                let n = acts.len().max(1) as f64;
                for c in &mut counts {
                    *c /= n;
                }
                all.push(counts);
                acts = next;
            }
            all
        }
        _ => model
            .layers
            .iter()
            .map(|layer| {
                let spec = &layer.spec;
                let g = spec.g as usize;
                let h = spec.knot_spacing();
                let mu = (spec.lo + spec.hi) / 2.0;
                let sigma = (spec.hi - spec.lo) / 4.0;
                let per_interval: Vec<f64> = (0..g)
                    .map(|j| {
                        let a = spec.lo + j as f64 * h;
                        let cdf = crate::mapping::probability::normal_cdf;
                        cdf((a + h - mu) / sigma) - cdf((a - mu) / sigma)
                    })
                    .collect();
                let mut probs = Vec::with_capacity(layer.din * g);
                for _ in 0..layer.din {
                    probs.extend_from_slice(&per_interval);
                }
                probs
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    fn toy_model(g: u32, k: u32, dims: &[usize]) -> QuantKanModel {
        let layers = dims
            .windows(2)
            .map(|w| toy_layer(g, k, w[0], w[1]))
            .collect();
        QuantKanModel {
            name: "toy".into(),
            dims: dims.to_vec(),
            g,
            k,
            layers,
        }
    }

    #[test]
    fn compile_shapes() {
        let model = toy_model(5, 3, &[4, 3, 2]);
        let plan = KanPlan::compile(&model, &PlanOptions::default(), None).unwrap();
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.input_dim, 4);
        assert_eq!(plan.output_dim, 2);
        assert_eq!(plan.max_width, 4);
        let l0 = &plan.layers[0];
        assert_eq!(l0.lut_rows.len(), 32 * 4); // 2^LD=32, K+1=4
        assert_eq!(l0.tile_off.len(), 4 * 5); // din * G
        assert!(l0.uses_fused());
    }

    #[test]
    fn tile_offsets_are_disjoint_and_in_bounds() {
        let model = toy_model(8, 3, &[3, 2]);
        let plan = KanPlan::compile(&model, &PlanOptions::default(), None).unwrap();
        let l = &plan.layers[0];
        let tile_size = l.taps * l.dout;
        let mut offs: Vec<u32> = l.tile_off.clone();
        offs.sort_unstable();
        for (rank, &o) in offs.iter().enumerate() {
            assert_eq!(o as usize, rank * tile_size);
        }
        assert_eq!(l.tiles.len(), l.tile_off.len() * tile_size);
    }

    #[test]
    fn fused_budget_zero_disables_fusion() {
        let model = toy_model(5, 3, &[2, 2]);
        let opts = PlanOptions { fused_budget: 0, ..Default::default() };
        let plan = KanPlan::compile(&model, &opts, None).unwrap();
        assert!(!plan.layers[0].uses_fused());
    }

    #[test]
    fn empty_model_rejected() {
        let model = QuantKanModel {
            name: "empty".into(),
            dims: vec![3],
            g: 5,
            k: 3,
            layers: Vec::new(),
        };
        assert!(KanPlan::compile(&model, &PlanOptions::default(), None).is_err());
    }

    #[test]
    fn gaussian_prior_ranks_central_tiles_hot() {
        let model = toy_model(8, 3, &[1, 1]);
        let probs = interval_probabilities(&model, None);
        let p = &probs[0];
        assert_eq!(p.len(), 8);
        // central intervals more probable than the edges
        assert!(p[3] > p[0] && p[4] > p[7]);
    }
}
