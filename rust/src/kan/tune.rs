//! Engine autotuner: sweep the batch-major execution knobs against a
//! live model and pick the fastest configuration.
//!
//! The sweep covers the three knobs that shape the hot loop —
//! [`EngineOptions::block`] (micro-block rows),
//! [`EngineOptions::group_threshold`] (grouped vs row-major dispatch,
//! including a pure row-major baseline candidate), and
//! [`EngineOptions::fused_budget`] (per-code fused rows vs coefficient
//! tiles) — benchmarking each compiled candidate on the same batch with
//! the crate's own harness ([`crate::util::bench`]). Every candidate is
//! first checked bit-identical to the default engine on the bench batch,
//! so the tuner can never trade correctness for speed.
//!
//! Consumers: `benches/hotpath.rs` embeds the report in
//! `BENCH_hotpath.json` (CI archives it), and the `kan-edge tune-engine`
//! subcommand runs the same sweep standalone. How to read the output:
//! `docs/PERFORMANCE.md`.

use crate::data::LoadGen;
use crate::error::{Error, Result};
use crate::kan::engine::{EngineOptions, KanEngine, MAX_BLOCK};
use crate::kan::model::QuantKanModel;
use crate::util::bench::{bench, black_box};
use crate::util::json::{arr, obj, Value};

/// One point of the sweep: the execution knobs under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneCandidate {
    /// Micro-block rows ([`EngineOptions::block`]).
    pub block: usize,
    /// Grouped-path threshold ([`EngineOptions::group_threshold`]);
    /// values above [`MAX_BLOCK`] select the row-major baseline.
    pub group_threshold: usize,
    /// Fusion budget ([`EngineOptions::fused_budget`]).
    pub fused_budget: usize,
}

impl TuneCandidate {
    /// The engine options this candidate compiles with (defaults
    /// elsewhere).
    pub fn options(&self) -> EngineOptions {
        EngineOptions {
            block: self.block,
            group_threshold: self.group_threshold,
            fused_budget: self.fused_budget,
            ..EngineOptions::default()
        }
    }

    fn to_value(self, ns_per_op: f64) -> Value {
        obj(vec![
            ("block", Value::Int(self.block as i64)),
            ("group_threshold", Value::Int(self.group_threshold as i64)),
            ("fused_budget", Value::Int(self.fused_budget as i64)),
            ("row_major", Value::Bool(self.group_threshold > MAX_BLOCK)),
            ("ns_per_op", Value::Float(ns_per_op)),
        ])
    }
}

/// A measured candidate.
#[derive(Debug, Clone, Copy)]
pub struct TuneOutcome {
    pub candidate: TuneCandidate,
    /// Median wall time of one batch forward, nanoseconds.
    pub ns_per_op: f64,
}

/// The full sweep result.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// Rows per benched batch forward.
    pub batch: usize,
    /// Median ns of the scalar reference (`QuantKanModel::forward_batch`).
    pub reference_ns: f64,
    /// Median ns of the engine at [`EngineOptions::default`].
    pub default_engine_ns: f64,
    /// Every candidate, in sweep order.
    pub outcomes: Vec<TuneOutcome>,
    /// The fastest candidate.
    pub best: TuneOutcome,
}

impl TuneReport {
    /// Engine options of the winning candidate.
    pub fn best_options(&self) -> EngineOptions {
        self.best.candidate.options()
    }

    /// Best-candidate speedup over the scalar reference.
    pub fn speedup_vs_reference(&self) -> f64 {
        self.reference_ns / self.best.ns_per_op.max(1.0)
    }

    /// Best-candidate speedup over the default-configured engine.
    pub fn speedup_vs_default(&self) -> f64 {
        self.default_engine_ns / self.best.ns_per_op.max(1.0)
    }

    /// Render the `autotune` section of `BENCH_hotpath.json`
    /// (`docs/PERFORMANCE.md` documents the schema). `model_source`
    /// records which checkpoint produced the numbers ("artifact" or
    /// "synthetic") so trajectories across runs stay apples-to-apples.
    pub fn to_value(&self, model_source: &str) -> Value {
        obj(vec![
            ("model_source", Value::Str(model_source.to_string())),
            ("batch", Value::Int(self.batch as i64)),
            ("reference_ns_per_op", Value::Float(self.reference_ns)),
            ("default_engine_ns_per_op", Value::Float(self.default_engine_ns)),
            (
                "candidates",
                arr(self
                    .outcomes
                    .iter()
                    .map(|o| o.candidate.to_value(o.ns_per_op))
                    .collect()),
            ),
            ("best", self.best.candidate.to_value(self.best.ns_per_op)),
            ("speedup_vs_reference", Value::Float(self.speedup_vs_reference())),
            ("speedup_vs_default_engine", Value::Float(self.speedup_vs_default())),
        ])
    }
}

/// The default sweep grid: micro-block sizes around the serving batch,
/// grouped execution vs the row-major baseline, fused rows vs tiles.
pub fn default_candidates() -> Vec<TuneCandidate> {
    let budgets = [EngineOptions::default().fused_budget, 0usize];
    let mut out = Vec::new();
    for &fused_budget in &budgets {
        for &block in &[16usize, 64, 256] {
            for &group_threshold in &[2usize, MAX_BLOCK + 1] {
                out.push(TuneCandidate { block, group_threshold, fused_budget });
            }
        }
    }
    out
}

/// Sweep `candidates` (or [`default_candidates`] when empty) on `model`,
/// benchmarking one `batch`-row forward per iteration for ~`target_ms`
/// per candidate. Inputs come from the deterministic [`LoadGen`] stream,
/// so two sweeps on one machine see identical work.
///
/// Fails if any candidate's outputs are not bit-identical to the
/// default engine's on the bench batch.
pub fn autotune(
    model: &QuantKanModel,
    batch: usize,
    target_ms: u64,
    candidates: &[TuneCandidate],
) -> Result<TuneReport> {
    let batch = batch.max(1);
    let din = model.input_dim();
    let dout = model.output_dim();
    let mut lg = LoadGen::new(0x7E57, din);
    let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();

    let reference_ns = bench("reference", target_ms, || {
        black_box(model.forward_batch(&flat, batch));
    })
    .per_iter_ns();

    let default_engine = KanEngine::compile(model, EngineOptions::default())?;
    let mut baseline = vec![0.0f64; batch * dout];
    let mut out = vec![0.0f64; batch * dout];
    let mut scratches = vec![default_engine.new_scratch()];
    default_engine.forward_batch_with(&flat, batch, &mut baseline, &mut scratches);
    let default_engine_ns = bench("engine default", target_ms, || {
        default_engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        black_box(&out);
    })
    .per_iter_ns();

    let grid = if candidates.is_empty() {
        default_candidates()
    } else {
        candidates.to_vec()
    };
    let mut outcomes = Vec::with_capacity(grid.len());
    for cand in grid {
        let engine = KanEngine::compile(model, cand.options())?;
        let mut scratches = vec![engine.new_scratch()];
        engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        for (a, b) in out.iter().zip(&baseline) {
            if a.to_bits() != b.to_bits() {
                return Err(Error::Config(format!(
                    "autotune candidate (block {}, threshold {}, budget {}) \
                     diverged from the default engine",
                    cand.block, cand.group_threshold, cand.fused_budget
                )));
            }
        }
        let ns = bench("candidate", target_ms, || {
            engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
            black_box(&out);
        })
        .per_iter_ns();
        outcomes.push(TuneOutcome { candidate: cand, ns_per_op: ns });
    }
    let best = *outcomes
        .iter()
        .min_by(|a, b| a.ns_per_op.total_cmp(&b.ns_per_op))
        .expect("sweep grid is never empty");
    Ok(TuneReport {
        batch,
        reference_ns,
        default_engine_ns,
        outcomes,
        best,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::checkpoint::synthetic_kan_checkpoint;

    fn tiny_model() -> QuantKanModel {
        let ckpt = synthetic_kan_checkpoint("tune", &[3, 4, 2], 5, 3, 0x7E57);
        QuantKanModel::from_checkpoint(&ckpt)
    }

    #[test]
    fn autotune_picks_a_candidate_and_reports() {
        let model = tiny_model();
        // 1 ms per candidate keeps the unit test fast; the sweep shape,
        // parity gate, and report schema are what is under test here
        let report = autotune(&model, 8, 1, &[]).unwrap();
        assert_eq!(report.batch, 8);
        assert_eq!(report.outcomes.len(), default_candidates().len());
        assert!(report.best.ns_per_op > 0.0);
        assert!(report.reference_ns > 0.0);
        let v = report.to_value("synthetic");
        assert_eq!(
            v.get("model_source").and_then(|s| s.as_str()),
            Some("synthetic")
        );
        let best = v.get("best").unwrap();
        assert!(best.get("block").and_then(|b| b.as_i64()).is_some());
        let cands = v.get("candidates").and_then(|c| c.as_array()).unwrap();
        assert_eq!(cands.len(), report.outcomes.len());
        // the winner's options compile
        assert!(KanEngine::compile(&model, report.best_options()).is_ok());
    }

    #[test]
    fn explicit_candidate_list_is_respected() {
        let model = tiny_model();
        let only = [TuneCandidate { block: 32, group_threshold: 2, fused_budget: 0 }];
        let report = autotune(&model, 4, 1, &only).unwrap();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.best.candidate, only[0]);
    }
}
