//! Loaders for the artifacts produced by `python/compile/aot.py`:
//! quantized weight checkpoints, the artifact manifest, and the dataset.
//!
//! Parsing goes through [`crate::util::json`] (the image has no serde_json);
//! every loader validates shapes and reports actionable errors ("run `make
//! artifacts`") instead of panicking downstream.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{obj, Value};

pub(crate) fn read_json(path: &Path) -> Result<Value> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Artifact(format!(
            "cannot read {} ({e}); run `make artifacts`",
            path.display()
        ))
    })?;
    Value::parse(&text).map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))
}

/// One quantized KAN layer as exported by the build path.
#[derive(Debug, Clone)]
pub struct KanLayerCheckpoint {
    pub din: usize,
    pub dout: usize,
    /// Grid range: code 0 maps to `lo`, knot spacing `(hi-lo)/G`.
    pub lo: f64,
    pub hi: f64,
    /// PowerGap exponent for this layer.
    pub ld: u32,
    /// SH-LUT rows (`2^(LD-1)+1` × `K+1`) as 8-bit codes.
    pub sh_lut: Vec<Vec<u32>>,
    /// int8 ci' codes, flattened `[din, G+K, dout]`, row-major.
    pub coeff_q: Vec<i32>,
    /// Dequantization scale for `coeff_q`.
    pub coeff_scale: f64,
    /// Residual-path weights w_b, flattened `[din, dout]`.
    pub wb: Vec<f64>,
}

impl KanLayerCheckpoint {
    fn from_json(v: &Value) -> Result<Self> {
        let sh_lut = v
            .req_array("sh_lut")?
            .iter()
            .map(|row| {
                row.as_array()
                    .ok_or_else(|| Error::Json("sh_lut row is not an array".into()))?
                    .iter()
                    .map(|x| {
                        x.as_i64()
                            .and_then(|i| u32::try_from(i).ok())
                            .ok_or_else(|| Error::Json("sh_lut entry not a u32".into()))
                    })
                    .collect::<Result<Vec<u32>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            din: v.req_usize("din")?,
            dout: v.req_usize("dout")?,
            lo: v.req_f64("lo")?,
            hi: v.req_f64("hi")?,
            ld: v.req_usize("ld")? as u32,
            sh_lut,
            coeff_q: v
                .i64_vec("coeff_q")?
                .into_iter()
                .map(|i| i as i32)
                .collect(),
            coeff_scale: v.req_f64("coeff_scale")?,
            wb: v.f64_vec("wb")?,
        })
    }
}

/// A quantized KAN checkpoint (`<model>.weights.json`).
#[derive(Debug, Clone)]
pub struct KanCheckpoint {
    pub name: String,
    pub kind: String,
    pub dims: Vec<usize>,
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
    pub num_params: usize,
    pub layers: Vec<KanLayerCheckpoint>,
    pub float_test_acc: Option<f64>,
    pub quant_test_acc: Option<f64>,
}

fn usize_vec(v: &Value, key: &str) -> Result<Vec<usize>> {
    v.i64_vec(key)?
        .into_iter()
        .map(|i| {
            usize::try_from(i).map_err(|_| Error::Json(format!("'{key}': negative value")))
        })
        .collect()
}

impl KanCheckpoint {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let v = read_json(path.as_ref())?;
        let ckpt = Self {
            name: v.req_str("name")?.to_string(),
            kind: v.req_str("kind")?.to_string(),
            dims: usize_vec(&v, "dims")?,
            g: v.req_usize("g")? as u32,
            k: v.req_usize("k")? as u32,
            n_bits: v.req_usize("n_bits")? as u32,
            num_params: v.req_usize("num_params")?,
            layers: v
                .req_array("layers")?
                .iter()
                .map(KanLayerCheckpoint::from_json)
                .collect::<Result<Vec<_>>>()?,
            float_test_acc: v.get("float_test_acc").and_then(|x| x.as_f64()),
            quant_test_acc: v.get("quant_test_acc").and_then(|x| x.as_f64()),
        };
        ckpt.validate()?;
        Ok(ckpt)
    }

    pub fn validate(&self) -> Result<()> {
        if self.kind != "kan" {
            return Err(Error::Artifact(format!(
                "{}: expected kind=kan, got {}",
                self.name, self.kind
            )));
        }
        if self.layers.len() + 1 != self.dims.len() {
            return Err(Error::Artifact(format!(
                "{}: {} layers but {} dims",
                self.name,
                self.layers.len(),
                self.dims.len()
            )));
        }
        let nb = (self.g + self.k) as usize;
        for (i, l) in self.layers.iter().enumerate() {
            if l.din != self.dims[i] || l.dout != self.dims[i + 1] {
                return Err(Error::Shape(format!(
                    "{} layer {i}: ({}, {}) vs dims ({}, {})",
                    self.name, l.din, l.dout, self.dims[i], self.dims[i + 1]
                )));
            }
            if l.coeff_q.len() != l.din * nb * l.dout {
                return Err(Error::Shape(format!(
                    "{} layer {i}: coeff_q len {} != {}x{}x{}",
                    self.name,
                    l.coeff_q.len(),
                    l.din,
                    nb,
                    l.dout
                )));
            }
            if l.wb.len() != l.din * l.dout {
                return Err(Error::Shape(format!(
                    "{} layer {i}: wb len {} != {}x{}",
                    self.name,
                    l.wb.len(),
                    l.din,
                    l.dout
                )));
            }
            let expect_rows = (1usize << l.ld) / 2 + 1;
            if l.sh_lut.len() != expect_rows {
                return Err(Error::Shape(format!(
                    "{} layer {i}: sh_lut has {} rows, expected {expect_rows}",
                    self.name,
                    l.sh_lut.len()
                )));
            }
        }
        Ok(())
    }
}

/// An MLP checkpoint (`mlp.weights.json`).
#[derive(Debug, Clone)]
pub struct MlpCheckpoint {
    pub name: String,
    pub kind: String,
    pub dims: Vec<usize>,
    pub num_params: usize,
    pub layers: Vec<MlpLayerCheckpoint>,
    pub test_acc: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct MlpLayerCheckpoint {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f64>,
    pub b: Vec<f64>,
}

impl MlpCheckpoint {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let v = read_json(path.as_ref())?;
        let layers = v
            .req_array("layers")?
            .iter()
            .map(|l| {
                Ok(MlpLayerCheckpoint {
                    din: l.req_usize("din")?,
                    dout: l.req_usize("dout")?,
                    w: l.f64_vec("w")?,
                    b: l.f64_vec("b")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let ckpt = Self {
            name: v.req_str("name")?.to_string(),
            kind: v.req_str("kind")?.to_string(),
            dims: usize_vec(&v, "dims")?,
            num_params: v.req_usize("num_params")?,
            layers,
            test_acc: v.get("test_acc").and_then(|x| x.as_f64()),
        };
        for (i, l) in ckpt.layers.iter().enumerate() {
            if l.w.len() != l.din * l.dout || l.b.len() != l.dout {
                return Err(Error::Shape(format!("mlp layer {i}: bad shapes")));
            }
        }
        Ok(ckpt)
    }
}

/// `manifest.json` — the artifact index.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: u32,
    pub seed: u64,
    pub dataset: DatasetMeta,
    pub models: HashMap<String, ModelEntry>,
    pub sweep: Vec<SweepEntry>,
    pub batch_sizes: Vec<usize>,
    pub build_seconds: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub num_features: usize,
    pub num_classes: usize,
    pub train: usize,
    pub val: usize,
    pub test: usize,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub kind: String,
    pub dims: Vec<usize>,
    pub g: Option<u32>,
    pub k: Option<u32>,
    pub num_params: usize,
    pub val_acc: f64,
    pub float_test_acc: Option<f64>,
    pub quant_test_acc: Option<f64>,
    pub test_acc: Option<f64>,
    pub weights: String,
    /// batch size -> hlo file name
    pub hlo: HashMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct SweepEntry {
    pub g: u32,
    pub num_params: usize,
    pub val_acc: f64,
    pub quant_test_acc: f64,
    pub weights: String,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let v = read_json(&dir.as_ref().join("manifest.json"))?;
        Self::from_value(&v)
    }

    /// Parse the flat (schema v1) manifest body from an already-parsed
    /// JSON document. `registry::ModelManifest` reuses this for the base
    /// part of schema-v2 documents.
    pub fn from_value(v: &Value) -> Result<Self> {
        let d = v.field("dataset")?;
        let dataset = DatasetMeta {
            num_features: d.req_usize("num_features")?,
            num_classes: d.req_usize("num_classes")?,
            train: d.req_usize("train")?,
            val: d.req_usize("val")?,
            test: d.req_usize("test")?,
        };
        let mut models = HashMap::new();
        for (name, m) in v
            .field("models")?
            .as_object()
            .ok_or_else(|| Error::Json("'models' is not an object".into()))?
        {
            let mut hlo = HashMap::new();
            if let Some(h) = m.get("hlo").and_then(|h| h.as_object()) {
                for (b, f) in h {
                    let batch: usize = b
                        .parse()
                        .map_err(|_| Error::Json(format!("bad batch key '{b}'")))?;
                    hlo.insert(
                        batch,
                        f.as_str()
                            .ok_or_else(|| Error::Json("hlo file not a string".into()))?
                            .to_string(),
                    );
                }
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    kind: m.req_str("kind")?.to_string(),
                    dims: usize_vec(m, "dims")?,
                    g: m.get("g").and_then(|x| x.as_i64()).map(|x| x as u32),
                    k: m.get("k").and_then(|x| x.as_i64()).map(|x| x as u32),
                    num_params: m.req_usize("num_params")?,
                    val_acc: m.req_f64("val_acc")?,
                    float_test_acc: m.get("float_test_acc").and_then(|x| x.as_f64()),
                    quant_test_acc: m.get("quant_test_acc").and_then(|x| x.as_f64()),
                    test_acc: m.get("test_acc").and_then(|x| x.as_f64()),
                    weights: m.req_str("weights")?.to_string(),
                    hlo,
                },
            );
        }
        let sweep = v
            .req_array("sweep")?
            .iter()
            .map(|s| {
                Ok(SweepEntry {
                    g: s.req_usize("g")? as u32,
                    num_params: s.req_usize("num_params")?,
                    val_acc: s.req_f64("val_acc")?,
                    quant_test_acc: s.req_f64("quant_test_acc")?,
                    weights: s.req_str("weights")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            format: v.req_usize("format")? as u32,
            seed: v.req_usize("seed")? as u64,
            dataset,
            models,
            sweep,
            batch_sizes: usize_vec(v, "batch_sizes")?,
            build_seconds: v.get("build_seconds").and_then(|x| x.as_f64()),
        })
    }

    /// Serialize back to the flat (schema v1) JSON document. The inverse
    /// of [`Manifest::from_value`]; `registry` layers schema-v2 metadata
    /// on top of this when writing manifests (`kan-edge publish`).
    pub fn to_value(&self) -> Value {
        let dataset = obj(vec![
            ("num_features", self.dataset.num_features.into()),
            ("num_classes", self.dataset.num_classes.into()),
            ("train", self.dataset.train.into()),
            ("val", self.dataset.val.into()),
            ("test", self.dataset.test.into()),
        ]);
        // BTreeMap for deterministic output (models is a HashMap)
        let models: BTreeMap<String, Value> = self
            .models
            .iter()
            .map(|(name, m)| (name.clone(), m.to_value()))
            .collect();
        let sweep: Vec<Value> = self
            .sweep
            .iter()
            .map(|s| {
                obj(vec![
                    ("g", (s.g as usize).into()),
                    ("num_params", s.num_params.into()),
                    ("val_acc", s.val_acc.into()),
                    ("quant_test_acc", s.quant_test_acc.into()),
                    ("weights", s.weights.as_str().into()),
                ])
            })
            .collect();
        let mut fields = vec![
            ("format", (self.format as usize).into()),
            ("seed", (self.seed as usize).into()),
            ("dataset", dataset),
            ("models", Value::Object(models)),
            ("sweep", Value::Array(sweep)),
            (
                "batch_sizes",
                Value::Array(self.batch_sizes.iter().map(|&b| b.into()).collect()),
            ),
        ];
        if let Some(b) = self.build_seconds {
            fields.push(("build_seconds", b.into()));
        }
        obj(fields)
    }
}

impl ModelEntry {
    /// Serialize one model entry (inverse of the manifest parser).
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("kind", self.kind.as_str().into()),
            (
                "dims",
                Value::Array(self.dims.iter().map(|&d| d.into()).collect()),
            ),
            ("num_params", self.num_params.into()),
            ("val_acc", self.val_acc.into()),
            ("weights", self.weights.as_str().into()),
        ];
        if let Some(g) = self.g {
            fields.push(("g", (g as usize).into()));
        }
        if let Some(k) = self.k {
            fields.push(("k", (k as usize).into()));
        }
        if let Some(a) = self.float_test_acc {
            fields.push(("float_test_acc", a.into()));
        }
        if let Some(a) = self.quant_test_acc {
            fields.push(("quant_test_acc", a.into()));
        }
        if let Some(a) = self.test_acc {
            fields.push(("test_acc", a.into()));
        }
        if !self.hlo.is_empty() {
            let hlo: BTreeMap<String, Value> = self
                .hlo
                .iter()
                .map(|(b, f)| (b.to_string(), f.as_str().into()))
                .collect();
            fields.push(("hlo", Value::Object(hlo)));
        }
        obj(fields)
    }
}

impl KanCheckpoint {
    /// Serialize back to the artifact JSON document (inverse of
    /// [`KanCheckpoint::load`]) — lets benches and tests publish
    /// synthetic checkpoints through the same path as real artifacts.
    pub fn to_value(&self) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let sh: Vec<Value> = l
                    .sh_lut
                    .iter()
                    .map(|row| {
                        Value::Array(row.iter().map(|&c| (c as usize).into()).collect())
                    })
                    .collect();
                obj(vec![
                    ("din", l.din.into()),
                    ("dout", l.dout.into()),
                    ("lo", l.lo.into()),
                    ("hi", l.hi.into()),
                    ("ld", (l.ld as usize).into()),
                    ("sh_lut", Value::Array(sh)),
                    (
                        "coeff_q",
                        Value::Array(
                            l.coeff_q.iter().map(|&c| Value::Int(c as i64)).collect(),
                        ),
                    ),
                    ("coeff_scale", l.coeff_scale.into()),
                    ("wb", Value::Array(l.wb.iter().map(|&w| w.into()).collect())),
                ])
            })
            .collect();
        let mut fields = vec![
            ("name", self.name.as_str().into()),
            ("kind", self.kind.as_str().into()),
            (
                "dims",
                Value::Array(self.dims.iter().map(|&d| d.into()).collect()),
            ),
            ("g", (self.g as usize).into()),
            ("k", (self.k as usize).into()),
            ("n_bits", (self.n_bits as usize).into()),
            ("num_params", self.num_params.into()),
            ("layers", Value::Array(layers)),
        ];
        if let Some(a) = self.float_test_acc {
            fields.push(("float_test_acc", a.into()));
        }
        if let Some(a) = self.quant_test_acc {
            fields.push(("quant_test_acc", a.into()));
        }
        obj(fields)
    }
}

/// Deterministic synthetic KAN checkpoint with a real quantization
/// geometry: valid shapes, int8 ci' codes, SH-LUT built from the actual
/// `(G, K)` spec over `[-1, 1]`. The fixture behind the hotpath bench's
/// artifact fallback and the engine test suite — not a trained model
/// (predictions are arbitrary but stable for a given seed).
pub fn synthetic_kan_checkpoint(
    name: &str,
    dims: &[usize],
    g: u32,
    k: u32,
    seed: u64,
) -> KanCheckpoint {
    use crate::quant::{AspSpec, ShLut};
    use crate::util::Rng;

    assert!(dims.len() >= 2, "need at least one layer");
    let n_bits = 8;
    let mut rng = Rng::new(seed);
    let nb = (g + k) as usize;
    let spec = AspSpec::build(g, k, n_bits, -1.0, 1.0).expect("valid (G, K, n)");
    let lut = ShLut::build(&spec, n_bits);
    let mut layers = Vec::new();
    let mut num_params = 0usize;
    for w in dims.windows(2) {
        let (din, dout) = (w[0], w[1]);
        let coeff_q: Vec<i32> =
            (0..din * nb * dout).map(|_| rng.int_range(-127, 127) as i32).collect();
        let wb: Vec<f64> = (0..din * dout).map(|_| rng.range(-0.5, 0.5)).collect();
        num_params += coeff_q.len() + wb.len();
        layers.push(KanLayerCheckpoint {
            din,
            dout,
            lo: -1.0,
            hi: 1.0,
            ld: spec.ld,
            sh_lut: lut.hemi.clone(),
            coeff_q,
            // keep layer outputs roughly inside the next layer's grid
            coeff_scale: 2.0 / (127.0 * nb as f64),
            wb,
        });
    }
    KanCheckpoint {
        name: name.to_string(),
        kind: "kan".into(),
        dims: dims.to_vec(),
        g,
        k,
        n_bits,
        num_params,
        layers,
        float_test_acc: None,
        quant_test_acc: None,
    }
}

/// A tiny valid KAN checkpoint (dims [2,2], G=1, K=1) whose residual
/// weights make every positive input land on `favor_class` (0 or 1).
/// The one canonical synthetic fixture behind `kan-edge bench-net`, the
/// offline examples, and the protocol tests — keeping the
/// format-sensitive layer JSON in a single place.
pub fn synthetic_checkpoint_json(name: &str, favor_class: usize) -> String {
    let wb = if favor_class == 0 {
        "[1.0, 0.0, 1.0, 0.0]"
    } else {
        "[0.0, 1.0, 0.0, 1.0]"
    };
    format!(
        r#"{{"name":"{name}","kind":"kan","dims":[2,2],"g":1,"k":1,"n_bits":8,
            "num_params":8,"quant_test_acc":0.9,
            "layers":[{{"din":2,"dout":2,"lo":-1.0,"hi":1.0,"ld":2,
              "sh_lut":[[255,0],[170,85],[128,128]],
              "coeff_q":[0,0,0,0,0,0,0,0],"coeff_scale":0.01,
              "wb":{wb}}}]}}"#
    )
}

/// `dataset.json` — test split + calibration sample.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub test_x: Vec<f32>,
    pub test_y: Vec<u32>,
    pub calib_x: Vec<f32>,
    pub calib_y: Vec<u32>,
    pub num_features: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let v = read_json(&dir.as_ref().join("dataset.json"))?;
        let ds = Self {
            test_x: v.f32_vec("test_x")?,
            test_y: v
                .i64_vec("test_y")?
                .into_iter()
                .map(|i| i as u32)
                .collect(),
            calib_x: v.f32_vec("calib_x")?,
            calib_y: v
                .i64_vec("calib_y")?
                .into_iter()
                .map(|i| i as u32)
                .collect(),
            num_features: v.req_usize("num_features")?,
            num_classes: v.req_usize("num_classes")?,
        };
        if ds.test_x.len() != ds.test_y.len() * ds.num_features {
            return Err(Error::Shape("dataset test arrays inconsistent".into()));
        }
        if ds.calib_x.len() != ds.calib_y.len() * ds.num_features {
            return Err(Error::Shape("dataset calib arrays inconsistent".into()));
        }
        Ok(ds)
    }

    pub fn test_rows(&self) -> impl Iterator<Item = (&[f32], u32)> {
        self.test_x
            .chunks_exact(self.num_features)
            .zip(self.test_y.iter().copied())
    }

    pub fn calib_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.calib_x.chunks_exact(self.num_features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("kan_edge_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        path
    }

    #[test]
    fn kan_checkpoint_roundtrip() {
        // G=1,K=1 -> nb=2, LD=7 -> sh_lut rows = 65... keep it small: use
        // ld consistent with validation (2^(ld-1)+1 rows)
        let sh_rows: Vec<String> = (0..3).map(|_| "[255, 0]".to_string()).collect();
        let text = format!(
            r#"{{"name":"t","kind":"kan","dims":[2,1],"g":1,"k":1,"n_bits":8,
               "num_params":6,
               "layers":[{{"din":2,"dout":1,"lo":-1.0,"hi":1.0,"ld":2,
                 "sh_lut":[{}],
                 "coeff_q":[1,2,3,4],"coeff_scale":0.5,"wb":[0.1,0.2]}}]}}"#,
            sh_rows.join(",")
        );
        let path = write_tmp("kan_ok.json", &text);
        let ckpt = KanCheckpoint::load(&path).unwrap();
        assert_eq!(ckpt.dims, vec![2, 1]);
        assert_eq!(ckpt.layers[0].coeff_q, vec![1, 2, 3, 4]);
    }

    #[test]
    fn kan_checkpoint_rejects_bad_shapes() {
        let text = r#"{"name":"t","kind":"kan","dims":[2,1],"g":1,"k":1,
            "n_bits":8,"num_params":6,
            "layers":[{"din":2,"dout":1,"lo":-1.0,"hi":1.0,"ld":2,
              "sh_lut":[[255,0],[200,55],[128,128]],
              "coeff_q":[1,2,3],"coeff_scale":0.5,"wb":[0.1,0.2]}]}"#;
        let path = write_tmp("kan_bad.json", text);
        let err = KanCheckpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("coeff_q"), "{err}");
    }

    #[test]
    fn synthetic_checkpoint_roundtrips_through_json() {
        let ckpt = synthetic_kan_checkpoint("syn", &[3, 4, 2], 5, 3, 0xAB);
        ckpt.validate().unwrap();
        let path = write_tmp("syn.json", &ckpt.to_value().to_string());
        let back = KanCheckpoint::load(&path).unwrap();
        assert_eq!(back.dims, vec![3, 4, 2]);
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[0].coeff_q, ckpt.layers[0].coeff_q);
        assert_eq!(back.layers[1].sh_lut, ckpt.layers[1].sh_lut);
        assert_eq!(back.layers[0].coeff_scale, ckpt.layers[0].coeff_scale);
        // deterministic: same seed, same checkpoint
        let again = synthetic_kan_checkpoint("syn", &[3, 4, 2], 5, 3, 0xAB);
        assert_eq!(again.layers[0].coeff_q, ckpt.layers[0].coeff_q);
    }

    #[test]
    fn missing_file_mentions_make_artifacts() {
        let err = KanCheckpoint::load("/no/such/file.json")
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
