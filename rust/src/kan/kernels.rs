//! Fixed-width integer inner kernels for the batch-major engine.
//!
//! These are the only loops that run per `(code, output)` pair in the
//! hot path, so they are written for the auto-vectorizer: each kernel
//! walks its operands in fixed strips of [`STRIP`] lanes via
//! `chunks_exact`, which proves the trip count to LLVM and removes all
//! bounds checks from the strip body; the tail shorter than one strip is
//! handled once after the strips. All arithmetic widens to `i64` before
//! accumulating, so the kernels are exact for every operand the plan can
//! produce (`|lut| < 2^30`, `|ci'| <= 2^15`, row lengths bounded by the
//! layer width).
//!
//! The kernels are `#[inline]` free functions with no dependency on
//! [`super::plan::LayerPlan`] internals, so they are unit-testable in
//! isolation (see the tests at the bottom of this file) and reusable by
//! both the row-major and batch-major execution paths.

/// Vector strip width (lanes per unrolled chunk). Eight `i64` lanes span
/// two 256-bit registers — wide enough to keep AVX2/NEON busy, small
/// enough that the sub-strip tail stays cheap for narrow layers.
pub const STRIP: usize = 8;

/// `acc[k] += b · row[k]` over an `i16` coefficient row.
///
/// This is the tile kernel: `row` is one tap row of a fused coefficient
/// tile and `b` the (pre-widened) LUT code weighting it.
#[inline]
pub fn axpy_i16(acc: &mut [i64], row: &[i16], b: i64) {
    debug_assert_eq!(acc.len(), row.len());
    let mut strips = acc.chunks_exact_mut(STRIP);
    let mut rows = row.chunks_exact(STRIP);
    for (a, r) in strips.by_ref().zip(rows.by_ref()) {
        for (av, &rv) in a.iter_mut().zip(r) {
            *av += b * rv as i64;
        }
    }
    for (av, &rv) in strips.into_remainder().iter_mut().zip(rows.remainder()) {
        *av += b * rv as i64;
    }
}

/// `acc[k] += src[k]` over an `i32` fused row (the per-code fast path).
#[inline]
pub fn add_i32(acc: &mut [i64], src: &[i32]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut strips = acc.chunks_exact_mut(STRIP);
    let mut rows = src.chunks_exact(STRIP);
    for (a, r) in strips.by_ref().zip(rows.by_ref()) {
        for (av, &rv) in a.iter_mut().zip(r) {
            *av += rv as i64;
        }
    }
    for (av, &rv) in strips.into_remainder().iter_mut().zip(rows.remainder()) {
        *av += rv as i64;
    }
}

/// `acc[k] += src[k]` over an `i64` staging row (broadcasting one
/// materialized LUT×tile product into every row of a code group).
#[inline]
pub fn add_i64(acc: &mut [i64], src: &[i64]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut strips = acc.chunks_exact_mut(STRIP);
    let mut rows = src.chunks_exact(STRIP);
    for (a, r) in strips.by_ref().zip(rows.by_ref()) {
        for (av, &rv) in a.iter_mut().zip(r) {
            *av += rv;
        }
    }
    for (av, &rv) in strips.into_remainder().iter_mut().zip(rows.remainder()) {
        *av += rv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random operands without pulling in a PRNG.
    fn pattern_i64(len: usize, salt: i64) -> Vec<i64> {
        (0..len).map(|k| (k as i64 * 37 + salt) % 1001 - 500).collect()
    }

    #[test]
    fn axpy_i16_matches_scalar_for_all_tail_lengths() {
        for len in 0..3 * STRIP + 1 {
            let mut acc = pattern_i64(len, 3);
            let want: Vec<i64> = acc
                .iter()
                .enumerate()
                .map(|(k, &a)| a + -7 * ((k as i64 * 13 - 91) % 300))
                .collect();
            let row: Vec<i16> =
                (0..len).map(|k| ((k as i64 * 13 - 91) % 300) as i16).collect();
            axpy_i16(&mut acc, &row, -7);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn add_i32_matches_scalar_for_all_tail_lengths() {
        for len in 0..3 * STRIP + 1 {
            let mut acc = pattern_i64(len, 11);
            let src: Vec<i32> =
                (0..len).map(|k| (k as i32 * 29 - 400) % 9999).collect();
            let want: Vec<i64> = acc
                .iter()
                .zip(&src)
                .map(|(&a, &s)| a + s as i64)
                .collect();
            add_i32(&mut acc, &src);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn add_i64_matches_scalar_for_all_tail_lengths() {
        for len in 0..3 * STRIP + 1 {
            let mut acc = pattern_i64(len, 23);
            let src = pattern_i64(len, 41);
            let want: Vec<i64> =
                acc.iter().zip(&src).map(|(&a, &s)| a + s).collect();
            add_i64(&mut acc, &src);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn axpy_i16_is_exact_at_operand_extremes() {
        // |b| can reach 2^30 - 1 (widest LUT the plan accepts) and the
        // coefficients span the full i16 range; the product must widen
        // through i64 without saturating or wrapping
        let b = (1i64 << 30) - 1;
        let row = [i16::MIN, i16::MAX, -1, 1];
        let mut acc = [0i64; 4];
        axpy_i16(&mut acc, &row, b);
        assert_eq!(acc[0], b * i16::MIN as i64);
        assert_eq!(acc[1], b * i16::MAX as i64);
        assert_eq!(acc[2], -b);
        assert_eq!(acc[3], b);
    }
}
