//! KAN model substrate: B-spline math, quantized layers/models, and the
//! artifact checkpoint schemas.

pub mod checkpoint;
pub mod engine;
pub mod kernels;
pub mod layer;
pub mod model;
pub mod plan;
pub mod spline;
pub mod tune;

pub use checkpoint::{Dataset, KanCheckpoint, Manifest, MlpCheckpoint};
pub use engine::{EngineOptions, EngineProfile, EngineScratch, KanEngine, LayerProfile};
pub use layer::QuantKanLayer;
pub use model::{argmax, QuantKanModel};
pub use plan::{KanPlan, LayerPlan, PlanOptions};
pub use tune::{autotune, TuneCandidate, TuneOutcome, TuneReport};
