//! Deterministic PRNG (no `rand` in the offline image): SplitMix64 seeding
//! a xoshiro256++ core, plus the distributions the simulator needs.
//!
//! Quality is far beyond what the noise models require (xoshiro256++ passes
//! BigCrush); determinism given a seed is the property the experiments
//! depend on.

/// One-way 64-bit mix (the SplitMix64 finalizer over `seed ^ f(salt)`):
/// derives statistically independent sub-seeds from a base seed and a
/// salt (row index, trial index). Pure and stable — the serving path
/// relies on it to make per-request noise reproducible regardless of
/// batching or worker count.
pub fn mix(seed: u64, salt: u64) -> u64 {
    let mut z = seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (handles seed = 0 fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0
            .wrapping_add(s3)
            .rotate_left(23)
            .wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal (Box-Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let u1 = self.uniform().max(f64::EPSILON);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn int_range_inclusive() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            let v = r.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
