//! Self-contained utility substrate for the fully-offline build: JSON
//! parsing/writing, a deterministic PRNG, and a benchmark harness. The
//! build image vendors only the `xla` crate's dependency closure (plus
//! `anyhow`/`thiserror`), so serde_json / rand / criterion equivalents are
//! implemented here (DESIGN.md §4).

pub mod bench;
pub mod json;
pub mod rng;
pub mod sync;

pub use json::Value;
pub use rng::Rng;
