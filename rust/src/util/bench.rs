//! Tiny benchmark harness (no criterion in the offline image).
//!
//! Warmup + timed iterations with median/mean/p95 reporting, plus a
//! `black_box` to defeat the optimizer. Used by every target in
//! `rust/benches/` (all declared `harness = false`).

use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding the benchmarked computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Time `f` adaptively: warm up, pick an iteration count targeting
/// ~`target_ms` of total runtime, then report per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((target_ms as f64 * 1e6) / once.as_nanos() as f64)
        .clamp(5.0, 1e6) as usize;

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        median: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min: samples[0],
    }
}

/// Pretty-print one result row.
pub fn report(r: &BenchResult) {
    println!(
        "  {:<44} {:>12} {:>12} {:>12}  ({} iters)",
        r.name,
        fmt_dur(r.median),
        fmt_dur(r.mean),
        fmt_dur(r.p95),
        r.iters
    );
}

/// Print the table header matching [`report`].
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "  {:<44} {:>12} {:>12} {:>12}",
        "case", "median", "mean", "p95"
    );
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_plausible_stats() {
        let r = bench("noop-ish", 5, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.median);
        assert!(r.median <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_dur(Duration::from_nanos(2_500)).ends_with("us"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }

    #[test]
    fn slow_bodies_get_few_iterations() {
        let r = bench("slow", 1, || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.iters <= 10, "{} iters", r.iters);
    }
}
