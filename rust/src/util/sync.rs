//! Poisoning-recovery lock helpers for the serving path.
//!
//! A poisoned `Mutex`/`RwLock` means some thread panicked while holding
//! the guard. The serving stack's policy is **availability over
//! poisoning**: every shared structure behind a lock here is either a
//! monotone counter set, a bounded ring, or a map that is rebuilt from
//! durable state (the manifest) — recovering the guard and continuing
//! is strictly better than letting one panicked worker cascade a panic
//! into every thread that touches the same lock. Request-path panics
//! are already converted to structured `internal` errors by the
//! dispatch layer's `catch_unwind`; these helpers make sure the *next*
//! request does not inherit the blast radius.
//!
//! The repo-native lint (`kan-edge lint`, see `docs/ANALYSIS.md`)
//! enforces the pairing: a bare `.lock().unwrap()` in a serving module
//! is a `lock-poison` finding; acquisitions through these helpers are
//! recognized as the sanctioned idiom.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard,
    RwLockWriteGuard, WaitTimeoutResult,
};
use std::time::Duration;

/// `Mutex` acquisition that recovers from poisoning instead of
/// propagating the panic.
pub trait LockExt<T> {
    /// Like `lock().unwrap()`, but a poisoned mutex yields its inner
    /// guard instead of panicking.
    fn lock_recover(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_recover(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// `RwLock` acquisition that recovers from poisoning.
pub trait RwLockExt<T> {
    fn read_recover(&self) -> RwLockReadGuard<'_, T>;
    fn write_recover(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn read_recover(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn write_recover(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Condvar waits that recover the re-acquired guard from poisoning.
/// (The wait itself *releases* the lock — it is never a
/// held-across-blocking hazard; only the re-acquisition can observe
/// poison.)
pub trait CondvarExt {
    fn wait_recover<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;
    fn wait_timeout_recover<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult);
}

impl CondvarExt for Condvar {
    fn wait_recover<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait(guard).unwrap_or_else(PoisonError::into_inner)
    }

    fn wait_timeout_recover<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        self.wait_timeout(guard, dur)
            .unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock_recover();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*m.lock_recover(), 7);
        *m.lock_recover() = 8;
        assert_eq!(*m.lock_recover(), 8);
    }

    #[test]
    fn rwlock_recover_survives_poison() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write_recover();
            panic!("poison it");
        })
        .join();
        assert_eq!(*l.read_recover(), 1);
        *l.write_recover() = 2;
        assert_eq!(*l.read_recover(), 2);
    }

    #[test]
    fn wait_timeout_recover_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock_recover();
        let (_g, res) = cv.wait_timeout_recover(g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
