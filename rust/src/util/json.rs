//! Minimal, correct JSON parser + writer.
//!
//! The build image is fully offline and carries no serde_json, so the
//! artifact interchange (weights/manifest/dataset JSON written by
//! `python/compile/aot.py`) is parsed by this module. It implements the
//! whole of RFC 8259 minus some escape exotica we never emit (`\uXXXX` *is*
//! supported), with precise number handling (i64 when exact, f64 otherwise).

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integral number that fits i64 exactly.
    Int(i64),
    /// Any other number.
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the path name (for loaders).
    pub fn field(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    // checked variants used by the artifact loaders
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not an unsigned int")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.field(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a string")))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.field(key)?
            .as_array()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not an array")))
    }

    /// Array of f64 (numbers), e.g. weight blobs.
    pub fn f64_vec(&self, key: &str) -> Result<Vec<f64>> {
        self.req_array(key)?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::Json(format!("'{key}': non-number element")))
            })
            .collect()
    }

    pub fn f32_vec(&self, key: &str) -> Result<Vec<f32>> {
        Ok(self.f64_vec(key)?.into_iter().map(|v| v as f32).collect())
    }

    pub fn i64_vec(&self, key: &str) -> Result<Vec<i64>> {
        self.req_array(key)?
            .iter()
            .map(|v| {
                v.as_i64()
                    .ok_or_else(|| Error::Json(format!("'{key}': non-integer element")))
            })
            .collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, text: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- writer ---------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => {
                if x.is_finite() {
                    write!(f, "{x}")
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for report serialization.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Int(v as i64)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-42").unwrap(), Value::Int(-42));
        assert_eq!(Value::parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(Value::parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2.5, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let inner = &v.get("a").unwrap().as_array().unwrap()[2];
        assert_eq!(inner.get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("tru").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"\\q\"").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Value::parse(r#""é😀""#).unwrap(),
            Value::Str("é😀".into())
        );
        assert_eq!(Value::parse("\"é😀\"").unwrap(), Value::Str("é😀".into()));
    }

    #[test]
    fn roundtrip_through_display() {
        let src = r#"{"arr":[1,2.5,-3],"b":true,"s":"a\"b\nc","z":null}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn big_float_array() {
        let text = format!(
            "[{}]",
            (0..1000)
                .map(|i| format!("{}", i as f64 * 0.25 - 100.0))
                .collect::<Vec<_>>()
                .join(",")
        );
        let v = Value::parse(&text).unwrap();
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 1000);
        assert_eq!(arr[0].as_f64().unwrap(), -100.0);
        assert_eq!(arr[401].as_f64().unwrap(), 0.25);
    }

    #[test]
    fn typed_accessor_errors_name_the_field() {
        let v = Value::parse(r#"{"x": "s"}"#).unwrap();
        let e = v.req_f64("x").unwrap_err().to_string();
        assert!(e.contains("'x'"), "{e}");
        let e = v.req_f64("missing").unwrap_err().to_string();
        assert!(e.contains("'missing'"), "{e}");
    }

    #[test]
    fn python_json_style_numbers() {
        // python json.dump writes bare floats like 0.014something and ints
        let v = Value::parse(r#"{"a": 0.013999999999999999, "b": 190214}"#).unwrap();
        assert!((v.req_f64("a").unwrap() - 0.014).abs() < 1e-12);
        assert_eq!(v.field("b").unwrap().as_i64().unwrap(), 190214);
    }
}
