//! Observability: request tracing, engine profiling statistics, and a
//! Prometheus-style exposition plane (`docs/OBSERVABILITY.md`).
//!
//! Three dependency-free pieces, sharing the bounded-memory,
//! never-block-the-hot-path contract the shadow mirror established:
//!
//! * [`log`] — a leveled structured logger emitting one JSON object per
//!   line to stderr, replacing the scattered ad-hoc `eprintln!` sites.
//!   Level comes from the `[observability]` config section or the
//!   `KAN_EDGE_LOG` environment variable.
//! * [`trace`] — end-to-end request tracing: a [`trace::TraceHub`]
//!   deterministically samples 1-in-N served v2 `infer` requests, and a
//!   sampled request carries a lock-free [`trace::SpanCell`] through
//!   admission → scheduler queue → batcher → engine execute → response
//!   write, each stage stamped with a monotonic offset. Completed spans
//!   land in a bounded ring buffer (the `trace` control verb reads it)
//!   and feed a per-model p50/p99 stage rollup folded into
//!   [`crate::coordinator::metrics::MetricsReport`].
//! * [`prom`] — Prometheus text-format exposition rendering of the
//!   whole metrics tree (wire, scheduler, shadow, per-model, trace),
//!   served by the `metrics_prom` control verb and the
//!   `kan-edge metrics --prom` subcommand, plus the grammar validator
//!   the tests and the CI scrape gate on.
//!
//! The module also hosts [`rank_correlation`], the Spearman statistic
//! used to report live-vs-calibration interval-occupancy "mapping
//! drift" per layer (see `DigitalSession::profile`).

pub mod log;
pub mod prom;
pub mod trace;

/// Spearman rank correlation between two equal-length samples, with
/// average ranks for ties (interval-occupancy vectors are tie-heavy:
/// most cold intervals count zero).
///
/// Returns a value in `[-1, 1]`; `0.0` when either input is shorter
/// than 2 or has zero rank variance (a constant vector carries no
/// ordering to agree or disagree with).
///
/// This is the engine's "mapping drift" statistic: the SAM tile
/// placement ranked intervals by calibration-time activation
/// probability, so the rank correlation between that prior and the live
/// occupancy histogram says how well the calibration ordering still
/// matches traffic (`1.0` = same ranking, `~0` = unrelated).
pub fn rank_correlation(a: &[f64], b: &[f64]) -> f64 {
    if a.len() != b.len() || a.len() < 2 {
        return 0.0;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    let n = ra.len() as f64;
    let mean = (n + 1.0) / 2.0;
    let (mut cov, mut va, mut vb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in ra.iter().zip(&rb) {
        let dx = x - mean;
        let dy = y - mean;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// 1-based ranks with ties averaged (the standard Spearman treatment).
fn ranks(v: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0f64; v.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && v[idx[j + 1]] == v[idx[i]] {
            j += 1;
        }
        // positions i..=j hold a tie group: each gets the average rank
        let rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = rank;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement_is_one() {
        let a = [0.1, 0.4, 0.2, 0.9];
        let b = [1.0, 4.0, 2.0, 9.0];
        assert!((rank_correlation(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reversed_order_is_minus_one() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [4.0, 3.0, 2.0, 1.0];
        assert!((rank_correlation(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ties_average_and_degenerate_inputs_are_zero() {
        // tie-heavy vectors still produce a bounded statistic
        let a = [0.0, 0.0, 1.0, 2.0, 0.0];
        let b = [0.0, 0.0, 2.0, 3.0, 0.0];
        let r = rank_correlation(&a, &b);
        assert!(r > 0.9 && r <= 1.0, "{r}");
        // constant vector: no ordering information
        assert_eq!(rank_correlation(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        // length mismatch and short inputs
        assert_eq!(rank_correlation(&[1.0], &[1.0]), 0.0);
        assert_eq!(rank_correlation(&[1.0, 2.0], &[1.0]), 0.0);
    }
}
