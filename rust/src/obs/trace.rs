//! End-to-end request tracing.
//!
//! A sampled v2 `infer` request carries a [`TraceHandle`] (an
//! `Arc<SpanCell>`) from the TCP dispatch thread through admission, the
//! scheduler queue, the batcher, the execution session, and the response
//! write. Each pipeline stage stamps a monotonic offset into the cell
//! with a single relaxed atomic store — no locks, no allocation on the
//! hot path. When the response has been written, the dispatch thread
//! hands the cell to [`TraceHub::finish`], which folds it into a bounded
//! ring buffer (read by the `trace` control verb) and a bounded
//! per-model stage rollup (folded into `MetricsReport` as p50/p99
//! per-stage durations).
//!
//! ## Stage partition
//!
//! The five stages partition the server-side lifetime of a request
//! exactly — durations sum to the end-to-end total by construction:
//!
//! | stage     | ends when                                            |
//! |-----------|------------------------------------------------------|
//! | admission | scheduler `try_submit` accepted the request          |
//! | queue     | the batcher closed the batch containing it           |
//! | batch     | a worker picked the batch up and is about to execute |
//! | execute   | the execution session returned                       |
//! | respond   | the response frame was written to the socket         |
//!
//! Unsampled requests carry `None` and pay one branch per stage.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::sync::LockExt;
use crate::coordinator::metrics::percentile;
use crate::util::json::{arr, obj, Value};

/// Pipeline stages, in order. Values index [`SpanCell`] stamp slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Admission = 0,
    Queue = 1,
    Batch = 2,
    Execute = 3,
    Respond = 4,
}

/// Number of stages (stamp slots per span).
pub const STAGES: usize = 5;

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; STAGES] = [
        Stage::Admission,
        Stage::Queue,
        Stage::Batch,
        Stage::Execute,
        Stage::Respond,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Batch => "batch",
            Stage::Execute => "execute",
            Stage::Respond => "respond",
        }
    }
}

/// Per-request span: a creation instant plus one atomic stamp slot per
/// stage. Stamps store `elapsed_µs + 1` so zero can mean "never marked"
/// (a request that errored out mid-pipeline leaves later slots unset).
#[derive(Debug)]
pub struct SpanCell {
    id: i64,
    t0: Instant,
    stamps: [AtomicU64; STAGES],
}

/// Shared handle threaded through the pipeline alongside a request.
pub type TraceHandle = Arc<SpanCell>;

impl SpanCell {
    pub fn new(id: i64) -> SpanCell {
        SpanCell {
            id,
            t0: Instant::now(),
            stamps: Default::default(),
        }
    }

    /// Request id (the wire-protocol request id for v2 requests).
    pub fn id(&self) -> i64 {
        self.id
    }

    /// Stamp `stage` as completed now.
    pub fn mark(&self, stage: Stage) {
        self.mark_at(stage, Instant::now());
    }

    /// Stamp `stage` as completed at `at` (used when the completion
    /// instant was captured elsewhere, e.g. the batcher's `closed_at`).
    /// `fetch_max` keeps stamps monotone if a stage is marked twice.
    pub fn mark_at(&self, stage: Stage, at: Instant) {
        let us = at.saturating_duration_since(self.t0).as_micros() as u64;
        self.stamps[stage as usize].fetch_max(us + 1, Ordering::Relaxed);
    }

    /// Raw offsets from span creation, in µs; `None` = stage never ran.
    pub fn offsets_us(&self) -> [Option<u64>; STAGES] {
        let mut out = [None; STAGES];
        for (slot, stamp) in out.iter_mut().zip(&self.stamps) {
            let v = stamp.load(Ordering::Relaxed);
            if v > 0 {
                *slot = Some(v - 1);
            }
        }
        out
    }
}

/// A completed (or abandoned) span, as stored in the ring buffer.
/// `stages_us` holds per-stage *durations*: `admission` is measured
/// from span creation, every later stage from the previous stage's
/// stamp — so present durations sum to `total_us` exactly.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub id: i64,
    pub model: String,
    pub stages_us: [Option<u64>; STAGES],
    pub total_us: u64,
    pub complete: bool,
}

impl SpanRecord {
    fn from_cell(cell: &SpanCell, model: &str) -> SpanRecord {
        let offsets = cell.offsets_us();
        let mut stages = [None; STAGES];
        let mut prev = 0u64;
        let mut total = 0u64;
        let mut complete = true;
        for (i, off) in offsets.iter().enumerate() {
            match off {
                Some(o) => {
                    // stamps come from different threads (e.g. admission
                    // from the submitter, queue from the worker at the
                    // batcher's close instant) and can land a few µs out
                    // of order; clamping keeps the partition exact
                    let o = (*o).max(prev);
                    stages[i] = Some(o - prev);
                    prev = o;
                    total = o;
                }
                None => complete = false,
            }
        }
        SpanRecord {
            id: cell.id,
            model: model.to_string(),
            stages_us: stages,
            total_us: total,
            complete,
        }
    }

    pub fn to_value(&self) -> Value {
        let mut stage_fields = Vec::with_capacity(STAGES);
        for (stage, d) in Stage::ALL.iter().zip(&self.stages_us) {
            let v = match d {
                Some(us) => Value::Int(*us as i64),
                None => Value::Null,
            };
            stage_fields.push((stage.as_str(), v));
        }
        obj(vec![
            ("id", Value::Int(self.id)),
            ("model", Value::Str(self.model.clone())),
            ("stages_us", obj(stage_fields)),
            ("total_us", Value::Int(self.total_us as i64)),
            ("complete", Value::Bool(self.complete)),
        ])
    }
}

/// Per-model bounded sliding windows of per-stage durations, feeding
/// the p50/p99 rollup. One window per stage, capped at
/// [`ROLLUP_WINDOW`] samples (oldest evicted first).
const ROLLUP_WINDOW: usize = 1024;

#[derive(Debug, Default)]
struct StageWindows {
    count: u64,
    windows: [VecDeque<u64>; STAGES],
}

/// p50/p99 of per-stage durations for one model, over the rollup
/// window. Folded into `MetricsReport` as the `stages` section.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    /// Completed sampled spans observed (monotonic, not windowed).
    pub count: u64,
    pub p50_us: [u64; STAGES],
    pub p99_us: [u64; STAGES],
}

impl StageReport {
    pub fn to_value(&self) -> Value {
        let mut fields = vec![("count", Value::Int(self.count as i64))];
        for (i, stage) in Stage::ALL.iter().enumerate() {
            fields.push((
                stage.as_str(),
                obj(vec![
                    ("p50_us", Value::Int(self.p50_us[i] as i64)),
                    ("p99_us", Value::Int(self.p99_us[i] as i64)),
                ]),
            ));
        }
        obj(fields)
    }
}

/// Sampling + storage hub. One per `TcpServer`.
///
/// Memory is bounded by construction: the ring holds at most `cap`
/// records and each model's rollup at most `ROLLUP_WINDOW` samples per
/// stage. Sampling is deterministic — request counter modulo N — so
/// tests and the overhead bench see a fixed schedule.
#[derive(Debug)]
pub struct TraceHub {
    sample_every: u64,
    cap: usize,
    counter: AtomicU64,
    sampled: AtomicU64,
    completed: AtomicU64,
    ring: Mutex<VecDeque<SpanRecord>>,
    rollup: Mutex<BTreeMap<String, StageWindows>>,
}

impl TraceHub {
    /// `sample_every` = N for 1-in-N sampling (0 disables tracing);
    /// `cap` = ring-buffer capacity in spans.
    pub fn new(sample_every: u64, cap: usize) -> TraceHub {
        TraceHub {
            sample_every,
            cap: cap.max(1),
            counter: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            rollup: Mutex::new(BTreeMap::new()),
        }
    }

    /// A hub that never samples — the default for embedded servers and
    /// existing callers that don't opt in.
    pub fn disabled() -> TraceHub {
        TraceHub::new(0, 1)
    }

    /// Whether any request can ever be sampled.
    pub fn enabled(&self) -> bool {
        self.sample_every > 0
    }

    /// 1-in-N decision for the next request. Returns a live span handle
    /// on the sampled schedule, `None` otherwise. The first request is
    /// always sampled when enabled (counter starts at 0).
    pub fn sample(&self, id: i64) -> Option<TraceHandle> {
        if self.sample_every == 0 {
            return None;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_every != 0 {
            return None;
        }
        self.sampled.fetch_add(1, Ordering::Relaxed);
        Some(Arc::new(SpanCell::new(id)))
    }

    /// Fold a finished span into the ring and the per-model rollup.
    /// Called once per sampled request after the response write (also
    /// on error paths, with whatever stages were stamped).
    pub fn finish(&self, span: &SpanCell, model: &str) {
        let record = SpanRecord::from_cell(span, model);
        if record.complete {
            self.completed.fetch_add(1, Ordering::Relaxed);
            let mut rollup = self.rollup.lock_recover();
            let windows = rollup.entry(model.to_string()).or_default();
            windows.count += 1;
            for (w, d) in windows.windows.iter_mut().zip(&record.stages_us) {
                if let Some(us) = d {
                    if w.len() >= ROLLUP_WINDOW {
                        w.pop_front();
                    }
                    w.push_back(*us);
                }
            }
        }
        let mut ring = self.ring.lock_recover();
        if ring.len() >= self.cap {
            ring.pop_front();
        }
        ring.push_back(record);
    }

    /// Most recent spans, newest first, at most `limit`.
    pub fn recent(&self, limit: usize) -> Vec<SpanRecord> {
        let ring = self.ring.lock_recover();
        ring.iter().rev().take(limit).cloned().collect()
    }

    /// Current ring occupancy (test hook for the boundedness contract).
    pub fn ring_len(&self) -> usize {
        self.ring.lock_recover().len()
    }

    /// p50/p99 stage breakdown for one model, if any sampled spans for
    /// it completed.
    pub fn stage_report(&self, model: &str) -> Option<StageReport> {
        let rollup = self.rollup.lock_recover();
        let windows = rollup.get(model)?;
        let mut p50 = [0u64; STAGES];
        let mut p99 = [0u64; STAGES];
        for (i, w) in windows.windows.iter().enumerate() {
            let mut sorted: Vec<u64> = w.iter().copied().collect();
            sorted.sort_unstable();
            p50[i] = percentile(&sorted, 0.50);
            p99[i] = percentile(&sorted, 0.99);
        }
        Some(StageReport {
            count: windows.count,
            p50_us: p50,
            p99_us: p99,
        })
    }

    /// Summary counters for the `trace` verb / `metrics` body.
    pub fn summary_value(&self) -> Value {
        obj(vec![
            ("sample_every", Value::Int(self.sample_every as i64)),
            ("ring_capacity", Value::Int(self.cap as i64)),
            ("ring_len", Value::Int(self.ring_len() as i64)),
            (
                "sampled_total",
                Value::Int(self.sampled.load(Ordering::Relaxed) as i64),
            ),
            (
                "completed_total",
                Value::Int(self.completed.load(Ordering::Relaxed) as i64),
            ),
        ])
    }

    /// Body for the `trace` control verb: summary plus recent spans.
    pub fn to_value(&self, limit: usize) -> Value {
        let spans: Vec<Value> = self.recent(limit).iter().map(|r| r.to_value()).collect();
        obj(vec![
            ("summary", self.summary_value()),
            ("spans", arr(spans)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn finish_marked(hub: &TraceHub, id: i64) {
        let span = SpanCell::new(id);
        for s in Stage::ALL {
            span.mark(s);
        }
        hub.finish(&span, "m");
    }

    #[test]
    fn sampling_schedule_is_deterministic() {
        let hub = TraceHub::new(4, 16);
        let hits: Vec<bool> = (0..12).map(|i| hub.sample(i).is_some()).collect();
        assert_eq!(
            hits,
            vec![true, false, false, false, true, false, false, false, true, false, false, false]
        );
        let off = TraceHub::new(0, 16);
        assert!(!off.enabled());
        assert!((0..100).all(|i| off.sample(i).is_none()));
    }

    #[test]
    fn durations_partition_total() {
        let span = SpanCell::new(7);
        let base = span.t0;
        for (i, s) in Stage::ALL.iter().enumerate() {
            span.mark_at(*s, base + Duration::from_micros(100 * (i as u64 + 1)));
        }
        let rec = SpanRecord::from_cell(&span, "m");
        assert!(rec.complete);
        assert_eq!(rec.total_us, 500);
        let sum: u64 = rec.stages_us.iter().map(|d| d.unwrap()).sum();
        assert_eq!(sum, rec.total_us);
        assert!(rec.stages_us.iter().all(|d| d == &Some(100)));
    }

    #[test]
    fn incomplete_span_keeps_missing_stages_none() {
        let hub = TraceHub::new(1, 8);
        let span = hub.sample(1).unwrap();
        span.mark(Stage::Admission);
        hub.finish(&span, "m");
        let recent = hub.recent(10);
        assert_eq!(recent.len(), 1);
        assert!(!recent[0].complete);
        assert!(recent[0].stages_us[Stage::Admission as usize].is_some());
        assert!(recent[0].stages_us[Stage::Respond as usize].is_none());
        // incomplete spans do not pollute the rollup
        assert!(hub.stage_report("m").is_none());
    }

    #[test]
    fn ring_is_bounded_and_newest_first() {
        let hub = TraceHub::new(1, 8);
        for i in 0..1000 {
            finish_marked(&hub, i);
        }
        assert_eq!(hub.ring_len(), 8);
        let recent = hub.recent(3);
        assert_eq!(recent[0].id, 999);
        assert_eq!(recent[1].id, 998);
        let report = hub.stage_report("m").unwrap();
        assert_eq!(report.count, 1000);
    }
}
