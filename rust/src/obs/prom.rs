//! Prometheus text-format exposition.
//!
//! [`render`] walks the same JSON tree the `metrics` control verb
//! returns and flattens every numeric leaf into a
//! `kan_edge_*`-prefixed gauge sample, so the Prometheus plane can
//! never drift from the JSON plane — new counters show up in both the
//! moment they are added to a report. Per-model series keep the model
//! id out of the metric name and in a `model="..."` label, following
//! Prometheus naming conventions.
//!
//! [`validate`] is a strict line-grammar checker for the subset of the
//! text format we emit (`# `-comments, `name{label="value"} value`).
//! The `metrics --prom` subcommand and the CI scrape step both gate on
//! it, so an exposition regression fails fast instead of surfacing as
//! a scrape error in some downstream collector.

use std::collections::BTreeMap;

use crate::util::json::Value;

/// One flattened sample: optional `(label_name, label_value)` + value.
type Sample = (Option<(String, String)>, f64);

/// Render a metrics JSON tree as Prometheus text format.
///
/// Mapping rules:
/// * the top-level `models` object becomes per-model series — each
///   model's subtree renders with the metric name
///   `kan_edge_model_<path>` and a `model="<id>"` label;
/// * the top-level `nodes` object (the cluster router's rollup) becomes
///   per-node series the same way: `kan_edge_node_<path>` with a
///   `node="<id>"` label (see `docs/CLUSTER.md`);
/// * the top-level `rollout` object (the rollout plane's overlay)
///   becomes per-rollout series: `kan_edge_rollout_<path>` with a
///   `model="<name>"` label (see `docs/ROLLOUT.md`);
/// * every other top-level section renders as
///   `kan_edge_<section>_<path>` with no labels;
/// * array elements append their index to the path;
/// * non-numeric leaves (strings, bools, nulls) and non-finite floats
///   are skipped — Prometheus samples are numbers.
///
/// Samples sharing a metric name are grouped under one `# TYPE` line.
/// Everything is declared `gauge`: several of our "counters" are
/// windowed or reservoir-derived, and gauge is the honest common type.
pub fn render(root: &Value) -> String {
    let mut samples: BTreeMap<String, Vec<Sample>> = BTreeMap::new();
    if let Some(map) = root.as_object() {
        for (section, v) in map {
            if section == "models" {
                if let Some(models) = v.as_object() {
                    for (id, report) in models {
                        let label = Some(("model".to_string(), id.clone()));
                        collect(report, &mut vec!["model".to_string()], &label, &mut samples);
                    }
                }
            } else if section == "nodes" {
                if let Some(nodes) = v.as_object() {
                    for (id, report) in nodes {
                        let label = Some(("node".to_string(), id.clone()));
                        collect(report, &mut vec!["node".to_string()], &label, &mut samples);
                    }
                }
            } else if section == "rollout" {
                if let Some(rollouts) = v.as_object() {
                    for (name, report) in rollouts {
                        let label = Some(("model".to_string(), name.clone()));
                        collect(report, &mut vec!["rollout".to_string()], &label, &mut samples);
                    }
                }
            } else {
                collect(v, &mut vec![section.clone()], &None, &mut samples);
            }
        }
    }
    let mut out = String::new();
    for (name, rows) in &samples {
        out.push_str(&format!("# TYPE {name} gauge\n"));
        for (label, value) in rows {
            match label {
                Some((k, v)) => {
                    let val = fmt(*value);
                    out.push_str(&format!("{name}{{{k}=\"{}\"}} {val}\n", escape_label(v)));
                }
                None => out.push_str(&format!("{name} {}\n", fmt(*value))),
            }
        }
    }
    out
}

fn collect(
    v: &Value,
    path: &mut Vec<String>,
    label: &Option<(String, String)>,
    samples: &mut BTreeMap<String, Vec<Sample>>,
) {
    match v {
        Value::Int(_) | Value::Float(_) => {
            let x = v.as_f64().unwrap_or(f64::NAN);
            if x.is_finite() {
                let mut name = String::from("kan_edge");
                for seg in path.iter() {
                    name.push('_');
                    name.push_str(&sanitize(seg));
                }
                samples.entry(name).or_default().push((label.clone(), x));
            }
        }
        Value::Object(map) => {
            for (k, child) in map {
                path.push(k.clone());
                collect(child, path, label, samples);
                path.pop();
            }
        }
        Value::Array(items) => {
            for (i, child) in items.iter().enumerate() {
                path.push(i.to_string());
                collect(child, path, label, samples);
                path.pop();
            }
        }
        _ => {}
    }
}

/// Replace anything outside `[a-zA-Z0-9_]` with `_`; prefix a digit
/// with `_` so a path segment like `0` stays a legal name part.
fn sanitize(seg: &str) -> String {
    let mut out = String::with_capacity(seg.len());
    for c in seg.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format a sample value: integral values without a fraction, others
/// via the shortest roundtrip float formatting Rust gives us.
fn fmt(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Validate Prometheus text-format lines (the subset we emit, which is
/// also the subset most exporters emit): comment lines starting with
/// `# `, blank lines, and sample lines `name[{labels}] value`.
/// Returns the first offense as `Err("line N: reason")`.
pub fn validate(text: &str) -> Result<(), String> {
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if rest.starts_with(' ') {
                continue;
            }
            return Err(format!("line {n}: comment must start with '# '"));
        }
        validate_sample(line).map_err(|e| format!("line {n}: {e}"))?;
    }
    Ok(())
}

fn is_name_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':'
}

fn is_name_char(c: char) -> bool {
    is_name_start(c) || c.is_ascii_digit()
}

fn validate_sample(line: &str) -> Result<(), String> {
    let bytes: Vec<char> = line.chars().collect();
    let mut pos = 0;
    // metric name
    if pos >= bytes.len() || !is_name_start(bytes[pos]) {
        return Err("metric name must start with [a-zA-Z_:]".into());
    }
    while pos < bytes.len() && is_name_char(bytes[pos]) {
        pos += 1;
    }
    // optional label set
    if pos < bytes.len() && bytes[pos] == '{' {
        pos += 1;
        loop {
            if pos < bytes.len() && bytes[pos] == '}' {
                pos += 1;
                break;
            }
            // label name
            if pos >= bytes.len() || !is_name_start(bytes[pos]) {
                return Err("label name must start with [a-zA-Z_:]".into());
            }
            while pos < bytes.len() && is_name_char(bytes[pos]) {
                pos += 1;
            }
            if pos >= bytes.len() || bytes[pos] != '=' {
                return Err("expected '=' after label name".into());
            }
            pos += 1;
            if pos >= bytes.len() || bytes[pos] != '"' {
                return Err("label value must be double-quoted".into());
            }
            pos += 1;
            while pos < bytes.len() && bytes[pos] != '"' {
                if bytes[pos] == '\\' {
                    pos += 1; // escape consumes the next char
                    if pos >= bytes.len() {
                        return Err("dangling escape in label value".into());
                    }
                }
                pos += 1;
            }
            if pos >= bytes.len() {
                return Err("unterminated label value".into());
            }
            pos += 1; // closing quote
            if pos < bytes.len() && bytes[pos] == ',' {
                pos += 1;
            } else if pos >= bytes.len() || bytes[pos] != '}' {
                return Err("expected ',' or '}' after label".into());
            }
        }
    }
    // single space, then the value
    if pos >= bytes.len() || bytes[pos] != ' ' {
        return Err("expected ' ' before sample value".into());
    }
    pos += 1;
    let value: String = bytes[pos..].iter().collect();
    if value.is_empty() {
        return Err("missing sample value".into());
    }
    match value.as_str() {
        "NaN" | "+Inf" | "-Inf" => Ok(()),
        v => v
            .parse::<f64>()
            .map(|_| ())
            .map_err(|_| format!("invalid sample value '{v}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{arr, obj};

    #[test]
    fn renders_sections_and_model_labels() {
        let root = obj(vec![
            (
                "wire",
                obj(vec![
                    ("v2_requests", Value::Int(12)),
                    ("connections_active", Value::Int(1)),
                ]),
            ),
            (
                "models",
                obj(vec![(
                    "bench",
                    obj(vec![
                        ("requests", Value::Int(5)),
                        ("latency_p99_us", Value::Int(740)),
                        ("name", Value::Str("bench".into())),
                    ]),
                )]),
            ),
        ]);
        let text = render(&root);
        assert!(text.contains("# TYPE kan_edge_wire_v2_requests gauge\n"));
        assert!(text.contains("kan_edge_wire_v2_requests 12\n"));
        assert!(text.contains("kan_edge_model_requests{model=\"bench\"} 5\n"));
        assert!(text.contains("kan_edge_model_latency_p99_us{model=\"bench\"} 740\n"));
        // string leaf skipped
        assert!(!text.contains("kan_edge_model_name"));
        validate(&text).unwrap();
    }

    #[test]
    fn arrays_index_and_bad_chars_sanitize() {
        let root = obj(vec![(
            "models",
            obj(vec![(
                "a-b.c",
                obj(vec![("hist", arr(vec![Value::Int(1), Value::Float(2.5)]))]),
            )]),
        )]);
        let text = render(&root);
        assert!(text.contains("kan_edge_model_hist_0{model=\"a-b.c\"} 1\n"));
        assert!(text.contains("kan_edge_model_hist_1{model=\"a-b.c\"} 2.5\n"));
        validate(&text).unwrap();
    }

    #[test]
    fn nodes_section_gets_node_labels() {
        let root = obj(vec![
            ("cluster", obj(vec![("hedges", Value::Int(3))])),
            (
                "nodes",
                obj(vec![
                    (
                        "node-a",
                        obj(vec![("up", Value::Int(1)), ("requests", Value::Int(7))]),
                    ),
                    (
                        "node-b",
                        obj(vec![("up", Value::Int(0)), ("state", Value::Str("down".into()))]),
                    ),
                ]),
            ),
        ]);
        let text = render(&root);
        assert!(text.contains("kan_edge_cluster_hedges 3\n"));
        assert!(text.contains("kan_edge_node_up{node=\"node-a\"} 1\n"));
        assert!(text.contains("kan_edge_node_requests{node=\"node-a\"} 7\n"));
        assert!(text.contains("kan_edge_node_up{node=\"node-b\"} 0\n"));
        // string leaves (state) are skipped, as everywhere else
        assert!(!text.contains("kan_edge_node_state"));
        validate(&text).unwrap();
    }

    #[test]
    fn rollout_section_gets_model_labels() {
        let root = obj(vec![(
            "rollout",
            obj(vec![(
                "mnist",
                obj(vec![
                    ("phase_code", Value::Int(0)),
                    ("fraction", Value::Float(0.25)),
                    ("flip_rate", Value::Float(0.0)),
                ]),
            )]),
        )]);
        let text = render(&root);
        assert!(text.contains("kan_edge_rollout_phase_code{model=\"mnist\"} 0\n"));
        assert!(text.contains("kan_edge_rollout_fraction{model=\"mnist\"} 0.25\n"));
        assert!(text.contains("kan_edge_rollout_flip_rate{model=\"mnist\"} 0\n"));
        validate(&text).unwrap();
    }

    #[test]
    fn validator_accepts_good_and_rejects_bad() {
        validate("# TYPE x gauge\nx 1\nx{a=\"b\",c=\"d\"} 2.5\nx NaN\nx -Inf\n").unwrap();
        assert!(validate("1bad 2\n").is_err());
        assert!(validate("x{a=b} 2\n").is_err());
        assert!(validate("x{a=\"b} 2\n").is_err());
        assert!(validate("x 1 trailing\n").is_err());
        assert!(validate("x\n").is_err());
        assert!(validate("#bad comment\n").is_err());
    }

    #[test]
    fn label_values_escape() {
        let root = obj(vec![(
            "models",
            obj(vec![("m\"odel", obj(vec![("requests", Value::Int(1))]))]),
        )]);
        let text = render(&root);
        assert!(text.contains("{model=\"m\\\"odel\"} 1\n"));
        validate(&text).unwrap();
    }
}
