//! Leveled structured logger: one JSON object per line on stderr.
//!
//! Replaces the ad-hoc `eprintln!` warning sites scattered through the
//! library. The CLI's own usage/exit messages in `main.rs` stay plain
//! `eprintln!` — they are user-facing terminal output, not telemetry.
//!
//! The level lives in a global atomic so checking it costs one relaxed
//! load; a disabled line allocates nothing. Output is a single `write_all`
//! of a preformatted line, so concurrent threads cannot interleave
//! mid-record (stderr writes are atomic per call on the platforms we
//! target, and a torn line only garbles, never blocks).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::{obj, Value};

/// Log severity, ordered so that `level <= current` means "emit".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a level name (case-insensitive). Returns `None` for
    /// anything else so callers can produce their own error message.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level (config load and tests).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level.
pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether a record at `level` would be emitted right now.
pub fn enabled(level: Level) -> bool {
    level <= self::level()
}

/// Apply the `KAN_EDGE_LOG` environment variable if set and valid.
/// The env var wins over config so an operator can turn on `debug`
/// for one run without editing files. Returns the resulting level.
pub fn init_from_env() -> Level {
    if let Ok(v) = std::env::var("KAN_EDGE_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
    level()
}

/// Emit one structured record: `{"level":..,"msg":..,"target":..,"ts_ms":..}`
/// plus any extra fields. Fields with keys colliding with the built-ins
/// are overridden by the built-ins (BTreeMap insert order).
pub fn log_kv(level: Level, target: &str, msg: &str, fields: Vec<(&str, Value)>) {
    if !enabled(level) {
        return;
    }
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as i64)
        .unwrap_or(0);
    let mut all = fields;
    all.push(("level", Value::Str(level.as_str().into())));
    all.push(("msg", Value::Str(msg.into())));
    all.push(("target", Value::Str(target.into())));
    all.push(("ts_ms", Value::Int(ts_ms)));
    let line = obj(all).to_string();
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(line.as_bytes());
    let _ = err.write_all(b"\n");
}

/// Error-level record with no extra fields.
pub fn error(target: &str, msg: &str) {
    log_kv(Level::Error, target, msg, Vec::new());
}

/// Warn-level record with no extra fields.
pub fn warn(target: &str, msg: &str) {
    log_kv(Level::Warn, target, msg, Vec::new());
}

/// Info-level record with no extra fields.
pub fn info(target: &str, msg: &str) {
    log_kv(Level::Info, target, msg, Vec::new());
}

/// Debug-level record with no extra fields.
pub fn debug(target: &str, msg: &str) {
    log_kv(Level::Debug, target, msg, Vec::new());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_ordering() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn enabled_respects_level() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(prev);
    }
}
