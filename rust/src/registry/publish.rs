//! Publishing: weights file → content-addressed store + manifest update.
//!
//! `publish_into` is the pure core shared by `kan-edge publish` and
//! [`super::ModelRegistry::publish_file`]: it validates the checkpoint,
//! ingests it into the [`ArtifactStore`], derives the v2 metadata
//! (version bump, digest, quant spec, accuracy, NeuroSim hardware cost)
//! and mutates the in-memory manifest. The caller decides when to
//! `save()` — the registry does it under its lock so a concurrent
//! hot-reload poll never sees a half-published state.

use std::collections::HashMap;
use std::path::Path;

use super::manifest::{HwCost, ModelManifest, ModelMeta, QuantSpec};
use super::store::ArtifactStore;
use crate::circuits::Tech;
use crate::error::{Error, Result};
use crate::kan::checkpoint::{KanCheckpoint, MlpCheckpoint, ModelEntry};
use crate::neurosim::{estimate_kan, KanArch};
use crate::util::json::Value;

/// Validate + ingest `weights`, then record it in `manifest` as a new
/// version of the model. Returns the published (name, meta).
pub fn publish_into(
    manifest: &mut ModelManifest,
    store: &ArtifactStore,
    artifacts_dir: &Path,
    weights: &Path,
    name_override: Option<&str>,
    version_override: Option<u32>,
) -> Result<(String, ModelMeta)> {
    let text = std::fs::read_to_string(weights).map_err(|e| {
        Error::Registry(format!("cannot read {}: {e}", weights.display()))
    })?;
    let kind = Value::parse(&text)
        .map_err(|e| Error::Registry(format!("{}: {e}", weights.display())))?
        .req_str("kind")?
        .to_string();

    // strict checkpoint validation + metadata extraction per kind
    let (ckpt_name, dims, num_params, quant, accuracy, entry_accs) = match kind.as_str() {
        "kan" => {
            let c = KanCheckpoint::load(weights)?;
            let quant = QuantSpec { g: c.g, k: c.k, n_bits: c.n_bits };
            let acc = c.quant_test_acc.or(c.float_test_acc);
            (
                c.name.clone(),
                c.dims.clone(),
                c.num_params,
                Some(quant),
                acc,
                (c.float_test_acc, c.quant_test_acc, None),
            )
        }
        "mlp" => {
            let c = MlpCheckpoint::load(weights)?;
            (
                c.name.clone(),
                c.dims.clone(),
                c.num_params,
                None,
                c.test_acc,
                (None, None, c.test_acc),
            )
        }
        other => {
            return Err(Error::Registry(format!(
                "cannot publish {}: unknown checkpoint kind '{other}' (kan | mlp)",
                weights.display()
            )))
        }
    };
    let name = name_override.unwrap_or(&ckpt_name).to_string();
    if name.is_empty() || name.contains('@') {
        return Err(Error::Registry(format!(
            "invalid model name '{name}': must be non-empty and free of '@'"
        )));
    }

    let stored = store.put_file(weights)?;
    let rel_weights = store.rel_path_of(&stored.digest, artifacts_dir)?;

    let prev_version = manifest
        .base
        .models
        .contains_key(&name)
        .then(|| manifest.meta_for(&name).version);
    let version = match version_override {
        Some(0) => {
            // version 0 would be rejected by the manifest parser on the
            // next load, bricking the registry file
            return Err(Error::Registry(format!(
                "model '{name}': version must be >= 1"
            )));
        }
        Some(v) => {
            if let Some(prev) = prev_version {
                if v <= prev {
                    return Err(Error::Registry(format!(
                        "model '{name}' is already at version {prev}; \
                         new version must be greater (got {v})"
                    )));
                }
            }
            v
        }
        None => prev_version.map(|v| v + 1).unwrap_or(1),
    };

    // hardware cost from the NeuroSim analytic model (KAN variants only);
    // 22 nm default technology, same as `kan-edge cost`
    let hw_cost = quant.and_then(|q| {
        estimate_kan(&KanArch::new(dims.clone(), q.g), &Tech::default())
            .ok()
            .map(|r| HwCost {
                area_mm2: r.area_mm2,
                energy_pj: r.energy_pj,
                latency_ns: r.latency_ns,
            })
    });

    let (float_test_acc, quant_test_acc, test_acc) = entry_accs;
    let entry = ModelEntry {
        kind,
        dims,
        g: quant.map(|q| q.g),
        k: quant.map(|q| q.k),
        num_params,
        val_acc: accuracy.unwrap_or(0.0),
        float_test_acc,
        quant_test_acc,
        test_acc,
        weights: rel_weights,
        hlo: HashMap::new(),
    };
    let meta = ModelMeta {
        version,
        digest: Some(stored.digest),
        quant,
        accuracy,
        hw_cost,
    };

    manifest.schema_version = 2;
    manifest.base.models.insert(name.clone(), entry);
    manifest.meta.insert(name.clone(), meta.clone());
    Ok((name, meta))
}
