//! Content-addressed artifact store.
//!
//! Weights / HLO blobs live under `<root>/objects/<digest-hex>`, keyed by
//! their FNV-1a 64 content digest (see [`super::digest`]). Properties the
//! registry relies on:
//!
//! * **Idempotent publish** — re-publishing identical bytes lands on the
//!   same object; nothing is duplicated or overwritten mid-read (writes
//!   go to a tmp file then `rename`, which is atomic on POSIX).
//! * **Integrity on load** — [`ArtifactStore::open_verified`] re-hashes
//!   the object and fails loudly on digest mismatch (bit-rot, truncated
//!   copy, manual tampering) instead of serving a corrupt model.

use std::path::{Path, PathBuf};

use super::digest;
use crate::error::{Error, Result};

/// Handle to one stored object.
#[derive(Debug, Clone)]
pub struct StoredArtifact {
    pub digest: String,
    pub path: PathBuf,
}

/// A directory of content-addressed artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(root.join("objects"))?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn object_path(&self, hex: &str) -> PathBuf {
        self.root.join("objects").join(hex)
    }

    /// Absolute path an object with `digest` would live at (validated,
    /// not checked for existence).
    pub fn path_of(&self, digest_str: &str) -> Result<PathBuf> {
        Ok(self.object_path(digest::parse(digest_str)?))
    }

    /// Store path relative to `base` (what gets written into manifests).
    pub fn rel_path_of(&self, digest_str: &str, base: &Path) -> Result<String> {
        let abs = self.path_of(digest_str)?;
        let rel = abs.strip_prefix(base).unwrap_or(&abs);
        Ok(rel.to_string_lossy().into_owned())
    }

    pub fn contains(&self, digest_str: &str) -> bool {
        self.path_of(digest_str).map(|p| p.exists()).unwrap_or(false)
    }

    /// Ingest a byte buffer; no-op (returning the existing object) when
    /// the content is already stored.
    pub fn put_bytes(&self, bytes: &[u8]) -> Result<StoredArtifact> {
        let digest_str = digest::digest_bytes(bytes);
        let path = self.path_of(&digest_str)?;
        if !path.exists() {
            let tmp = path.with_extension("tmp");
            std::fs::write(&tmp, bytes)?;
            std::fs::rename(&tmp, &path)?;
        }
        Ok(StoredArtifact { digest: digest_str, path })
    }

    /// Ingest a file from anywhere on disk.
    pub fn put_file(&self, src: impl AsRef<Path>) -> Result<StoredArtifact> {
        let src = src.as_ref();
        let bytes = std::fs::read(src).map_err(|e| {
            Error::Registry(format!("cannot read {}: {e}", src.display()))
        })?;
        self.put_bytes(&bytes)
    }

    /// Resolve an object and verify its content still matches the digest.
    pub fn open_verified(&self, digest_str: &str) -> Result<PathBuf> {
        let path = self.path_of(digest_str)?;
        if !path.exists() {
            return Err(Error::Registry(format!(
                "artifact {digest_str} not in store at {}",
                self.root.display()
            )));
        }
        verify_file(&path, digest_str)?;
        Ok(path)
    }

    /// All digests currently stored.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(self.root.join("objects"))? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.len() == 16 && name.bytes().all(|b| b.is_ascii_hexdigit()) {
                out.push(format!("{}{name}", digest::FNV64_PREFIX));
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Lowercase hex encoding of a byte buffer — how artifact payloads ride
/// the v2 wire on `pull_artifact` / `push_artifact` (the hand-rolled
/// JSON layer has no binary type, and hex survives every JSON transport
/// unescaped).
pub fn encode_hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Inverse of [`encode_hex`]; rejects odd lengths and non-hex digits.
pub fn decode_hex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Registry(format!(
            "hex payload has odd length {}",
            s.len()
        )));
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    let nibble = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            other => Err(Error::Registry(format!(
                "invalid hex digit '{}' in payload",
                other as char
            ))),
        }
    };
    for pair in bytes.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

/// Check that `path`'s content hashes to `expected` (used both by the
/// store and by the registry when validating manifest-declared digests
/// against weights files living outside the store).
pub fn verify_file(path: &Path, expected: &str) -> Result<()> {
    digest::parse(expected)?;
    let actual = digest::digest_file(path)?;
    if actual != expected {
        return Err(Error::Registry(format!(
            "digest mismatch for {}: manifest says {expected}, file is {actual} \
             (artifact corrupted or overwritten?)",
            path.display()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> ArtifactStore {
        let dir = std::env::temp_dir().join("kan_edge_store_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        ArtifactStore::open(&dir).unwrap()
    }

    #[test]
    fn put_get_roundtrip() {
        let store = tmp_store("roundtrip");
        let a = store.put_bytes(b"weights-v1").unwrap();
        assert!(store.contains(&a.digest));
        let path = store.open_verified(&a.digest).unwrap();
        assert_eq!(std::fs::read(path).unwrap(), b"weights-v1");
        // idempotent re-put
        let b = store.put_bytes(b"weights-v1").unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(store.list().unwrap(), vec![a.digest]);
    }

    #[test]
    fn corruption_detected_on_load() {
        let store = tmp_store("corrupt");
        let a = store.put_bytes(b"good bytes").unwrap();
        std::fs::write(&a.path, b"evil bytes").unwrap();
        let err = store.open_verified(&a.digest).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn hex_roundtrip_and_rejection() {
        let data: Vec<u8> = (0..=255u8).collect();
        let hex = encode_hex(&data);
        assert_eq!(decode_hex(&hex).unwrap(), data);
        assert_eq!(encode_hex(&[0x00, 0xff, 0x0a]), "00ff0a");
        assert_eq!(decode_hex("00FF0A").unwrap(), vec![0x00, 0xff, 0x0a]);
        assert!(decode_hex("abc").is_err()); // odd length
        assert!(decode_hex("zz").is_err()); // bad digit
        assert!(decode_hex("").unwrap().is_empty());
    }

    #[test]
    fn missing_object_is_clear_error() {
        let store = tmp_store("missing");
        let err = store
            .open_verified("fnv64:00000000000000aa")
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("not in store"), "{err}");
    }
}
