//! Versioned model-manifest schema.
//!
//! Two wire schemas are supported, tagged by a top-level
//! `schema_version` field (trow-style: the tag selects a strict parser,
//! unknown tags are hard errors, never best-effort):
//!
//! * **v1** — today's flat `manifest.json` written by
//!   `python/compile/aot.py` (no `schema_version` field, or `1`). Every
//!   model implicitly has `version: 1` and no digest.
//! * **v2** — v1 plus a required per-model `meta` object carrying the
//!   registry metadata: monotonically increasing `version`, the
//!   content digest of the weights artifact, the quantization spec the
//!   variant was built with, its accuracy, and the hardware cost from
//!   the NeuroSim co-search. Written by `kan-edge publish`.
//!
//! ```text
//! {"schema_version": 2, "format": 1, ..., "models": {
//!    "kan1": {"kind": "kan", ..., "meta": {
//!       "version": 3, "digest": "fnv64:8a1f...",
//!       "quant": {"g": 5, "k": 3, "n_bits": 8},
//!       "accuracy": 0.8612,
//!       "hw_cost": {"area_mm2": 0.021, "energy_pj": 94.0, "latency_ns": 310.0}}}}}
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::kan::checkpoint::{read_json, Manifest};
use crate::util::json::{obj, Value};

/// Schema versions this build can parse.
pub const SUPPORTED_SCHEMAS: &[u32] = &[1, 2];

/// Quantization point a variant was built at (paper §3.1 geometry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
}

/// Hardware cost of a variant, from the NeuroSim co-search (§3.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwCost {
    pub area_mm2: f64,
    pub energy_pj: f64,
    pub latency_ns: f64,
}

/// Per-model registry metadata (schema v2).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Monotonic publish version; serving ids are `name@version`.
    pub version: u32,
    /// Expected content digest of the weights artifact.
    pub digest: Option<String>,
    pub quant: Option<QuantSpec>,
    pub accuracy: Option<f64>,
    pub hw_cost: Option<HwCost>,
}

impl Default for ModelMeta {
    fn default() -> Self {
        Self { version: 1, digest: None, quant: None, accuracy: None, hw_cost: None }
    }
}

impl ModelMeta {
    fn from_json(model: &str, v: &Value) -> Result<Self> {
        let version = v.req_usize("version").map_err(|e| {
            Error::Registry(format!("model '{model}' meta: {e}"))
        })? as u32;
        if version == 0 {
            return Err(Error::Registry(format!(
                "model '{model}' meta: version must be >= 1"
            )));
        }
        let digest = match v.get("digest") {
            None => None,
            Some(d) => Some(
                d.as_str()
                    .ok_or_else(|| {
                        Error::Registry(format!(
                            "model '{model}' meta: 'digest' is not a string"
                        ))
                    })?
                    .to_string(),
            ),
        };
        let quant = match v.get("quant") {
            None => None,
            Some(q) => Some(QuantSpec {
                g: q.req_usize("g")? as u32,
                k: q.req_usize("k")? as u32,
                n_bits: q.req_usize("n_bits")? as u32,
            }),
        };
        let hw_cost = match v.get("hw_cost") {
            None => None,
            Some(h) => Some(HwCost {
                area_mm2: h.req_f64("area_mm2")?,
                energy_pj: h.req_f64("energy_pj")?,
                latency_ns: h.req_f64("latency_ns")?,
            }),
        };
        Ok(Self {
            version,
            digest,
            quant,
            accuracy: v.get("accuracy").and_then(|x| x.as_f64()),
            hw_cost,
        })
    }

    fn to_value(&self) -> Value {
        let mut fields = vec![("version", (self.version as usize).into())];
        if let Some(d) = &self.digest {
            fields.push(("digest", d.as_str().into()));
        }
        if let Some(q) = &self.quant {
            fields.push((
                "quant",
                obj(vec![
                    ("g", (q.g as usize).into()),
                    ("k", (q.k as usize).into()),
                    ("n_bits", (q.n_bits as usize).into()),
                ]),
            ));
        }
        if let Some(a) = self.accuracy {
            fields.push(("accuracy", a.into()));
        }
        if let Some(h) = &self.hw_cost {
            fields.push((
                "hw_cost",
                obj(vec![
                    ("area_mm2", h.area_mm2.into()),
                    ("energy_pj", h.energy_pj.into()),
                    ("latency_ns", h.latency_ns.into()),
                ]),
            ));
        }
        obj(fields)
    }
}

/// A parsed, schema-tagged manifest: the flat v1 base plus (for v2) the
/// per-model registry metadata.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub schema_version: u32,
    pub base: Manifest,
    pub meta: BTreeMap<String, ModelMeta>,
}

impl ModelManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let v = read_json(&dir.as_ref().join("manifest.json"))?;
        Self::from_value(&v)
    }

    /// Strict schema-tagged parse. Missing `schema_version` means v1
    /// (backwards compatibility with aot.py output); anything not in
    /// [`SUPPORTED_SCHEMAS`] is rejected outright.
    pub fn from_value(v: &Value) -> Result<Self> {
        let schema_version = match v.get("schema_version") {
            None => 1,
            Some(tag) => tag.as_usize().ok_or_else(|| {
                Error::Registry("'schema_version' must be a non-negative integer".into())
            })? as u32,
        };
        if !SUPPORTED_SCHEMAS.contains(&schema_version) {
            return Err(Error::Registry(format!(
                "unsupported manifest schema_version {schema_version} \
                 (this build supports: {SUPPORTED_SCHEMAS:?})"
            )));
        }
        let base = Manifest::from_value(v)?;
        let mut meta = BTreeMap::new();
        if schema_version >= 2 {
            let models = v
                .field("models")?
                .as_object()
                .ok_or_else(|| Error::Json("'models' is not an object".into()))?;
            for (name, m) in models {
                let mv = m.get("meta").ok_or_else(|| {
                    Error::Registry(format!(
                        "schema v2 requires a 'meta' object on model '{name}'"
                    ))
                })?;
                meta.insert(name.clone(), ModelMeta::from_json(name, mv)?);
            }
        } else {
            for name in base.models.keys() {
                meta.insert(name.clone(), ModelMeta::default());
            }
        }
        Ok(Self { schema_version, base, meta })
    }

    /// Serialize; v2 documents carry `schema_version` + per-model `meta`.
    pub fn to_value(&self) -> Value {
        let mut v = self.base.to_value();
        if self.schema_version < 2 {
            return v;
        }
        if let Value::Object(top) = &mut v {
            top.insert("schema_version".into(), (self.schema_version as usize).into());
            if let Some(Value::Object(models)) = top.get_mut("models") {
                for (name, entry) in models.iter_mut() {
                    let meta = self.meta.get(name).cloned().unwrap_or_default();
                    if let Value::Object(e) = entry {
                        e.insert("meta".into(), meta.to_value());
                    }
                }
            }
        }
        v
    }

    /// Write `manifest.json` atomically (tmp file + rename) so a serving
    /// registry polling the file never observes a half-written document.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join("manifest.json.tmp");
        let dst = dir.join("manifest.json");
        std::fs::write(&tmp, self.to_value().to_string())?;
        std::fs::rename(&tmp, &dst)?;
        Ok(())
    }

    /// Metadata for `name` (default v1 meta when absent).
    pub fn meta_for(&self, name: &str) -> ModelMeta {
        self.meta.get(name).cloned().unwrap_or_default()
    }

    /// A minimal empty v2 manifest, used by `kan-edge publish` when
    /// starting a registry in a fresh directory.
    pub fn empty() -> Self {
        use crate::kan::checkpoint::DatasetMeta;
        Self {
            schema_version: 2,
            base: Manifest {
                format: 1,
                seed: 0,
                dataset: DatasetMeta {
                    num_features: 0,
                    num_classes: 0,
                    train: 0,
                    val: 0,
                    test: 0,
                },
                models: std::collections::HashMap::new(),
                sweep: Vec::new(),
                batch_sizes: Vec::new(),
                build_seconds: None,
            },
            meta: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v1_doc() -> String {
        r#"{"format":1,"seed":7,
            "dataset":{"num_features":2,"num_classes":2,"train":10,"val":5,"test":5},
            "models":{"a":{"kind":"kan","dims":[2,2],"g":1,"k":1,"num_params":8,
                           "val_acc":0.9,"weights":"a.weights.json"}},
            "sweep":[],"batch_sizes":[1,8]}"#
            .to_string()
    }

    #[test]
    fn v1_parses_with_default_meta() {
        let m = ModelManifest::from_value(&Value::parse(&v1_doc()).unwrap()).unwrap();
        assert_eq!(m.schema_version, 1);
        assert_eq!(m.meta_for("a").version, 1);
        assert!(m.meta_for("a").digest.is_none());
    }

    #[test]
    fn v2_roundtrips() {
        let mut m = ModelManifest::from_value(&Value::parse(&v1_doc()).unwrap()).unwrap();
        m.schema_version = 2;
        m.meta.insert(
            "a".into(),
            ModelMeta {
                version: 3,
                digest: Some("fnv64:0123456789abcdef".into()),
                quant: Some(QuantSpec { g: 1, k: 1, n_bits: 8 }),
                accuracy: Some(0.91),
                hw_cost: Some(HwCost {
                    area_mm2: 0.02,
                    energy_pj: 100.0,
                    latency_ns: 300.0,
                }),
            },
        );
        let text = m.to_value().to_string();
        let re = ModelManifest::from_value(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(re.schema_version, 2);
        let meta = re.meta_for("a");
        assert_eq!(meta.version, 3);
        assert_eq!(meta.digest.as_deref(), Some("fnv64:0123456789abcdef"));
        assert_eq!(meta.quant, Some(QuantSpec { g: 1, k: 1, n_bits: 8 }));
        assert_eq!(meta.hw_cost.unwrap().energy_pj, 100.0);
        assert_eq!(re.base.models["a"].dims, vec![2, 2]);
    }

    #[test]
    fn unknown_schema_version_rejected() {
        let doc = v1_doc().replacen("{", r#"{"schema_version":99,"#, 1);
        let err = ModelManifest::from_value(&Value::parse(&doc).unwrap())
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("99") && err.contains("supports"), "{err}");
    }

    #[test]
    fn v2_without_meta_rejected() {
        let doc = v1_doc().replacen("{", r#"{"schema_version":2,"#, 1);
        let err = ModelManifest::from_value(&Value::parse(&doc).unwrap())
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("meta"), "{err}");
    }

    #[test]
    fn non_integer_schema_version_rejected() {
        let doc = v1_doc().replacen("{", r#"{"schema_version":"two","#, 1);
        assert!(ModelManifest::from_value(&Value::parse(&doc).unwrap()).is_err());
    }
}
