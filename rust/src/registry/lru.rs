//! A tiny LRU recency list used to bound the number of live (compiled /
//! loaded) backends. At registry scale (a handful of variants) an O(n)
//! `Vec` beats a linked-hash-map in both code size and constant factor.

/// LRU recency tracker over keys; front of the list = most recent.
#[derive(Debug, Clone)]
pub struct Lru<K: PartialEq + Clone> {
    cap: usize,
    order: Vec<K>,
}

impl<K: PartialEq + Clone> Lru<K> {
    /// `cap` is the max number of tracked keys; inserting beyond it
    /// reports the evicted (least-recent) key.
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), order: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn contains(&self, k: &K) -> bool {
        self.order.contains(k)
    }

    /// Mark `k` as most recently used (no-op if untracked).
    pub fn touch(&mut self, k: &K) {
        if let Some(pos) = self.order.iter().position(|x| x == k) {
            let key = self.order.remove(pos);
            self.order.insert(0, key);
        }
    }

    /// Insert (or touch) `k`; returns the evicted key when capacity is
    /// exceeded. The just-inserted key is never the one evicted.
    pub fn insert(&mut self, k: K) -> Option<K> {
        if let Some(pos) = self.order.iter().position(|x| x == &k) {
            let key = self.order.remove(pos);
            self.order.insert(0, key);
            return None;
        }
        self.order.insert(0, k);
        if self.order.len() > self.cap {
            self.order.pop()
        } else {
            None
        }
    }

    /// Like [`Lru::insert`], but only keys satisfying `is_evictable`
    /// may be chosen as the victim: the scan walks from least-recent
    /// toward most-recent and skips protected (pinned) keys. When every
    /// over-capacity candidate is protected the list is allowed to run
    /// over capacity — pinning is a guarantee, not a suggestion. The
    /// just-inserted key is never the one evicted.
    pub fn insert_with(
        &mut self,
        k: K,
        is_evictable: impl Fn(&K) -> bool,
    ) -> Option<K> {
        if let Some(pos) = self.order.iter().position(|x| x == &k) {
            let key = self.order.remove(pos);
            self.order.insert(0, key);
            return None;
        }
        self.order.insert(0, k);
        if self.order.len() > self.cap {
            // least-recent first; index 0 is the key just inserted
            for pos in (1..self.order.len()).rev() {
                if is_evictable(&self.order[pos]) {
                    return Some(self.order.remove(pos));
                }
            }
        }
        None
    }

    pub fn remove(&mut self, k: &K) {
        self.order.retain(|x| x != k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let mut lru = Lru::new(2);
        assert_eq!(lru.insert("a"), None);
        assert_eq!(lru.insert("b"), None);
        lru.touch(&"a"); // order now a, b
        assert_eq!(lru.insert("c"), Some("b"));
        assert!(lru.contains(&"a") && lru.contains(&"c"));
    }

    #[test]
    fn reinsert_touches_instead_of_evicting() {
        let mut lru = Lru::new(2);
        lru.insert(1);
        lru.insert(2);
        assert_eq!(lru.insert(1), None); // already tracked
        assert_eq!(lru.insert(3), Some(2));
    }

    #[test]
    fn insert_with_skips_pinned_victims() {
        let mut lru = Lru::new(2);
        lru.insert("pinned");
        lru.insert("a"); // order: a, pinned
        // the least-recent key is protected, so the next-oldest goes
        assert_eq!(lru.insert_with("b", |k| *k != "pinned"), Some("a"));
        assert!(lru.contains(&"pinned") && lru.contains(&"b"));
        // everything protected: runs over capacity instead of evicting
        assert_eq!(lru.insert_with("c", |_| false), None);
        assert_eq!(lru.len(), 3);
        assert!(lru.contains(&"pinned") && lru.contains(&"b") && lru.contains(&"c"));
        // re-inserting a tracked key is a touch, never an eviction
        assert_eq!(lru.insert_with("pinned", |_| true), None);
        assert_eq!(lru.len(), 3);
    }

    #[test]
    fn remove_untracks() {
        let mut lru = Lru::new(3);
        lru.insert("x");
        lru.remove(&"x");
        assert!(lru.is_empty());
        assert_eq!(lru.len(), 0);
    }
}
