//! Model registry & multi-model serving.
//!
//! The paper's co-search (§3.4) emits a *family* of KAN variants with
//! different G/K/LD points and area/energy/accuracy trade-offs; this
//! subsystem turns the serving stack from one-model-per-process into a
//! versioned, hot-reloadable registry:
//!
//! * [`manifest`] — the schema-tagged manifest (`schema_version` 1 = the
//!   flat aot.py output, 2 = per-model registry metadata: version,
//!   digest, quant spec, accuracy, NeuroSim hardware cost), with strict
//!   unknown-version rejection.
//! * [`store`] — a content-addressed [`ArtifactStore`]
//!   (`objects/<fnv64 digest>`): idempotent publish, integrity
//!   verification on load.
//! * [`digest`] — FNV-1a 64 content digests (`fnv64:<16 hex>`).
//! * [`lru`] — the recency tracker bounding live backends.
//! * [`registry`] — [`ModelRegistry`]: per-variant serving pipelines
//!   keyed `name@version`, lazy load + LRU eviction, atomic publish and
//!   mtime/digest-polled hot reload that never drops in-flight requests.
//! * [`publish`] — checkpoint validation + manifest mutation backing
//!   `kan-edge publish`.
//!
//! The TCP wire protocol reaches it through
//! [`Dispatch`](crate::coordinator::server::Dispatch): requests carry an
//! optional `"model"` field, responses echo the resolved `name@version`.

pub mod digest;
pub mod lru;
pub mod manifest;
pub mod publish;
#[allow(clippy::module_inception)]
pub mod registry;
pub mod store;

pub use digest::{digest_bytes, digest_file};
pub use manifest::{HwCost, ModelManifest, ModelMeta, QuantSpec};
pub use registry::{parse_model_spec, spawn_reload_thread, ModelInfo, ModelRegistry, ServedModel};
pub use store::{decode_hex, encode_hex, ArtifactStore, StoredArtifact};
