//! The live model registry: versioned variants → running backends.
//!
//! [`ModelRegistry`] owns one serving pipeline (dynamic batcher + worker
//! pool + metrics, i.e. an
//! [`InferenceService`](crate::coordinator::server::InferenceService)) per
//! live model, keyed by name with serving ids `name@version`. It
//! implements [`Dispatch`], so a single TCP endpoint routes per-request
//! (`{"model": "kan2", ...}`) across every published variant.
//!
//! Lifecycle guarantees:
//!
//! * **Lazy load + LRU** — backends are built on first request and
//!   bounded by `registry.max_loaded`; the least-recently-used variant
//!   is evicted (its worker pool drains and exits once in-flight
//!   requests complete — channel teardown, no force-kill).
//! * **Atomic publish / hot reload** — [`ModelRegistry::poll_reload`]
//!   re-stats `manifest.json` and each live variant's weights digest;
//!   a changed variant is rebuilt *outside* the registry lock and then
//!   swapped in with a single map write. Requests already admitted to
//!   the old pipeline finish against the old weights; new requests see
//!   the new version. Nothing is dropped.
//! * **Integrity** — when the manifest declares a digest (schema v2),
//!   the weights file is re-hashed before a backend is built; mismatch
//!   is a hard [`Error::Registry`].

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use super::digest;
use super::lru::Lru;
use super::manifest::{ModelManifest, ModelMeta};
use super::store::{verify_file, ArtifactStore};
use crate::util::sync::{LockExt, RwLockExt};
use crate::config::AppConfig;
use crate::coordinator::backend::{BackendKind, BackendSpec, RowOutput};
use crate::coordinator::metrics::{MetricsHub, MetricsReport};
use crate::coordinator::protocol::{BackendInfo, ModelSummary};
use crate::coordinator::router::{serve_options, BackendFactory};
use crate::coordinator::scheduler::ClientId;
use crate::coordinator::server::{Dispatch, InferenceService, RouteSpec};
use crate::coordinator::shadow::{ShadowExec, ShadowObservation, ShadowState};
use crate::error::{Error, Result};
use crate::rollout::{Rollout, RolloutPlane, Split, TickOutcome};
use crate::util::json::Value;

/// One live (servable) model version: the primary pipeline plus the
/// variant's *backend set* — lazily built pipelines for per-request
/// backend selection and the optional shadow mirror.
pub struct ServedModel {
    /// `name@version` serving id.
    pub id: String,
    pub name: String,
    pub version: u32,
    /// Content digest of the weights the backend was built from.
    pub digest: String,
    /// The primary backend's private batcher + worker pool.
    pub svc: InferenceService,
    /// Capability descriptor of the primary session.
    pub spec: BackendSpec,
    /// Shadow mirror sampling primary traffic off the response path.
    pub shadow: Option<Arc<ShadowState>>,
    /// The manifest snapshot this variant was built from. Per-request
    /// backend pipelines build against *this*, not the registry's
    /// current manifest — during a hot reload the in-memory manifest
    /// already points at the next version, and an extra pipeline built
    /// from it would serve new weights under the old `name@version`.
    manifest: crate::kan::checkpoint::Manifest,
    /// Pipelines for per-request backend selection, built on first
    /// request for each kind. Each gets its own batcher + worker pool,
    /// so batches stay keyed by `(model, backend)` and mixed traffic on
    /// one connection batches correctly per backend.
    extra: Mutex<BTreeMap<BackendKind, InferenceService>>,
}

/// CLI-facing summary of one registered model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub meta: ModelMeta,
    pub kind: String,
    pub dims: Vec<usize>,
    pub num_params: usize,
    pub weights: String,
    pub live: bool,
}

struct Inner {
    manifest: ModelManifest,
    live: BTreeMap<String, Arc<ServedModel>>,
}

/// Multi-model serving registry (see module docs).
pub struct ModelRegistry {
    cfg: AppConfig,
    dir: PathBuf,
    store: ArtifactStore,
    hub: MetricsHub,
    /// Session compiler shared across variants: its calibration-
    /// occupancy cache makes hot reloads and mirror builds of unchanged
    /// weights skip recalibration.
    factory: BackendFactory,
    inner: RwLock<Inner>,
    lru: Mutex<Lru<String>>,
    /// Names protected from LRU eviction ([`ModelRegistry::pin`]):
    /// replication targets and canary-rollback fallbacks must not have
    /// their pipeline evicted mid-flight.
    pinned: Mutex<BTreeSet<String>>,
    /// The previously-live pipeline per name, retained warm at hot-swap
    /// time. The manifest keeps only the current version, so this shelf
    /// is the only place the old version's running backend survives —
    /// it is what a rollout's instant rollback repoints to.
    standby: Mutex<BTreeMap<String, Arc<ServedModel>>>,
    /// Staged canary deployments ([`crate::rollout`]).
    rollouts: RolloutPlane,
    /// Names this registry pinned *on behalf of a rollout* (so terminal
    /// cleanup unpins exactly those, never an operator's own pin).
    rollout_pins: Mutex<BTreeSet<String>>,
    /// Self-reference for spawning rollout driver threads (weak: a
    /// driver must not keep a dropped registry alive).
    self_weak: Mutex<Weak<ModelRegistry>>,
}

/// Split `"name@version"` into its parts; plain `"name"` pins nothing.
pub fn parse_model_spec(spec: &str) -> Result<(&str, Option<u32>)> {
    match spec.split_once('@') {
        None => Ok((spec, None)),
        Some((name, ver)) => {
            let v: u32 = ver.parse().map_err(|_| {
                Error::Registry(format!(
                    "bad model spec '{spec}': version after '@' must be an integer"
                ))
            })?;
            Ok((name, Some(v)))
        }
    }
}

impl ModelRegistry {
    /// Open the registry over `cfg.artifacts.dir` (manifest parsed, no
    /// backends built yet).
    pub fn open(cfg: &AppConfig) -> Result<Arc<Self>> {
        let dir = PathBuf::from(&cfg.artifacts.dir);
        let manifest = ModelManifest::load(&dir)?;
        let store = ArtifactStore::open(dir.join(&cfg.registry.store_dir))?;
        let reg = Arc::new(Self {
            cfg: cfg.clone(),
            dir,
            store,
            hub: MetricsHub::new(),
            factory: BackendFactory::new(cfg),
            inner: RwLock::new(Inner { manifest, live: BTreeMap::new() }),
            lru: Mutex::new(Lru::new(cfg.registry.max_loaded)),
            pinned: Mutex::new(BTreeSet::new()),
            standby: Mutex::new(BTreeMap::new()),
            rollouts: RolloutPlane::new(cfg.rollout.clone()),
            rollout_pins: Mutex::new(BTreeSet::new()),
            self_weak: Mutex::new(Weak::new()),
        });
        *reg.self_weak.lock_recover() = Arc::downgrade(&reg);
        Ok(reg)
    }

    /// The session factory (test hook: its occupancy cache proves the
    /// calibrate-once contract).
    pub fn factory(&self) -> &BackendFactory {
        &self.factory
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Names registered in the manifest (not necessarily live).
    pub fn model_names(&self) -> Vec<String> {
        let g = self.inner.read_recover();
        let mut names: Vec<String> = g.manifest.base.models.keys().cloned().collect();
        names.sort();
        names
    }

    /// Summaries for `kan-edge models`.
    pub fn models(&self) -> Vec<ModelInfo> {
        let g = self.inner.read_recover();
        let mut out: Vec<ModelInfo> = g
            .manifest
            .base
            .models
            .iter()
            .map(|(name, e)| ModelInfo {
                name: name.clone(),
                meta: g.manifest.meta_for(name),
                kind: e.kind.clone(),
                dims: e.dims.clone(),
                num_params: e.num_params,
                weights: e.weights.clone(),
                live: g.live.contains_key(name),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Per-model metrics reports (includes retired versions). Live
    /// models running a shadow mirror get their divergence report
    /// attached under `shadow`.
    pub fn metrics(&self) -> Vec<(String, MetricsReport)> {
        let mut reports = self.hub.reports();
        let live: BTreeMap<String, Arc<ServedModel>> = {
            let g = self.inner.read_recover();
            g.live.values().map(|s| (s.id.clone(), s.clone())).collect()
        };
        for (id, report) in reports.iter_mut() {
            if let Some(s) = live.get(id) {
                if let Some(sh) = &s.shadow {
                    report.shadow = Some(sh.metrics.report());
                }
                // live scheduler gauges + engine profile come from the
                // primary pipeline; retired versions keep plain counters
                let g = s.svc.queue_gauges();
                report.queue_depth = Some(g.depth);
                report.queue_clients = Some(g.clients);
                report.max_client_backlog = Some(g.max_client_backlog);
                report.engine_profile = s.svc.session().profile();
            }
        }
        // staged rollouts attach their numeric summary to the candidate
        // version's report (decision history stays on `rollout_status`)
        for ro in self.rollouts.all() {
            for r in reports.iter_mut() {
                if r.0 == ro.candidate_id {
                    r.1.rollout = Some(ro.prom_value());
                }
            }
        }
        reports
    }

    /// Exact rollup across all models and versions.
    pub fn aggregate_metrics(&self) -> MetricsReport {
        self.hub.aggregate()
    }

    /// Build a serving pipeline for `name` from the current manifest.
    /// Slow (reads weights, may compile); called with no locks held.
    fn build_served(&self, name: &str) -> Result<Arc<ServedModel>> {
        let (manifest, meta) = {
            let g = self.inner.read_recover();
            if !g.manifest.base.models.contains_key(name) {
                // (names computed inline: taking the lock again here
                // would be a re-entrant read on this RwLock)
                let mut names: Vec<&String> = g.manifest.base.models.keys().collect();
                names.sort();
                return Err(Error::Registry(format!(
                    "model '{name}' not in manifest (have: {names:?})"
                )));
            }
            (g.manifest.base.clone(), g.manifest.meta_for(name))
        };
        let entry = &manifest.models[name];
        let weights_path = self.dir.join(&entry.weights);
        // integrity: verify the manifest-declared digest, else record the
        // current content digest for hot-reload change detection
        let file_digest = match &meta.digest {
            Some(expected) => {
                verify_file(&weights_path, expected)?;
                expected.clone()
            }
            None => digest::digest_file(&weights_path)?,
        };
        let session = self.factory.build(&manifest, name, self.cfg.server.backend)?;
        let spec = session.spec();
        // cross-check backend output shape against the manifest entry
        let declared_out = *entry.dims.last().unwrap_or(&0);
        if spec.output_dim != declared_out {
            return Err(Error::Shape(format!(
                "model '{name}': weights produce {} outputs but manifest dims \
                 end in {declared_out}",
                spec.output_dim
            )));
        }
        let id = format!("{name}@{}", meta.version);
        let svc = InferenceService::start_with_metrics(
            session,
            serve_options(&self.cfg),
            self.hub.for_model(&id),
        );
        // optional shadow mirror: a build failure (e.g. a kind this
        // artifact cannot back) degrades to primary-only serving with a
        // warning — shadow observability must never take a model down
        let shadow = match self.cfg.server.shadow.backend {
            Some(kind) if kind != spec.kind => {
                match self.factory.build_shadow_exec(&manifest, name, kind) {
                    Ok(exec) => Some(ShadowState::spawn(
                        kind,
                        self.cfg.server.shadow.fraction,
                        self.cfg.server.shadow.queue,
                        exec,
                    )),
                    Err(e) => {
                        crate::obs::log::warn(
                            "registry",
                            &format!(
                                "shadow '{kind}' for '{id}' failed to build \
                                 ({e}); serving without a mirror"
                            ),
                        );
                        None
                    }
                }
            }
            _ => None,
        };
        Ok(Arc::new(ServedModel {
            id,
            name: name.to_string(),
            version: meta.version,
            digest: file_digest,
            svc,
            spec,
            shadow,
            manifest,
            extra: Mutex::new(BTreeMap::new()),
        }))
    }

    /// The pipeline executing `backend` for `served`: the primary when
    /// `backend` is `None` or names the primary's kind, else a
    /// per-kind pipeline from the variant's backend set, built on first
    /// use (its session comes from the shared factory, so e.g. an ACIM
    /// mirror reuses cached calibration occupancy).
    fn service_for(
        &self,
        served: &Arc<ServedModel>,
        backend: Option<BackendKind>,
    ) -> Result<InferenceService> {
        let kind = match backend {
            None => return Ok(served.svc.clone()),
            Some(k) if k == served.spec.kind => return Ok(served.svc.clone()),
            Some(k) => k,
        };
        if let Some(svc) = served.extra.lock_recover().get(&kind) {
            return Ok(svc.clone());
        }
        // build outside the lock (slow: reads weights, may calibrate)
        // and from the variant's own manifest snapshot — never the
        // registry's current one, which may already describe the next
        // version mid-hot-reload. Losing a race just builds twice and
        // keeps the first insert.
        let session = self
            .factory
            .build(&served.manifest, &served.name, kind)
            .map_err(|e| match e {
                // requesting a kind this deployment cannot execute — the
                // artifact cannot back it (Artifact) or the executor
                // cannot come up at all, e.g. a pjrt-less build
                // (Runtime) — is a routing error, shaped like the
                // single-endpoint refusal so it maps to `not_found`
                // rather than a retryable `internal`
                Error::Artifact(m) | Error::Runtime(m) => Error::Serving(format!(
                    "backend '{kind}' is not served here for '{}': {m}",
                    served.name
                )),
                other => other,
            })?;
        let svc = InferenceService::start_with_metrics(
            session,
            serve_options(&self.cfg),
            self.hub.for_model(&format!("{}+{kind}", served.id)),
        );
        Ok(served
            .extra
            .lock_recover()
            .entry(kind)
            .or_insert(svc)
            .clone())
    }

    /// Protect `spec` (`"name"` or `"name@version"`) from LRU eviction.
    /// The model must be in the manifest; a pinned version must match
    /// the published one (pins track the *name* — a later publish keeps
    /// the pin on the new version, which is what a rollback fallback
    /// wants). Idempotent.
    pub fn pin(&self, spec: &str) -> Result<()> {
        let (name, version) = parse_model_spec(spec)?;
        let current = {
            let g = self.inner.read_recover();
            g.manifest
                .base
                .models
                .contains_key(name)
                .then(|| g.manifest.meta_for(name).version)
        };
        let current = current.ok_or_else(|| {
            Error::Registry(format!(
                "cannot pin '{spec}': model '{name}' not in manifest"
            ))
        })?;
        if let Some(v) = version {
            if v != current {
                return Err(Error::Registry(format!(
                    "cannot pin '{spec}': model '{name}' is at version {current}"
                )));
            }
        }
        self.pinned.lock_recover().insert(name.to_string());
        Ok(())
    }

    /// Remove an eviction pin; returns whether it existed.
    pub fn unpin(&self, name: &str) -> bool {
        self.pinned.lock_recover().remove(name)
    }

    pub fn is_pinned(&self, name: &str) -> bool {
        self.pinned.lock_recover().contains(name)
    }

    /// Track `name` in the LRU and apply any pin-respecting eviction:
    /// pinned names are never chosen as the victim (the list runs over
    /// capacity instead when everything else is pinned).
    fn lru_admit(&self, name: &str, live: &mut BTreeMap<String, Arc<ServedModel>>) {
        let evicted = {
            let pinned = self.pinned.lock_recover();
            self.lru
                .lock_recover()
                .insert_with(name.to_string(), |k| !pinned.contains(k))
        };
        if let Some(old) = evicted {
            // dropping the ServedModel closes its request channel; the
            // batcher flushes and the workers drain in-flight batches
            live.remove(&old);
        }
    }

    /// The live pipeline for `name`, loading it on first use (LRU-bounded).
    pub fn ensure_loaded(&self, name: &str) -> Result<Arc<ServedModel>> {
        if let Some(served) = self.inner.read_recover().live.get(name) {
            self.lru.lock_recover().touch(&name.to_string());
            return Ok(served.clone());
        }
        let built = self.build_served(name)?;
        let mut g = self.inner.write_recover();
        // lost the race? serve whichever version won
        if let Some(existing) = g.live.get(name) {
            return Ok(existing.clone());
        }
        g.live.insert(name.to_string(), built.clone());
        self.lru_admit(name, &mut g.live);
        Ok(built)
    }

    /// Unload `name` (manifest entry stays; next request reloads).
    /// Returns whether it was live. Retiring a model mid-rollout aborts
    /// the rollout (instant rollback) — an unloaded candidate must not
    /// keep receiving canary traffic.
    pub fn retire(&self, name: &str) -> bool {
        if let Some(ro) = self.rollouts.active(name) {
            if ro.abort("model retired").is_ok() {
                crate::obs::log::warn(
                    "rollout",
                    &format!("rollout for '{name}' rolled back: model retired"),
                );
            }
            self.finalize_rollout(name);
        }
        let mut g = self.inner.write_recover();
        self.lru.lock_recover().remove(&name.to_string());
        g.live.remove(name).is_some()
    }

    /// Resolve a model spec to its live pipeline, loading it on first
    /// use. `spec` is `None` (default model), `"name"`, or
    /// `"name@version"`; a pinned version must match the published one.
    fn resolve(&self, spec: Option<&str>) -> Result<Arc<ServedModel>> {
        let spec = spec.unwrap_or(self.cfg.artifacts.model.as_str());
        let (name, pinned) = parse_model_spec(spec)?;
        if let Some(v) = pinned {
            // reject a stale pin against the manifest *before* loading:
            // a doomed request must not build a backend (and potentially
            // LRU-evict a serving model) only to be refused afterwards
            let current = {
                let g = self.inner.read_recover();
                g.manifest
                    .base
                    .models
                    .contains_key(name)
                    .then(|| g.manifest.meta_for(name).version)
            };
            if let Some(current) = current {
                if v != current {
                    return Err(Error::Registry(format!(
                        "model '{name}' is at version {current}, request pinned @{v}"
                    )));
                }
            }
            // unknown names fall through to ensure_loaded's error
        }
        let served = self.ensure_loaded(name)?;
        if let Some(v) = pinned {
            if v != served.version {
                return Err(Error::Registry(format!(
                    "model '{name}' is live at version {}, request pinned @{v}",
                    served.version
                )));
            }
        }
        Ok(served)
    }

    /// Route one request (see [`ModelRegistry::resolve`] for the spec
    /// grammar). Fresh [`ClientId`]: this call is its own fairness class.
    pub fn infer(&self, spec: Option<&str>, features: Vec<f32>) -> Result<(String, Vec<f32>)> {
        self.infer_from(ClientId::fresh(), spec, features)
    }

    /// Like [`ModelRegistry::infer`] attributed to `client` for fair
    /// admission (the TCP layer passes its per-connection id).
    pub fn infer_from(
        &self,
        client: ClientId,
        spec: Option<&str>,
        features: Vec<f32>,
    ) -> Result<(String, Vec<f32>)> {
        let (id, out) = self.infer_route_from(client, &RouteSpec::to_model(spec), features)?;
        Ok((id, out.logits))
    }

    /// Full-route single-row dispatch: resolves the model, picks the
    /// requested backend pipeline from the variant's backend set, runs
    /// the row, and offers the served result to the shadow mirror (only
    /// when the primary served it — a mirrored backend watching its own
    /// output would measure nothing).
    pub fn infer_route_from(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        let served = self.resolve(route.model.as_deref())?;
        // staged-rollout override: default-routed traffic (no explicit
        // version pin, primary backend) splits between candidate and
        // baseline; an explicit `name@v` or backend request must see
        // exactly what it asked for
        if route.backend.is_none() && !spec_pins_version(route.model.as_deref()) {
            if let Some((ro, split)) = self.rollouts.route(&served.name) {
                return self.infer_rollout_row(client, &served, &ro, split, route, features);
            }
        }
        let svc = self.service_for(&served, route.backend)?;
        // presample before dispatch consumes the row: only a selected
        // row is ever copied on the serving path
        let mirror = primary_shadow(&served, route.backend);
        let keep = mirror
            .as_ref()
            .and_then(|sh| sh.presample().then(|| features.clone()));
        let out = svc.infer_traced_from(client, features, route.opts, route.trace.clone())?;
        if let (Some(sh), Some(row)) = (mirror, keep) {
            sh.enqueue(row, out.logits.clone(), route.opts);
        }
        Ok((served.id.clone(), out))
    }

    /// Route one whole batch: the variant is resolved once and every row
    /// hits its dynamic batcher back-to-back, so a single call produces
    /// multi-row batches (the v2 `infer_batch` verb lands here). Fresh
    /// [`ClientId`] per call.
    pub fn infer_batch(
        &self,
        spec: Option<&str>,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<Vec<f32>>)> {
        self.infer_batch_from(ClientId::fresh(), spec, rows)
    }

    /// Like [`ModelRegistry::infer_batch`] attributed to `client`: under
    /// the `drr` admission policy the batch occupies at most the client
    /// quota of the target model's queue while it drains.
    pub fn infer_batch_from(
        &self,
        client: ClientId,
        spec: Option<&str>,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<Vec<f32>>)> {
        let (id, outs) =
            self.infer_batch_route_from(client, &RouteSpec::to_model(spec), rows)?;
        Ok((id, outs.into_iter().map(|o| o.logits).collect()))
    }

    /// Full-route batch dispatch (see [`ModelRegistry::infer_route_from`]).
    pub fn infer_batch_route_from(
        &self,
        client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        let served = self.resolve(route.model.as_deref())?;
        // the whole batch is one split unit (a batch response carries a
        // single resolved id, so its rows cannot straddle versions)
        if route.backend.is_none() && !spec_pins_version(route.model.as_deref()) {
            if let Some((ro, split)) = self.rollouts.route(&served.name) {
                return self.infer_rollout_batch(client, &served, &ro, split, route, rows);
            }
        }
        let svc = self.service_for(&served, route.backend)?;
        // presample before dispatch consumes the rows: only selected
        // rows are copied, never the whole batch
        let mirror = primary_shadow(&served, route.backend);
        let sampled: Vec<(usize, Vec<f32>)> = match &mirror {
            Some(sh) => rows
                .iter()
                .enumerate()
                .filter(|_| sh.presample())
                .map(|(i, row)| (i, row.clone()))
                .collect(),
            None => Vec::new(),
        };
        let outs = svc.infer_many_opts_from(client, rows, route.opts)?;
        if let Some(sh) = mirror {
            for (i, row) in sampled {
                // the same per-row seed derivation the service applied
                // (ExecOptions::for_row), so a mirrored comparison
                // reproduces offline
                sh.enqueue(row, outs[i].logits.clone(), route.opts.for_row(i));
            }
        }
        Ok((served.id.clone(), outs))
    }

    /// Rebuild `name` from the on-disk manifest/weights and atomically
    /// swap it in. In-flight requests on the old pipeline complete. The
    /// displaced pipeline (if the version actually changed) moves to the
    /// standby shelf, so a subsequent `rollout start` has a warm
    /// baseline to fall back to.
    pub fn reload_model(&self, name: &str) -> Result<Arc<ServedModel>> {
        let built = self.build_served(name)?;
        let prev = {
            let mut g = self.inner.write_recover();
            let prev = g.live.insert(name.to_string(), built.clone());
            // keep live and the LRU in sync: reloading a model that was not
            // tracked (non-live reload, or a racing eviction) can push another
            // entry past capacity
            self.lru_admit(name, &mut g.live);
            prev
        };
        if let Some(old) = prev {
            if old.id != built.id {
                self.standby.lock_recover().insert(name.to_string(), old);
                self.rollout_candidate_superseded(name, &built.id);
            }
        }
        Ok(built)
    }

    /// Hot-reload poll: re-read the manifest (it is small, and `save` is
    /// an atomic rename, so this never observes a torn write), then
    /// rebuild any live model whose version or weights digest differs.
    /// Returns the ids of swapped-in versions.
    pub fn poll_reload(&self) -> Result<Vec<String>> {
        let fresh = ModelManifest::load(&self.dir)?;
        {
            let mut g = self.inner.write_recover();
            g.manifest = fresh;
        }
        // snapshot live state, then compare digests without locks
        let live: Vec<(String, u32, String)> = {
            let g = self.inner.read_recover();
            g.live
                .values()
                .map(|s| (s.name.clone(), s.version, s.digest.clone()))
                .collect()
        };
        let mut swapped = Vec::new();
        for (name, version, old_digest) in live {
            let lookup = {
                let g = self.inner.read_recover();
                g.manifest
                    .base
                    .models
                    .get(&name)
                    .map(|e| (g.manifest.meta_for(&name), self.dir.join(&e.weights)))
            };
            let (meta, weights_path) = match lookup {
                Some(found) => found,
                None => {
                    // model removed from the manifest: retire it
                    self.retire(&name);
                    continue;
                }
            };
            let changed = meta.version != version
                || match digest::digest_file(&weights_path) {
                    Ok(d) => d != old_digest,
                    Err(_) => false, // weights temporarily unreadable: keep serving
                };
            if changed {
                // a model that fails to rebuild (corrupt weights, digest
                // mismatch) keeps serving its old version and must not
                // block reloads of the models after it in the loop
                match self.reload_model(&name) {
                    Ok(served) => swapped.push(served.id.clone()),
                    Err(e) => crate::obs::log::warn(
                        "registry",
                        &format!("hot-reload of '{name}' failed: {e}"),
                    ),
                }
            }
        }
        Ok(swapped)
    }

    /// Publish a weights file as a new (or updated) model: ingest it into
    /// the content-addressed store, bump the version, record digest +
    /// quant/accuracy metadata, and atomically rewrite `manifest.json`
    /// (upgrading it to schema v2). If the model is currently live it is
    /// hot-swapped immediately.
    pub fn publish_file(
        &self,
        weights: &std::path::Path,
        name_override: Option<&str>,
        version_override: Option<u32>,
    ) -> Result<(String, ModelMeta)> {
        let published = {
            let mut g = self.inner.write_recover();
            let published = super::publish::publish_into(
                &mut g.manifest,
                &self.store,
                &self.dir,
                weights,
                name_override,
                version_override,
            )?;
            g.manifest.save(&self.dir)?;
            published
        };
        let (name, meta) = &published;
        let was_live = self.inner.read_recover().live.contains_key(name);
        if was_live {
            self.reload_model(name)?;
        }
        Ok((name.clone(), meta.clone()))
    }
}

/// Staged canary deployments ([`crate::rollout`], `docs/ROLLOUT.md`).
///
/// The registry is the rollout plane's host: it retains the displaced
/// pipeline on the standby shelf at hot-swap time (the warm baseline),
/// pins the candidate's live slot against LRU eviction for the rollout
/// lifetime, consults the splitter on the dispatch path, and runs one
/// driver thread per rollout to expire observation windows.
impl ModelRegistry {
    /// The rollout plane (test hook).
    pub fn rollout_plane(&self) -> &RolloutPlane {
        &self.rollouts
    }

    /// Start a rollout: ramp `model_spec` (which must resolve to the
    /// manifest-current version) against `baseline_spec` (which must
    /// match the warm pipeline retained on the standby shelf at the last
    /// hot swap).
    pub fn rollout_start(&self, model_spec: &str, baseline_spec: &str) -> Result<Value> {
        let (name, want_ver) = parse_model_spec(model_spec)?;
        let (bname, want_base_ver) = parse_model_spec(baseline_spec)?;
        if bname != name {
            return Err(Error::Serving(format!(
                "baseline '{baseline_spec}' must be a version of '{name}'"
            )));
        }
        let candidate = self.ensure_loaded(name)?;
        if let Some(v) = want_ver {
            if v != candidate.version {
                return Err(Error::Registry(format!(
                    "candidate must be the current version: '{name}' is live at \
                     {}, requested @{v}",
                    candidate.version
                )));
            }
        }
        let baseline = self.standby.lock_recover().get(name).cloned();
        let baseline = baseline.ok_or_else(|| {
            Error::Registry(format!(
                "no retained baseline for '{name}': the previous version's \
                 pipeline survives only across a hot swap (serve the old \
                 version, publish the new one, then start the rollout)"
            ))
        })?;
        if let Some(v) = want_base_ver {
            if v != baseline.version {
                return Err(Error::Registry(format!(
                    "retained baseline for '{name}' is @{}, requested @{v}",
                    baseline.version
                )));
            }
        }
        if baseline.id == candidate.id {
            return Err(Error::Serving(format!(
                "baseline and candidate are both {}",
                candidate.id
            )));
        }
        // the divergence mirror re-executes canary-served rows on the
        // warm baseline and compares logits; it runs off the response
        // path on the shadow worker, so a mirrored row costs the canary
        // request nothing
        let base = baseline.clone();
        let exec: ShadowExec = Box::new(move |job| {
            let out = base.svc.infer_opts_from(
                ClientId::fresh(),
                job.features.clone(),
                job.opts,
            )?;
            Ok(compare_divergence(&out.logits, &job.primary))
        });
        let ro = self.rollouts.start(
            name,
            baseline.clone(),
            &candidate.id,
            baseline.spec.kind,
            exec,
        )?;
        // pin the candidate's live slot for the rollout lifetime; track
        // the pin so terminal cleanup never removes an operator's own
        if !self.is_pinned(name) {
            self.pin(name)?;
            self.rollout_pins.lock_recover().insert(name.to_string());
        }
        self.spawn_rollout_driver(name);
        crate::obs::log::info(
            "rollout",
            &format!(
                "rollout started for '{name}': {} -> {} (ramp {:?})",
                ro.baseline_id,
                ro.candidate_id,
                self.cfg.rollout.ramp
            ),
        );
        self.rollouts.status(Some(name))
    }

    /// `rollout_status` body: all rollouts, or just `model`'s.
    pub fn rollout_status(&self, model: Option<&str>) -> Result<Value> {
        self.rollouts.status(model)
    }

    /// Operator-initiated instant rollback.
    pub fn rollout_abort(&self, model: &str) -> Result<Value> {
        self.rollouts.abort(model, "operator abort")?;
        crate::obs::log::warn(
            "rollout",
            &format!("rollout for '{model}' rolled back: operator abort"),
        );
        self.finalize_rollout(model);
        self.rollouts.status(Some(model))
    }

    /// Drop a terminal rollout record (and its routing override — after
    /// clearing a rolled-back rollout, default traffic returns to the
    /// manifest-current version). Returns the final status.
    pub fn rollout_clear(&self, model: &str) -> Result<Value> {
        let status = self.rollouts.clear(model)?;
        self.finalize_rollout_record_gone(model);
        Ok(status)
    }

    /// Terminal cleanup (idempotent): unpin what the rollout pinned;
    /// a promoted rollout also releases the standby shelf (its baseline
    /// is obsolete), while a rolled-back one keeps it — the baseline is
    /// still serving all default traffic.
    fn finalize_rollout(&self, name: &str) {
        let Some(ro) = self.rollouts.get(name) else {
            return;
        };
        if !ro.is_terminal() {
            return;
        }
        if !ro.needs_cleanup.swap(false, std::sync::atomic::Ordering::SeqCst) {
            return;
        }
        let was_mine = self.rollout_pins.lock_recover().remove(name);
        if was_mine {
            self.unpin(name);
        }
        if ro.phase() == crate::rollout::RolloutPhase::Promoted {
            let mut shelf = self.standby.lock_recover();
            let matches = shelf.get(name).map_or(false, |s| s.id == ro.baseline_id);
            if matches {
                shelf.remove(name);
            }
        }
        crate::obs::log::info(
            "rollout",
            &format!(
                "rollout for '{name}' finalized: {} ({} -> {})",
                ro.phase().as_str(),
                ro.baseline_id,
                ro.candidate_id
            ),
        );
    }

    /// Cleanup after the record itself was removed (`rollout_clear`):
    /// only the pin bookkeeping can still be pending.
    fn finalize_rollout_record_gone(&self, name: &str) {
        let was_mine = self.rollout_pins.lock_recover().remove(name);
        if was_mine {
            self.unpin(name);
        }
    }

    /// A newer version replaced the rollout's candidate as the manifest
    /// default: the rollout's verdict is moot and its routing override
    /// must not shadow the new version. Abort (recorded) and drop the
    /// record.
    fn rollout_candidate_superseded(&self, name: &str, new_id: &str) {
        let Some(ro) = self.rollouts.get(name) else {
            return;
        };
        if ro.candidate_id == new_id {
            return;
        }
        if !ro.is_terminal() {
            let _ = ro.abort(&format!("candidate superseded by {new_id}"));
            crate::obs::log::warn(
                "rollout",
                &format!(
                    "rollout for '{name}' rolled back: candidate {} superseded \
                     by {new_id}",
                    ro.candidate_id
                ),
            );
        }
        self.finalize_rollout(name);
        self.rollouts.remove(name);
    }

    /// One driver thread per rollout: ticks the window clock every
    /// `rollout.poll_ms` and runs terminal cleanup. Holds only a `Weak`
    /// on the registry, so a dropped registry stops the driver.
    fn spawn_rollout_driver(&self, name: &str) {
        let weak = self.self_weak.lock_recover().clone();
        let name = name.to_string();
        let poll = Duration::from_millis(self.cfg.rollout.poll_ms.max(1));
        let spawned = std::thread::Builder::new()
            .name("kan-edge-rollout".into())
            .spawn(move || loop {
                std::thread::sleep(poll);
                let Some(reg) = weak.upgrade() else { break };
                match reg.rollouts.tick(&name) {
                    TickOutcome::Gone => break,
                    TickOutcome::Promoted => {
                        crate::obs::log::info(
                            "rollout",
                            &format!("rollout for '{name}' promoted"),
                        );
                        reg.finalize_rollout(&name);
                        break;
                    }
                    TickOutcome::RolledBack => {
                        crate::obs::log::warn(
                            "rollout",
                            &format!(
                                "rollout for '{name}' rolled back by gate breach"
                            ),
                        );
                        reg.finalize_rollout(&name);
                        break;
                    }
                    TickOutcome::Idle => {
                        // an operator abort lands terminal outside the
                        // tick path; notice and stop
                        let done = reg
                            .rollouts
                            .get(&name)
                            .map_or(true, |ro| ro.is_terminal());
                        if done {
                            reg.finalize_rollout(&name);
                            break;
                        }
                    }
                    TickOutcome::Advanced | TickOutcome::Extended => {}
                }
            });
        if let Err(e) = spawned {
            crate::obs::log::warn(
                "rollout",
                &format!(
                    "cannot spawn rollout driver for '{name}' ({e}); the \
                     rollout will not advance or roll back on its own"
                ),
            );
        }
    }

    /// Serve one split-routed row (see [`crate::rollout`] module docs).
    fn infer_rollout_row(
        &self,
        client: ClientId,
        candidate: &Arc<ServedModel>,
        ro: &Arc<Rollout>,
        split: Split,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        if split == Split::Baseline {
            if let Some(base) = ro.baseline_model() {
                let t0 = Instant::now();
                let out = base.svc.infer_traced_from(
                    client,
                    features,
                    route.opts,
                    route.trace.clone(),
                )?;
                ro.record_baseline(t0.elapsed());
                return Ok((base.id.clone(), out));
            }
            // promoted concurrently: the candidate serves everything now
        }
        let t0 = Instant::now();
        let out = candidate.svc.infer_traced_from(
            client,
            features.clone(),
            route.opts,
            route.trace.clone(),
        )?;
        ro.record_canary(t0.elapsed());
        // every canary-served row feeds the divergence mirror (bounded
        // queue; overflow drops, never blocks)
        ro.mirror_canary(features, out.logits.clone(), route.opts);
        Ok((candidate.id.clone(), out))
    }

    /// Serve one split-routed batch. The whole batch is one split unit —
    /// a batch response carries a single resolved id, so its rows cannot
    /// straddle versions.
    fn infer_rollout_batch(
        &self,
        client: ClientId,
        candidate: &Arc<ServedModel>,
        ro: &Arc<Rollout>,
        split: Split,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        if split == Split::Baseline {
            if let Some(base) = ro.baseline_model() {
                let t0 = Instant::now();
                let outs = base.svc.infer_many_opts_from(client, rows, route.opts)?;
                ro.record_baseline(t0.elapsed());
                return Ok((base.id.clone(), outs));
            }
        }
        // clone before dispatch consumes the rows: mirrored comparisons
        // need the features (canary batches pay this copy only while a
        // rollout is active; see docs/ROLLOUT.md perf notes)
        let copies = rows.clone();
        let t0 = Instant::now();
        let outs = candidate.svc.infer_many_opts_from(client, rows, route.opts)?;
        ro.record_canary(t0.elapsed());
        for (i, row) in copies.into_iter().enumerate() {
            // the same per-row seed derivation the service applied
            // (ExecOptions::for_row), so the mirror reproduces the row
            ro.mirror_canary(row, outs[i].logits.clone(), route.opts.for_row(i));
        }
        Ok((candidate.id.clone(), outs))
    }
}

/// `"name@version"` pins an exact version; pinned requests bypass the
/// rollout splitter (an operator probing a version must see exactly it).
fn spec_pins_version(spec: Option<&str>) -> bool {
    spec.map_or(false, |s| s.contains('@'))
}

/// Row-level divergence between the baseline's recomputation and what
/// the canary actually served for the same features and options.
fn compare_divergence(baseline: &[f32], canary: &[f32]) -> ShadowObservation {
    let flip = argmax(baseline) != argmax(canary);
    let n = baseline.len().min(canary.len());
    let mae = if n == 0 {
        0.0
    } else {
        (0..n)
            .map(|i| (f64::from(baseline[i]) - f64::from(canary[i])).abs())
            .sum::<f64>()
            / n as f64
    };
    ShadowObservation { flip, mae, layer_err: Vec::new() }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// The shadow to offer a served row to: only when the row was served by
/// the primary backend (explicitly or by default).
fn primary_shadow(
    served: &Arc<ServedModel>,
    backend: Option<BackendKind>,
) -> Option<Arc<ShadowState>> {
    match backend {
        None => served.shadow.clone(),
        Some(k) if k == served.spec.kind => served.shadow.clone(),
        Some(_) => None,
    }
}

impl Dispatch for ModelRegistry {
    fn dispatch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        self.infer_route_from(client, route, features)
    }

    fn dispatch_batch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        // `infer_many` also rejects empty batches, but guarding before
        // `resolve` avoids lazily loading a pipeline for a no-op call
        if rows.is_empty() {
            return Err(Error::Serving("empty batch".into()));
        }
        self.infer_batch_route_from(client, route, rows)
    }

    fn model_summaries(&self) -> Vec<ModelSummary> {
        // served-backend capabilities for live variants, from the
        // primary session's spec + shadow status
        let live_info: BTreeMap<String, BackendInfo> = {
            let g = self.inner.read_recover();
            g.live
                .values()
                .map(|s| {
                    let shadow = s.shadow.as_ref().map(|sh| (sh.kind, sh.fraction));
                    (s.name.clone(), BackendInfo::from_spec(&s.spec, shadow))
                })
                .collect()
        };
        self.models()
            .into_iter()
            .map(|m| {
                let backend = live_info.get(&m.name).cloned();
                ModelSummary {
                    name: m.name,
                    version: m.meta.version,
                    kind: m.kind,
                    dims: m.dims,
                    num_params: m.num_params,
                    live: m.live,
                    accuracy: m.meta.accuracy,
                    digest: m.meta.digest,
                    backend,
                }
            })
            .collect()
    }

    fn metrics_reports(&self) -> Vec<(String, MetricsReport)> {
        self.metrics()
    }

    fn live_model_count(&self) -> usize {
        self.inner.read_recover().live.len()
    }

    /// Replication read side: resolve `digest` in the content-addressed
    /// store (re-hashed — a corrupted object is refused, never shipped)
    /// and attach the manifest entry it currently backs, so the puller
    /// can republish under the same `name@version`.
    fn pull_artifact(
        &self,
        digest_str: &str,
    ) -> Result<(Option<crate::util::json::Value>, Vec<u8>)> {
        use crate::util::json::{obj, Value};
        let path = self.store.open_verified(digest_str)?;
        let data = std::fs::read(&path)?;
        let meta = {
            let g = self.inner.read_recover();
            g.manifest.base.models.iter().find_map(|(name, e)| {
                let m = g.manifest.meta_for(name);
                (m.digest.as_deref() == Some(digest_str)).then(|| {
                    obj(vec![
                        ("name", Value::Str(name.clone())),
                        ("version", Value::Int(m.version as i64)),
                        ("kind", Value::Str(e.kind.clone())),
                    ])
                })
            })
        };
        Ok((meta, data))
    }

    /// Replication write side: verify the payload against the declared
    /// digest *first*, then run the normal validated publish path
    /// (checkpoint parse, store ingest, manifest rewrite, hot swap if
    /// live). A re-push of an already-published `(name, version,
    /// digest)` is an idempotent success — replication retries must not
    /// trip the version-monotonicity check.
    fn push_artifact(
        &self,
        name: &str,
        version: Option<u32>,
        digest_str: &str,
        data: &[u8],
    ) -> Result<String> {
        let actual = digest::digest_bytes(data);
        if actual != digest_str {
            return Err(Error::Registry(format!(
                "digest mismatch for pushed artifact '{name}': caller says \
                 {digest_str}, payload is {actual} (artifact corrupted in \
                 transit?)"
            )));
        }
        {
            let g = self.inner.read_recover();
            if g.manifest.base.models.contains_key(name) {
                let m = g.manifest.meta_for(name);
                if m.digest.as_deref() == Some(digest_str)
                    && version.map_or(true, |v| v == m.version)
                {
                    return Ok(format!("{name}@{}", m.version));
                }
            }
        }
        // stage to a temp file: publish validates the checkpoint by
        // loading it, and the store ingests by path
        let tmp = self
            .dir
            .join(format!(".push-{name}-{}.incoming.json", std::process::id()));
        std::fs::write(&tmp, data)?;
        let result = self.publish_file(&tmp, Some(name), version);
        let _ = std::fs::remove_file(&tmp);
        let (published_name, meta) = result?;
        Ok(format!("{published_name}@{}", meta.version))
    }

    /// Rollout summaries ride the `metrics` body (and the Prometheus
    /// exposition renders them as `kan_edge_rollout_*` series).
    fn metrics_overlay(&self) -> Option<Value> {
        self.rollouts
            .prom_overlay()
            .map(|v| crate::util::json::obj(vec![("rollout", v)]))
    }

    fn rollout_start(&self, model: &str, baseline: &str) -> Result<Value> {
        ModelRegistry::rollout_start(self, model, baseline)
    }

    fn rollout_status(&self, model: Option<&str>) -> Result<Value> {
        ModelRegistry::rollout_status(self, model)
    }

    fn rollout_abort(&self, model: &str) -> Result<Value> {
        ModelRegistry::rollout_abort(self, model)
    }

    fn rollout_clear(&self, model: &str) -> Result<Value> {
        ModelRegistry::rollout_clear(self, model)
    }
}

/// Spawn the hot-reload poller; it stops on its own once the registry is
/// dropped (holds only a `Weak`).
pub fn spawn_reload_thread(registry: &Arc<ModelRegistry>, interval: Duration) {
    let weak = Arc::downgrade(registry);
    let _ = std::thread::Builder::new()
        .name("kan-edge-reload".into())
        .spawn(move || loop {
            std::thread::sleep(interval);
            match weak.upgrade() {
                Some(reg) => {
                    if let Err(e) = reg.poll_reload() {
                        crate::obs::log::warn(
                            "registry",
                            &format!("hot-reload poll failed: {e}"),
                        );
                    }
                }
                None => break,
            }
        });
}
