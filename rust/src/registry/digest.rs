//! Content digests for artifact addressing.
//!
//! FNV-1a 64 is the digest of record: dependency-free, fast enough for
//! multi-MB weight files, and collision-safe at registry scale (dozens of
//! artifacts, not billions). Digest strings are prefixed with the
//! algorithm (`fnv64:<16 hex>`) so a stronger hash can be added later
//! without ambiguity.

use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Digest-string prefix for the FNV-1a 64 algorithm.
pub const FNV64_PREFIX: &str = "fnv64:";

/// Raw FNV-1a 64 over a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Digest of an in-memory buffer, e.g. `fnv64:af63dc4c8601ec8c`.
pub fn digest_bytes(bytes: &[u8]) -> String {
    format!("{FNV64_PREFIX}{:016x}", fnv64(bytes))
}

/// Streaming digest of a file on disk.
pub fn digest_file(path: impl AsRef<Path>) -> Result<String> {
    let path = path.as_ref();
    let mut file = std::fs::File::open(path).map_err(|e| {
        Error::Registry(format!("cannot read artifact {}: {e}", path.display()))
    })?;
    let mut h = FNV_OFFSET;
    let mut buf = [0u8; 64 * 1024];
    loop {
        let n = file.read(&mut buf)?;
        if n == 0 {
            break;
        }
        for &b in &buf[..n] {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    Ok(format!("{FNV64_PREFIX}{h:016x}"))
}

/// Validate a digest string and return its 16-hex-char payload.
pub fn parse(digest: &str) -> Result<&str> {
    let hex = digest.strip_prefix(FNV64_PREFIX).ok_or_else(|| {
        Error::Registry(format!(
            "unsupported digest '{digest}' (expected '{FNV64_PREFIX}<16 hex>')"
        ))
    })?;
    if hex.len() != 16 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(Error::Registry(format!(
            "malformed digest '{digest}' (expected 16 hex chars after '{FNV64_PREFIX}')"
        )));
    }
    Ok(hex)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv64_known_answers() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn file_and_bytes_digests_agree() {
        let dir = std::env::temp_dir().join("kan_edge_digest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let data: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        assert_eq!(digest_file(&path).unwrap(), digest_bytes(&data));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse("fnv64:0123456789abcdef").is_ok());
        assert!(parse("sha256:0123456789abcdef").is_err());
        assert!(parse("fnv64:short").is_err());
        assert!(parse("fnv64:0123456789abcdeg").is_err());
    }
}
