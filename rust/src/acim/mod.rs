//! RRAM analog compute-in-memory (ACIM) behavioural simulator.
//!
//! * [`array`] — crossbar programming + ideal differential MAC.
//! * [`irdrop`] — the bit-line resistive-ladder model (position-dependent
//!   attenuation, the physics behind Fig 12).
//! * [`noise`] — programming variation + read noise (seeded, deterministic).
//! * [`adc`] — partial-sum quantization.
//! * [`stats`] — "measured-chip" calibration tables (DESIGN.md §4).
//! * [`tile`] — executing quantized KAN layers/models through the analog
//!   pipeline under a pluggable row mapping (KAN-SAM's hook).

pub mod adc;
pub mod array;
pub mod irdrop;
pub mod noise;
pub mod stats;
pub mod tile;

pub use adc::Adc;
pub use array::{ArrayConfig, Crossbar};
pub use irdrop::mac_with_irdrop;
pub use noise::NoiseModel;
pub use stats::{calibrate, measured_table, ArrayStats};
pub use tile::{identity_mapping, AcimLayer, AcimModel, AcimOptions};
