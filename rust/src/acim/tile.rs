//! ACIM tile execution: running quantized KAN layers through the analog
//! pipeline (crossbar → IR-drop → read noise → ADC), with a pluggable row
//! mapping — the integration point for KAN-SAM (paper §3.3).
//!
//! A layer's spline path occupies `din · (G+K)` crossbar rows (one per
//! (input, basis) pair, holding that pair's `dout` ci' codes). Rows are
//! placed onto physical arrays of `cfg.rows` rows by a *mapping*: a
//! permutation assigning logical rows to physical slots ordered by distance
//! from the BL clamp. Partial sums from multiple tiles are combined
//! digitally (ideal adders), as in the paper's architecture.


use super::adc::Adc;
use super::array::{ArrayConfig, Crossbar};
use super::irdrop::mac_with_irdrop;
use super::noise::NoiseModel;
use crate::error::Result;
use crate::kan::layer::QuantKanLayer;
use crate::kan::model::QuantKanModel;

/// Non-ideality switches for an ACIM run.
#[derive(Debug, Clone, Copy)]
pub struct AcimOptions {
    pub array: ArrayConfig,
    /// ADC resolution for partial sums.
    pub adc_bits: u32,
    /// ADC full-scale as a fraction of the sum of active-row full-scale
    /// currents (headroom factor; <1 exploits sign cancellation).
    pub adc_fs_factor: f64,
    /// Enable the IR-drop ladder (off = ideal wires).
    pub irdrop: bool,
    /// Enable programming variation + read noise.
    pub noise: bool,
    /// RNG seed for the noise model.
    pub seed: u64,
}

impl Default for AcimOptions {
    fn default() -> Self {
        Self {
            array: ArrayConfig::default(),
            adc_bits: 8,
            adc_fs_factor: 0.5,
            irdrop: true,
            noise: true,
            seed: 0x5eed,
        }
    }
}

/// One physical array holding a slice of a layer's logical rows.
struct Tile {
    xb: Crossbar,
    /// logical row index for each physical slot (clamp-nearest first).
    logical_rows: Vec<usize>,
}

/// A KAN layer programmed onto ACIM tiles under a given row mapping.
pub struct AcimLayer {
    pub din: usize,
    pub dout: usize,
    tiles: Vec<Tile>,
    adc: Adc,
    lut_scale: f64,
    coeff_scale: f64,
    wb: Vec<f64>,
    spec: crate::quant::AspSpec,
    lut: crate::quant::ShLut,
}

impl AcimLayer {
    /// Program `layer` onto tiles. `mapping[k]` = the logical row placed at
    /// global physical slot `k` (slots are filled tile by tile, each tile's
    /// slot 0 nearest its clamp). Identity mapping = the uniform baseline.
    pub fn program(
        layer: &QuantKanLayer,
        opts: &AcimOptions,
        mapping: &[usize],
        noise: &mut NoiseModel,
    ) -> Result<Self> {
        let n_rows = layer.spline_rows();
        assert_eq!(mapping.len(), n_rows, "mapping must cover all rows");
        let per_tile = opts.array.rows;
        let mut tiles = Vec::new();
        let mut slot = 0usize;
        while slot < n_rows {
            let count = per_tile.min(n_rows - slot);
            let logical_rows: Vec<usize> = mapping[slot..slot + count].to_vec();
            let mut w = Vec::with_capacity(count * layer.dout);
            for &lr in &logical_rows {
                w.extend_from_slice(layer.row_weights(lr));
            }
            let mut xb =
                Crossbar::program(opts.array, &w, count, layer.dout, 127.0)?;
            if opts.noise {
                noise.apply_programming_variation(&mut xb);
            }
            tiles.push(Tile { xb, logical_rows });
            slot += count;
        }
        // ADC full scale: active rows per input sum to ~1 drive (partition
        // of unity), so the worst-case current is din * full-scale-cell.
        let cell_fs = (opts.array.g_lrs_us - opts.array.g_hrs_us) * opts.array.v_read;
        let fs = (layer.din as f64 * cell_fs * opts.adc_fs_factor).max(cell_fs);
        Ok(Self {
            din: layer.din,
            dout: layer.dout,
            tiles,
            adc: Adc::new(opts.adc_bits, fs),
            lut_scale: 1.0 / ((1u64 << layer.lut.bits) - 1) as f64,
            coeff_scale: layer.coeff_scale,
            wb: layer.wb.clone(),
            spec: layer.spec,
            lut: layer.lut.clone(),
        })
    }

    /// Analog forward for one sample's input codes.
    pub fn forward(
        &self,
        xq: &[u32],
        opts: &AcimOptions,
        noise: &mut NoiseModel,
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), self.dout);
        // WL drives per logical row, in [0, 1]
        let nb = self.spec.num_basis();
        let kk = self.spec.k as usize;
        let mut drives = vec![0.0f64; self.din * nb];
        for (i, &q) in xq.iter().enumerate() {
            let (j, l) = self.spec.decompose(q);
            for t in 0..=kk {
                let code = self.lut.lookup(l, t as u32);
                drives[i * nb + j as usize + t] = code as f64 * self.lut_scale;
            }
        }
        out.fill(0.0);
        let mut tile_drives: Vec<f64> = Vec::new();
        for tile in &self.tiles {
            tile_drives.clear();
            tile_drives.extend(tile.logical_rows.iter().map(|&lr| drives[lr]));
            let currents = if opts.irdrop {
                mac_with_irdrop(&tile.xb, &tile_drives)
            } else {
                tile.xb.mac_ideal(&tile_drives)
            };
            for (c, &i_ua) in currents.iter().enumerate() {
                let i_noisy = if opts.noise { noise.read_noise(i_ua) } else { i_ua };
                let i_q = self.adc.roundtrip(i_noisy);
                // current -> code units (Σ drive·w) -> value
                out[c] += tile.xb.current_to_code(i_q) * self.coeff_scale;
            }
        }
        // w_b · ReLU residual path: standard DNN crossbar in the paper;
        // modelled as digital-exact (it is not what Fig 12 varies).
        for (i, &q) in xq.iter().enumerate() {
            let x = self.spec.dequantize(q);
            if x > 0.0 {
                for c in 0..self.dout {
                    out[c] += x * self.wb[i * self.dout + c];
                }
            }
        }
    }
}

/// A whole KAN model programmed onto ACIM, with per-layer mappings.
pub struct AcimModel {
    pub layers: Vec<AcimLayer>,
    pub opts: AcimOptions,
}

impl AcimModel {
    /// `mappings[i]` = row mapping for layer i (see [`AcimLayer::program`]).
    pub fn program(
        model: &QuantKanModel,
        opts: AcimOptions,
        mappings: &[Vec<usize>],
    ) -> Result<Self> {
        assert_eq!(mappings.len(), model.layers.len());
        let mut noise = NoiseModel::from_config(opts.seed, &opts.array);
        let layers = model
            .layers
            .iter()
            .zip(mappings)
            .map(|(l, m)| AcimLayer::program(l, &opts, m, &mut noise))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { layers, opts })
    }

    /// Analog forward for one sample.
    pub fn forward(&self, x: &[f32], noise: &mut NoiseModel) -> Vec<f64> {
        let mut h: Vec<f32> = x.to_vec();
        let mut out = Vec::new();
        for layer in &self.layers {
            let xq: Vec<u32> = h.iter().map(|&v| layer.spec.quantize(v as f64)).collect();
            out = vec![0.0; layer.dout];
            layer.forward(&xq, &self.opts, noise, &mut out);
            h = out.iter().map(|&v| v as f32).collect();
        }
        out
    }

    /// Top-1 accuracy over the artifact test set.
    pub fn accuracy(&self, ds: &crate::kan::checkpoint::Dataset) -> f64 {
        let mut noise = NoiseModel::from_config(self.opts.seed ^ 0xabcd, &self.opts.array);
        let mut correct = 0usize;
        let mut total = 0usize;
        for (row, label) in ds.test_rows() {
            let out = self.forward(row, &mut noise);
            if crate::kan::model::argmax(&out) == label as usize {
                correct += 1;
            }
            total += 1;
        }
        correct as f64 / total.max(1) as f64
    }
}

/// Identity mapping (the uniform baseline of Fig 12).
pub fn identity_mapping(rows: usize) -> Vec<usize> {
    (0..rows).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    fn ideal_opts(rows: usize) -> AcimOptions {
        AcimOptions {
            array: ArrayConfig { r_wire_ohm: 0.0, ..ArrayConfig::with_rows(rows) },
            adc_bits: 12,
            adc_fs_factor: 1.0,
            irdrop: false,
            noise: false,
            seed: 1,
        }
    }

    #[test]
    fn ideal_acim_matches_digital_reference() {
        let layer = toy_layer(5, 3, 4, 3);
        let opts = ideal_opts(256);
        let mut nm = NoiseModel::new(1, 0.0, 0.0);
        let mapping = identity_mapping(layer.spline_rows());
        let acim = AcimLayer::program(&layer, &opts, &mapping, &mut nm).unwrap();
        let xq = layer.quantize_input(&[0.3, -0.7, 0.95, -0.05]);
        let mut want = vec![0.0; 3];
        layer.forward_digital(&xq, &mut want);
        let mut got = vec![0.0; 3];
        acim.forward(&xq, &opts, &mut nm, &mut got);
        for o in 0..3 {
            // MLC (128 levels vs 127 codes) + 12-bit ADC keep this tight
            assert!(
                (got[o] - want[o]).abs() < 0.05 * want[o].abs().max(1.0),
                "o={o}: {} vs {}",
                got[o],
                want[o]
            );
        }
    }

    #[test]
    fn multiple_tiles_when_layer_exceeds_array() {
        let layer = toy_layer(5, 3, 4, 3); // 4 * 8 = 32 rows
        let opts = ideal_opts(8); // forces 4 tiles
        let mut nm = NoiseModel::new(1, 0.0, 0.0);
        let mapping = identity_mapping(layer.spline_rows());
        let acim = AcimLayer::program(&layer, &opts, &mapping, &mut nm).unwrap();
        assert_eq!(acim.tiles.len(), 4);
        // forward still matches digital
        let xq = layer.quantize_input(&[0.1, 0.2, -0.3, 0.8]);
        let mut want = vec![0.0; 3];
        layer.forward_digital(&xq, &mut want);
        let mut got = vec![0.0; 3];
        acim.forward(&xq, &opts, &mut nm, &mut got);
        for o in 0..3 {
            assert!((got[o] - want[o]).abs() < 0.05 * want[o].abs().max(1.0));
        }
    }

    #[test]
    fn permuted_mapping_is_exact_under_ideal_wires() {
        // with no IR-drop, row order must not matter at all
        let layer = toy_layer(5, 3, 4, 3);
        let opts = ideal_opts(256);
        let mut nm = NoiseModel::new(1, 0.0, 0.0);
        let rows = layer.spline_rows();
        let reversed: Vec<usize> = (0..rows).rev().collect();
        let a = AcimLayer::program(&layer, &opts, &identity_mapping(rows), &mut nm).unwrap();
        let b = AcimLayer::program(&layer, &opts, &reversed, &mut nm).unwrap();
        let xq = layer.quantize_input(&[0.5, -0.2, 0.9, -0.9]);
        let (mut oa, mut ob) = (vec![0.0; 3], vec![0.0; 3]);
        a.forward(&xq, &opts, &mut nm, &mut oa);
        b.forward(&xq, &opts, &mut nm, &mut ob);
        for o in 0..3 {
            assert!((oa[o] - ob[o]).abs() < 1e-9);
        }
    }

    #[test]
    fn irdrop_changes_output() {
        let layer = toy_layer(5, 3, 8, 2);
        let mut opts = ideal_opts(64);
        let mut nm = NoiseModel::new(1, 0.0, 0.0);
        let mapping = identity_mapping(layer.spline_rows());
        let ideal_layer = AcimLayer::program(&layer, &opts, &mapping, &mut nm).unwrap();
        let xq = layer.quantize_input(&[0.4; 8]);
        let mut ideal_out = vec![0.0; 2];
        ideal_layer.forward(&xq, &opts, &mut nm, &mut ideal_out);

        opts.irdrop = true;
        opts.array.r_wire_ohm = 20.0; // exaggerated to make the effect obvious
        let real_layer = AcimLayer::program(&layer, &opts, &mapping, &mut nm).unwrap();
        let mut real_out = vec![0.0; 2];
        real_layer.forward(&xq, &opts, &mut nm, &mut real_out);
        let diff: f64 = ideal_out
            .iter()
            .zip(&real_out)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-6, "IR-drop had no effect");
    }
}
