//! Bit-line IR-drop model: the resistive ladder, solved exactly.
//!
//! The BL clamp (column amplifier input) holds node 0 at virtual ground;
//! each cell `r` injects current at node `r` through its programmed
//! conductance from the driven word line, and the shared BL wire adds
//! `r_wire` Ω between adjacent nodes. Current from far rows must flow
//! through more wire, raising the local BL potential and shrinking the
//! cell's effective V_ds — so far rows contribute *less* than they should.
//! The error grows with array size and with distance from the clamp:
//! exactly the trend the paper extracts from TSMC 22 nm measurements [13]
//! and the lever KAN-SAM pulls.
//!
//! The network is linear, so we solve it exactly: KCL gives a tridiagonal
//! system `(L + diag(g_eff)) v = g_eff · V_read` where `L` is the wire
//! Laplacian with a grounded end; one Thomas-algorithm sweep (O(rows))
//! yields the node voltages and the clamp current. (An earlier fixed-point
//! iteration oscillated for large arrays — see EXPERIMENTS.md §Fig12.)

use super::array::Crossbar;

/// IR-drop-aware MAC for one crossbar. `drives` in [0, 1] per row.
/// Returns per-column differential current (µA).
pub fn mac_with_irdrop(xb: &Crossbar, drives: &[f64]) -> Vec<f64> {
    let cols = xb.cols();
    let rows = xb.cfg.rows.min(drives.len());
    let mut out = vec![0.0; cols];
    let mut geff = vec![0.0f64; rows];
    let mut scratch = Scratch::new(rows);
    for c in 0..cols {
        for (r, g) in geff.iter_mut().enumerate() {
            *g = drives[r] * xb.g_pos[r * cols + c];
        }
        let ip = ladder_current(&geff, xb.cfg.r_wire_ohm, xb.cfg.v_read, &mut scratch);
        for (r, g) in geff.iter_mut().enumerate() {
            *g = drives[r] * xb.g_neg[r * cols + c];
        }
        let in_ = ladder_current(&geff, xb.cfg.r_wire_ohm, xb.cfg.v_read, &mut scratch);
        out[c] = ip - in_;
    }
    out
}

/// Reusable buffers for the tridiagonal solve.
pub(crate) struct Scratch {
    cp: Vec<f64>,
    dp: Vec<f64>,
}

impl Scratch {
    pub(crate) fn new(rows: usize) -> Self {
        Self { cp: vec![0.0; rows], dp: vec![0.0; rows] }
    }
}

/// Exact clamp current (µA) for one physical BL.
///
/// `geff[r]` is the effective source conductance of node `r` in µS (drive
/// × cell conductance); `r_wire` Ω per segment; clamp at virtual ground.
/// With zero wire resistance this degenerates to `Σ geff · v_read`.
pub(crate) fn ladder_current(
    geff: &[f64],
    r_wire: f64,
    v_read: f64,
    s: &mut Scratch,
) -> f64 {
    let rows = geff.len();
    if rows == 0 {
        return 0.0;
    }
    if r_wire <= 0.0 {
        return geff.iter().sum::<f64>() * v_read;
    }
    // conductances in µS; wire conductance in µS: 1/r [S] = 1e6/r [µS]
    let gw = 1e6 / r_wire;
    // Tridiagonal system over node voltages v[0..rows):
    //   node r: (gw_left + gw_right + geff[r]) v[r]
    //           - gw v[r-1] - gw v[r+1] = geff[r] * v_read
    // where gw_left connects node 0 to the clamp (ground) and the last
    // node has no right neighbour.
    // Thomas algorithm with constant off-diagonals (-gw).
    let b0 = gw + if rows > 1 { gw } else { 0.0 } + geff[0];
    s.cp[0] = -gw / b0;
    s.dp[0] = geff[0] * v_read / b0;
    for r in 1..rows {
        let right = if r + 1 < rows { gw } else { 0.0 };
        let b = gw + right + geff[r];
        let m = b + gw * s.cp[r - 1]; // b - a*cp (a = -gw)
        s.cp[r] = -gw / m;
        s.dp[r] = (geff[r] * v_read + gw * s.dp[r - 1]) / m;
    }
    // back-substitute
    let mut v_next = s.dp[rows - 1];
    let mut v0 = v_next;
    for r in (0..rows.saturating_sub(1)).rev() {
        v_next = s.dp[r] - s.cp[r] * v_next;
        v0 = v_next;
    }
    // clamp current = gw * (v[0] - 0)
    gw * v0
}

/// Relative attenuation profile: drive each decile row alone and compare
/// against the ideal current — a diagnostic for the stats calibration.
pub fn attenuation_profile(xb: &Crossbar, active_rows: usize) -> Vec<f64> {
    let rows = xb.cfg.rows;
    let n = active_rows.min(rows);
    let ideal = xb.mac_ideal(&vec![1.0; n]);
    let real = mac_with_irdrop(xb, &vec![1.0; n]);
    (0..1)
        .filter(|&c| ideal[c].abs() > 1e-12)
        .map(|c| real[c] / ideal[c])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acim::array::{ArrayConfig, Crossbar};

    fn uniform_xb(rows: usize, r_wire: f64) -> Crossbar {
        let cfg = ArrayConfig {
            rows,
            r_wire_ohm: r_wire,
            ..ArrayConfig::with_rows(rows)
        };
        let w = vec![127i32; rows];
        Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap()
    }

    #[test]
    fn zero_wire_resistance_matches_ideal() {
        let xb = uniform_xb(64, 0.0);
        let drives = vec![1.0; 64];
        let ideal = xb.mac_ideal(&drives);
        let real = mac_with_irdrop(&xb, &drives);
        assert!((ideal[0] - real[0]).abs() < 1e-9);
    }

    #[test]
    fn single_cell_analytic_check() {
        // one cell at distance d: I = g*(v - I*R*d') with d' = d+1 segments
        // => I = g*v / (1 + g*R*(d+1))
        let rows = 16;
        let cfg = ArrayConfig {
            rows,
            r_wire_ohm: 100.0, // exaggerated for visibility
            ..ArrayConfig::with_rows(rows)
        };
        let w = vec![127i32; rows];
        let xb = Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        for d in [0usize, 7, 15] {
            let mut drives = vec![0.0; rows];
            drives[d] = 1.0;
            let got = mac_with_irdrop(&xb, &drives)[0];
            let r_tot = 100.0 * (d as f64 + 1.0); // Ω to the clamp
            // differential: positive BL at G_LRS minus negative BL leakage
            // at the G_HRS floor, each attenuated by its own ladder
            let gp = xb.g_pos[d] * 1e-6; // S
            let gn = xb.g_neg[d] * 1e-6;
            let want = (gp / (1.0 + gp * r_tot) - gn / (1.0 + gn * r_tot))
                * xb.cfg.v_read
                * 1e6; // A -> µA
            assert!(
                (got - want).abs() < 1e-6 * want.abs().max(1.0),
                "d={d}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn irdrop_only_reduces_current() {
        let xb = uniform_xb(128, 2.0);
        let drives = vec![1.0; 128];
        let ideal = xb.mac_ideal(&drives)[0];
        let real = mac_with_irdrop(&xb, &drives)[0];
        assert!(real < ideal);
        assert!(real > 0.0);
    }

    #[test]
    fn error_grows_with_array_size() {
        // the paper's Fig 12 premise: bigger arrays, bigger IR-drop error
        let mut last_err = 0.0;
        for rows in [128usize, 256, 512, 1024] {
            let xb = uniform_xb(rows, 1.0);
            let drives = vec![1.0; rows];
            let ideal = xb.mac_ideal(&drives)[0];
            let real = mac_with_irdrop(&xb, &drives)[0];
            let err = (ideal - real) / ideal;
            assert!(err > last_err, "rows={rows}: err {err} not > {last_err}");
            last_err = err;
        }
    }

    #[test]
    fn near_rows_contribute_more_than_far_rows() {
        let xb = uniform_xb(512, 2.0);
        let mut near = vec![0.0; 512];
        near[0] = 1.0;
        let mut far = vec![0.0; 512];
        far[511] = 1.0;
        let i_near = mac_with_irdrop(&xb, &near)[0];
        let i_far = mac_with_irdrop(&xb, &far)[0];
        assert!(
            i_far < i_near,
            "far row current {i_far} should be < near row {i_near}"
        );
    }

    #[test]
    fn superposition_does_not_hold_but_total_is_bounded() {
        // sanity on the exact solve: the all-on current must be less than
        // the sum of single-row currents (shared wire makes them compete)
        let rows = 64;
        let xb = uniform_xb(rows, 5.0);
        let all = mac_with_irdrop(&xb, &vec![1.0; rows])[0];
        let sum_singles: f64 = (0..rows)
            .map(|r| {
                let mut d = vec![0.0; rows];
                d[r] = 1.0;
                mac_with_irdrop(&xb, &d)[0]
            })
            .sum();
        assert!(all < sum_singles);
        assert!(all > 0.0);
    }
}
