//! Column ADC / sense amplifier: partial-sum quantization.
//!
//! The analog column current is digitized to `bits` by a converter whose
//! full scale is set per layer (from the largest representable partial
//! sum). Quantization is the last error source in the analog chain; its
//! resolution interacts with IR-drop (attenuated currents land in lower
//! codes) which is why the paper evaluates accuracy with both effects on.


/// Partial-sum ADC model.
#[derive(Debug, Clone, Copy)]
pub struct Adc {
    pub bits: u32,
    /// Full-scale input (µA). Inputs beyond ±full_scale saturate.
    pub full_scale_ua: f64,
}

impl Adc {
    pub fn new(bits: u32, full_scale_ua: f64) -> Self {
        Self { bits, full_scale_ua }
    }

    /// Signed levels available on each side of zero.
    pub fn half_levels(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Quantize a (differential, signed) current to an ADC code.
    pub fn convert(&self, i_ua: f64) -> i64 {
        let lv = self.half_levels() as f64;
        let code = (i_ua / self.full_scale_ua * lv).round();
        code.clamp(-lv, lv) as i64
    }

    /// Code back to current (µA).
    pub fn dequant(&self, code: i64) -> f64 {
        code as f64 / self.half_levels() as f64 * self.full_scale_ua
    }

    /// Convert and dequantize in one go (what the pipeline does).
    pub fn roundtrip(&self, i_ua: f64) -> f64 {
        self.dequant(self.convert(i_ua))
    }

    /// Max quantization error (half an LSB) in µA.
    pub fn lsb_ua(&self) -> f64 {
        self.full_scale_ua / self.half_levels() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_within_half_lsb() {
        let adc = Adc::new(6, 100.0);
        for i in -100..=100 {
            let x = i as f64;
            let err = (adc.roundtrip(x) - x).abs();
            assert!(err <= adc.lsb_ua() / 2.0 + 1e-12, "x={x} err={err}");
        }
    }

    #[test]
    fn saturates_at_full_scale() {
        let adc = Adc::new(6, 50.0);
        assert_eq!(adc.convert(500.0), adc.half_levels());
        assert_eq!(adc.convert(-500.0), -adc.half_levels());
    }

    #[test]
    fn more_bits_less_error() {
        let coarse = Adc::new(4, 100.0);
        let fine = Adc::new(8, 100.0);
        assert!(fine.lsb_ua() < coarse.lsb_ua() / 8.0);
    }

    #[test]
    fn zero_maps_to_zero() {
        let adc = Adc::new(6, 100.0);
        assert_eq!(adc.convert(0.0), 0);
        assert_eq!(adc.roundtrip(0.0), 0.0);
    }
}
