//! RRAM crossbar array: conductance programming and ideal MAC.
//!
//! Signed int8 weights are stored differentially: each logical column is a
//! (positive, negative) BL pair, each cell a multi-level conductance between
//! `g_hrs` and `g_lrs` (the SLC-MLC hybrid of ref [13] reduced to its
//! behavioural essence). The analog MAC is
//! `I_col = Σ_rows drive_i · G_i · V_read`, computed ideally here; IR-drop
//! and variation live in [`super::irdrop`] / [`super::noise`].


use crate::error::{Error, Result};

/// Physical configuration of one crossbar array (one "tile").
#[derive(Debug, Clone, Copy)]
pub struct ArrayConfig {
    /// Rows (cells per bit line) — the paper's "array size" axis in Fig 12.
    pub rows: usize,
    /// Logical columns (each backed by a differential BL pair).
    pub cols: usize,
    /// BL wire resistance between adjacent cells (Ω).
    pub r_wire_ohm: f64,
    /// Low-resistance-state conductance (µS) — full-scale weight.
    pub g_lrs_us: f64,
    /// High-resistance-state conductance (µS) — zero weight (leakage floor).
    pub g_hrs_us: f64,
    /// Programmable conductance levels per cell (MLC).
    pub levels: u32,
    /// Read voltage on the WL (V).
    pub v_read: f64,
    /// Relative conductance programming error σ (device-to-device).
    pub sigma_program: f64,
    /// Relative read-noise σ (cycle-to-cycle).
    pub sigma_read: f64,
}

impl Default for ArrayConfig {
    fn default() -> Self {
        Self {
            rows: 256,
            cols: 64,
            r_wire_ohm: 1.0,
            g_lrs_us: 50.0,
            g_hrs_us: 0.5,
            levels: 128,
            v_read: 0.1,
            sigma_program: 0.015,
            sigma_read: 0.005,
        }
    }
}

impl ArrayConfig {
    /// Convenience: the Fig 12 sweep ties array size to G; everything else
    /// stays at the defaults.
    pub fn with_rows(rows: usize) -> Self {
        Self { rows, ..Self::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols == 0 {
            return Err(Error::Config("array must have rows and cols".into()));
        }
        if self.g_lrs_us <= self.g_hrs_us {
            return Err(Error::Config("G_LRS must exceed G_HRS".into()));
        }
        if self.levels < 2 {
            return Err(Error::Config("need >= 2 conductance levels".into()));
        }
        Ok(())
    }
}

/// A programmed crossbar: conductances in µS, row-major `[rows][col_pairs]`.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub cfg: ArrayConfig,
    /// Positive-BL conductances, `rows * cols`.
    pub g_pos: Vec<f64>,
    /// Negative-BL conductances, `rows * cols`.
    pub g_neg: Vec<f64>,
    /// Full-scale weight magnitude a single cell encodes.
    pub w_max: f64,
}

impl Crossbar {
    /// Program signed integer weights `w[row][col]` (flattened row-major)
    /// with `w_max` = the code magnitude mapped to full-scale conductance.
    pub fn program(cfg: ArrayConfig, weights: &[i32], rows: usize, cols: usize, w_max: f64) -> Result<Self> {
        cfg.validate()?;
        if weights.len() != rows * cols {
            return Err(Error::Shape(format!(
                "weights len {} != {rows}x{cols}",
                weights.len()
            )));
        }
        if rows > cfg.rows {
            return Err(Error::Config(format!(
                "{rows} rows exceed array size {}",
                cfg.rows
            )));
        }
        let span = cfg.g_lrs_us - cfg.g_hrs_us;
        let quant = |mag: f64| -> f64 {
            // MLC programming quantizes the target conductance to `levels`
            let lv = (mag * (cfg.levels - 1) as f64).round() / (cfg.levels - 1) as f64;
            cfg.g_hrs_us + lv * span
        };
        let mut g_pos = vec![cfg.g_hrs_us; cfg.rows * cols];
        let mut g_neg = vec![cfg.g_hrs_us; cfg.rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let w = weights[r * cols + c] as f64 / w_max;
                let mag = w.abs().min(1.0);
                if w >= 0.0 {
                    g_pos[r * cols + c] = quant(mag);
                } else {
                    g_neg[r * cols + c] = quant(mag);
                }
            }
        }
        Ok(Self { cfg, g_pos, g_neg, w_max })
    }

    pub fn cols(&self) -> usize {
        self.g_pos.len() / self.cfg.rows
    }

    /// Ideal differential MAC: `out[c] = Σ_r drive[r] · (G⁺ − G⁻) · V_read`
    /// in µA. `drives` are WL activations in [0, 1].
    pub fn mac_ideal(&self, drives: &[f64]) -> Vec<f64> {
        let cols = self.cols();
        let mut out = vec![0.0; cols];
        for (r, &d) in drives.iter().enumerate().take(self.cfg.rows) {
            if d == 0.0 {
                continue;
            }
            let base = r * cols;
            for c in 0..cols {
                out[c] += d * (self.g_pos[base + c] - self.g_neg[base + c]);
            }
        }
        for v in &mut out {
            *v *= self.cfg.v_read;
        }
        out
    }

    /// Convert a differential column current (µA) back to the weight-domain
    /// value it represents: `w · drive` summed over rows, in code units.
    pub fn current_to_code(&self, i_ua: f64) -> f64 {
        let span = self.cfg.g_lrs_us - self.cfg.g_hrs_us;
        i_ua / (self.cfg.v_read * span) * self.w_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_rejects_bad_shapes() {
        let cfg = ArrayConfig::with_rows(8);
        assert!(Crossbar::program(cfg, &[0; 7], 4, 2, 127.0).is_err());
        assert!(Crossbar::program(cfg, &[0; 32], 16, 2, 127.0).is_err()); // rows > array
    }

    #[test]
    fn ideal_mac_recovers_integer_dot_product() {
        let cfg = ArrayConfig { levels: 128, ..ArrayConfig::with_rows(8) };
        let w = vec![100, -50, 25, 0, -125, 13, 7, -7];
        let xb = Crossbar::program(cfg, &w, 8, 1, 127.0).unwrap();
        let drives = vec![1.0, 0.5, 0.25, 1.0, 0.1, 0.0, 1.0, 1.0];
        let i = xb.mac_ideal(&drives);
        let got = xb.current_to_code(i[0]);
        let want: f64 = w
            .iter()
            .zip(&drives)
            .map(|(&w, &d)| w as f64 * d)
            .sum();
        // MLC quantization (127 codes -> 127 levels) keeps this nearly exact
        assert!(
            (got - want).abs() < want.abs().max(1.0) * 0.02,
            "{got} vs {want}"
        );
    }

    #[test]
    fn differential_encoding_cancels_leakage() {
        // zero weights must produce (near) zero current despite G_HRS floor
        let cfg = ArrayConfig::with_rows(16);
        let w = vec![0i32; 16];
        let xb = Crossbar::program(cfg, &w, 16, 1, 127.0).unwrap();
        let i = xb.mac_ideal(&vec![1.0; 16]);
        assert!(i[0].abs() < 1e-9, "leakage current {}", i[0]);
    }

    #[test]
    fn mlc_quantization_error_bounded() {
        let cfg = ArrayConfig { levels: 16, ..ArrayConfig::with_rows(4) };
        let w = vec![37, -90, 5, 127];
        let xb = Crossbar::program(cfg, &w, 4, 1, 127.0).unwrap();
        for (r, &wv) in w.iter().enumerate() {
            let drives: Vec<f64> = (0..4).map(|i| if i == r { 1.0 } else { 0.0 }).collect();
            let got = xb.current_to_code(xb.mac_ideal(&drives)[0]);
            // 16 levels over 127 codes -> max error ~ 127/(2*15) ≈ 4.2
            assert!((got - wv as f64).abs() <= 5.0, "row {r}: {got} vs {wv}");
        }
    }
}
