//! "Measured-chip" calibration statistics.
//!
//! The paper calibrates its error injection against IR-drop / MAC-error
//! statistics measured from TSMC 22 nm RRAM-ACIM prototype chips [13] for
//! array sizes 128-1024. Those measurements are not public; this module
//! *generates* the equivalent tables from the resistive-ladder model by
//! Monte-Carlo over representative workloads (DESIGN.md §4). The rest of
//! the system consumes the statistics through the same interface the
//! paper's flow did — per-array-size, per-row-distance error magnitudes —
//! so swapping in real silicon data would be a one-file change.

use super::array::{ArrayConfig, Crossbar};
use super::irdrop::mac_with_irdrop;
use super::noise::NoiseModel;
use crate::util::Rng;

/// MAC-error statistics for one array size.
#[derive(Debug, Clone)]
pub struct ArrayStats {
    pub rows: usize,
    /// Mean relative MAC error (signed; negative = attenuation).
    pub mean_rel_error: f64,
    /// Std-dev of the relative MAC error.
    pub sigma_rel_error: f64,
    /// Relative attenuation per row-distance decile (10 buckets, bucket 0 =
    /// nearest the clamp). The monotone decay of this profile is what
    /// KAN-SAM exploits.
    pub row_attenuation: Vec<f64>,
}

/// Generate calibration stats for an array size by Monte-Carlo over random
/// sparse workloads (the B(X)-like drive pattern: a small fraction of rows
/// active at fractional drive levels).
pub fn calibrate(rows: usize, seed: u64, trials: usize) -> ArrayStats {
    let cfg = ArrayConfig::with_rows(rows);
    let mut rng = Rng::new(seed);
    let mut rel_errors = Vec::with_capacity(trials);

    // per-decile single-row attenuation (measured with one row driven)
    let w_full = vec![100i32; rows];
    let xb_full = Crossbar::program(cfg, &w_full, rows, 1, 127.0).unwrap();
    let mut row_attenuation = Vec::with_capacity(10);
    for d in 0..10 {
        let r = ((d as f64 + 0.5) / 10.0 * rows as f64) as usize;
        let mut drives = vec![0.0; rows];
        drives[r.min(rows - 1)] = 1.0;
        // background activity: 20% of rows at drive 0.25, like a busy layer
        for i in (0..rows).step_by(5) {
            if i != r {
                drives[i] = 0.25;
            }
        }
        let ideal_all = xb_full.mac_ideal(&drives)[0];
        let real_all = mac_with_irdrop(&xb_full, &drives)[0];
        // subtract the background contribution measured separately
        drives[r.min(rows - 1)] = 0.0;
        let ideal_bg = xb_full.mac_ideal(&drives)[0];
        let real_bg = mac_with_irdrop(&xb_full, &drives)[0];
        let ideal_row = ideal_all - ideal_bg;
        let real_row = real_all - real_bg;
        row_attenuation.push(if ideal_row.abs() > 1e-12 {
            real_row / ideal_row
        } else {
            1.0
        });
    }

    for t in 0..trials {
        // random signed weights, sparse fractional drives
        let w: Vec<i32> = (0..rows).map(|_| rng.int_range(-127, 127) as i32).collect();
        let mut xb = Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        let mut nm = NoiseModel::from_config(seed.wrapping_add(t as u64), &cfg);
        nm.apply_programming_variation(&mut xb);
        let drives: Vec<f64> = (0..rows)
            .map(|_| {
                if rng.uniform() < 0.2 {
                    rng.uniform()
                } else {
                    0.0
                }
            })
            .collect();
        let ideal = xb.mac_ideal(&drives)[0];
        let real = nm.read_noise(mac_with_irdrop(&xb, &drives)[0]);
        // normalize by the full-scale current of the active rows
        let scale = drives.iter().sum::<f64>().max(1.0)
            * xb.cfg.g_lrs_us
            * xb.cfg.v_read;
        rel_errors.push((real - ideal) / scale);
    }

    let n = rel_errors.len() as f64;
    let mean = rel_errors.iter().sum::<f64>() / n;
    let var = rel_errors.iter().map(|e| (e - mean).powi(2)).sum::<f64>() / n;
    ArrayStats {
        rows,
        mean_rel_error: mean,
        sigma_rel_error: var.sqrt(),
        row_attenuation,
    }
}

/// The paper's Fig 12 array-size axis with pre-computed statistics.
pub fn measured_table(seed: u64) -> Vec<ArrayStats> {
    [128usize, 256, 512, 1024]
        .iter()
        .map(|&rows| calibrate(rows, seed, 200))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_sigma_grows_with_array_size() {
        let table = measured_table(11);
        for w in table.windows(2) {
            assert!(
                w[1].sigma_rel_error + w[1].mean_rel_error.abs()
                    >= w[0].sigma_rel_error + w[0].mean_rel_error.abs(),
                "{}->{} error shrank",
                w[0].rows,
                w[1].rows
            );
        }
    }

    #[test]
    fn attenuation_profile_decays_with_distance() {
        let stats = calibrate(512, 5, 50);
        let first = stats.row_attenuation[0];
        let last = *stats.row_attenuation.last().unwrap();
        assert!(
            last < first,
            "far rows should attenuate more: near={first} far={last}"
        );
        for a in &stats.row_attenuation {
            assert!(*a > 0.0 && *a <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn mean_error_is_attenuation_dominated() {
        // IR-drop strictly removes current, so the mean relative error of
        // the aggregate MAC should be <= 0 (read noise is zero-mean)
        let stats = calibrate(1024, 9, 100);
        assert!(stats.mean_rel_error < 0.01);
    }
}
