//! Device non-idealities: programming variation and cycle-to-cycle read
//! noise, with a deterministic (seeded) RNG so experiments are repeatable.
//!
//! The paper injects "partial sum errors ... evaluated with the statistics
//! measured from the TSMC 22nm RRAM-ACIM prototype chips". We reproduce the
//! *mechanism* (per-cell multiplicative conductance error + per-read noise)
//! with magnitudes in the published range for 22 nm RRAM (σ ≈ 1-2%
//! programming, ≈ 0.5% read); DESIGN.md §4 records the substitution.

use super::array::Crossbar;
use crate::util::Rng;

/// Deterministic noise source for ACIM simulation.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    rng: Rng,
    pub sigma_program: f64,
    pub sigma_read: f64,
}

impl NoiseModel {
    pub fn new(seed: u64, sigma_program: f64, sigma_read: f64) -> Self {
        Self { rng: Rng::new(seed), sigma_program, sigma_read }
    }

    pub fn from_config(seed: u64, cfg: &super::array::ArrayConfig) -> Self {
        Self::new(seed, cfg.sigma_program, cfg.sigma_read)
    }

    /// Standard normal from the crate PRNG.
    fn standard_normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Apply one-time programming variation to a crossbar's conductances
    /// (multiplicative log-normal-ish error, clamped at ±4σ).
    pub fn apply_programming_variation(&mut self, xb: &mut Crossbar) {
        let sp = self.sigma_program;
        for g in xb.g_pos.iter_mut().chain(xb.g_neg.iter_mut()) {
            let e = self.standard_normal().clamp(-4.0, 4.0);
            *g *= 1.0 + sp * e;
            *g = g.max(0.0);
        }
    }

    /// Per-read multiplicative noise on a column current.
    pub fn read_noise(&mut self, i_ua: f64) -> f64 {
        let e = self.standard_normal().clamp(-4.0, 4.0);
        i_ua * (1.0 + self.sigma_read * e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acim::array::{ArrayConfig, Crossbar};

    #[test]
    fn deterministic_given_seed() {
        let mut a = NoiseModel::new(42, 0.02, 0.01);
        let mut b = NoiseModel::new(42, 0.02, 0.01);
        for _ in 0..100 {
            assert_eq!(a.read_noise(10.0), b.read_noise(10.0));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = NoiseModel::new(1, 0.02, 0.01);
        let mut b = NoiseModel::new(2, 0.02, 0.01);
        let va: Vec<f64> = (0..10).map(|_| a.read_noise(10.0)).collect();
        let vb: Vec<f64> = (0..10).map(|_| b.read_noise(10.0)).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn noise_statistics_match_sigma() {
        let mut nm = NoiseModel::new(7, 0.0, 0.05);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| nm.read_noise(1.0) - 1.0).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.002, "mean {mean}");
        assert!((var.sqrt() - 0.05).abs() < 0.004, "sigma {}", var.sqrt());
    }

    #[test]
    fn programming_variation_perturbs_but_preserves_scale() {
        let cfg = ArrayConfig::with_rows(64);
        let w = vec![64i32; 64];
        let mut xb = Crossbar::program(cfg, &w, 64, 1, 127.0).unwrap();
        let before: f64 = xb.g_pos.iter().sum();
        let mut nm = NoiseModel::new(3, 0.02, 0.0);
        nm.apply_programming_variation(&mut xb);
        let after: f64 = xb.g_pos.iter().sum();
        assert_ne!(before, after);
        assert!((after / before - 1.0).abs() < 0.02);
        assert!(xb.g_pos.iter().all(|&g| g >= 0.0));
    }
}
