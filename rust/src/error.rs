//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for the kan-edge crate.
#[derive(Debug, Error)]
pub enum Error {
    /// Invalid configuration or hyperparameters (e.g. `G > 2^n`).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// Artifact files missing or malformed (run `make artifacts`).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Shape mismatch in tensor plumbing.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Serving-path failure (queue closed, admission rejected, ...).
    #[error("serving error: {0}")]
    Serving(String),

    /// JSON parse / schema error.
    #[error("json error: {0}")]
    Json(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
