//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline build image carries no
//! thiserror); the variants and messages are part of the crate's public
//! contract — tests assert on their wording.

use std::fmt;

/// Unified error type for the kan-edge crate.
#[derive(Debug)]
pub enum Error {
    /// Invalid configuration or hyperparameters (e.g. `G > 2^n`).
    Config(String),

    /// Artifact files missing or malformed (run `make artifacts`).
    Artifact(String),

    /// Shape mismatch in tensor plumbing.
    Shape(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Serving-path failure (queue closed, backend failure, ...).
    Serving(String),

    /// Admission control rejected the request (queue or per-client quota
    /// full). Carries a best-effort client backoff hint so the wire
    /// layers can surface a structured `retry_after_ms` field.
    Overloaded { message: String, retry_after_ms: u64 },

    /// JSON parse / schema error.
    Json(String),

    /// Model-registry failure (manifest schema, digest mismatch, routing).
    Registry(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serving(m) => write!(f, "serving error: {m}"),
            Error::Overloaded { message, retry_after_ms } => {
                write!(f, "overloaded: {message} (retry after ~{retry_after_ms} ms)")
            }
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Registry(m) => write!(f, "registry error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_are_stable() {
        assert!(Error::Config("x".into()).to_string().starts_with("invalid configuration"));
        assert!(Error::Registry("x".into()).to_string().starts_with("registry error"));
        assert!(Error::Json("x".into()).to_string().starts_with("json error"));
    }

    #[test]
    fn overloaded_carries_retry_hint() {
        let e = Error::Overloaded {
            message: "client quota exceeded (4/4 rows in queue)".into(),
            retry_after_ms: 7,
        };
        let s = e.to_string();
        assert!(s.starts_with("overloaded:"), "{s}");
        assert!(s.contains("~7 ms"), "{s}");
    }

    #[test]
    fn io_errors_pass_through() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
    }
}
