//! Rollout plane: staged canary deployments with SLO-gated
//! auto-promote and instant auto-rollback.
//!
//! The quant/NeuroSim co-search emits a stream of model variants, and
//! the shadow plane already measures the safety signals that matter
//! (argmax-flip rate, logit MAE, latency quantiles) — this module is
//! the controller that *acts* on them. A rollout pairs the manifest's
//! current version (the **candidate**) with the previously-live
//! pipeline (the **baseline**, retained warm by the registry at
//! hot-swap time) and drives the state machine
//!
//! ```text
//! Ramping(fraction) → … → Observing → Promoted
//!        └──────────────── any gate breach ───────→ RolledBack
//! ```
//!
//! * **Ramping** — a deterministic counter-based splitter (same
//!   floor-fraction idiom as the shadow sampler: exact fractions, no
//!   RNG on the serving path) sends `ramp[step]` of the model's default
//!   traffic to the candidate and the remainder to the baseline. Every
//!   candidate-served row is also mirrored off the response path onto
//!   the baseline to measure divergence.
//! * **Observing** — the final full-traffic window (fraction 1.0)
//!   before promotion.
//! * Each window, the SLO gates from [`crate::config::RolloutConfig`]
//!   are evaluated over that window's samples only (the divergence
//!   metrics are keyed to this (baseline, candidate) pair and reset at
//!   every window boundary, so no decision ever inherits another
//!   pair's — or another window's — reservoirs). All gates green for a
//!   full window advances the ramp; the last window promotes the
//!   candidate (it is already the manifest default, so promotion simply
//!   retires the override). Any breach instantly repoints **all**
//!   default traffic to the pinned baseline and records why.
//! * **Promoted / RolledBack** are terminal; the registry unpins the
//!   versions it pinned at start. A rolled-back rollout keeps routing
//!   to the baseline until the operator clears it or publishes a fix.
//!
//! Requests that pin an explicit version (`name@v`) bypass the
//! splitter: an operator probing a specific version must see exactly
//! that version.
//!
//! Everything surfaces on the control plane (`rollout_*` verbs), in
//! `kan-edge models`, in per-model metrics reports, and as
//! `kan_edge_rollout_*` Prometheus series. See `docs/ROLLOUT.md`.

pub mod controller;

pub use controller::{Rollout, RolloutPlane, Split, TickOutcome};

use crate::util::json::{arr, obj, Value};

/// Where a rollout's state machine currently is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutPhase {
    /// Splitting `ramp[step]` of default traffic onto the candidate.
    Ramping { step: usize },
    /// Final full-traffic window before promotion.
    Observing,
    /// Terminal: the candidate passed every window; it keeps serving as
    /// the manifest default with no override.
    Promoted,
    /// Terminal: a gate breached (or an operator aborted); all default
    /// traffic is repointed to the baseline.
    RolledBack,
}

impl RolloutPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            RolloutPhase::Ramping { .. } => "ramping",
            RolloutPhase::Observing => "observing",
            RolloutPhase::Promoted => "promoted",
            RolloutPhase::RolledBack => "rolled_back",
        }
    }

    /// Stable numeric encoding for Prometheus series
    /// (`kan_edge_rollout_phase_code`): 0 ramping, 1 observing,
    /// 2 promoted, 3 rolled back.
    pub fn code(&self) -> i64 {
        match self {
            RolloutPhase::Ramping { .. } => 0,
            RolloutPhase::Observing => 1,
            RolloutPhase::Promoted => 2,
            RolloutPhase::RolledBack => 3,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, RolloutPhase::Promoted | RolloutPhase::RolledBack)
    }
}

/// One gate evaluation inside a window decision.
#[derive(Debug, Clone)]
pub struct GateEval {
    /// Config key of the gate (`max_flip_rate`, `max_logit_mae_p99`,
    /// `max_latency_regression`).
    pub gate: &'static str,
    pub observed: f64,
    pub limit: f64,
    pub pass: bool,
}

impl GateEval {
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("gate", Value::Str(self.gate.to_string())),
            ("observed", Value::Float(self.observed)),
            ("limit", Value::Float(self.limit)),
            ("pass", Value::Bool(self.pass)),
        ])
    }
}

/// One recorded state-machine decision (bounded history; newest last).
#[derive(Debug, Clone)]
pub struct Decision {
    /// Milliseconds since the rollout started.
    pub at_ms: u64,
    /// Phase the decision moved the rollout *into*.
    pub phase: &'static str,
    /// Canary traffic fraction after the decision.
    pub fraction: f64,
    /// `start` | `advance` | `promote` | `rollback` | `abort`.
    pub action: &'static str,
    pub reason: String,
    /// The per-gate evaluations that drove the decision (empty for
    /// `start`/`abort`).
    pub gates: Vec<GateEval>,
}

impl Decision {
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("at_ms", Value::Int(self.at_ms as i64)),
            ("phase", Value::Str(self.phase.to_string())),
            ("fraction", Value::Float(self.fraction)),
            ("action", Value::Str(self.action.to_string())),
            ("reason", Value::Str(self.reason.clone())),
            ("gates", arr(self.gates.iter().map(|g| g.to_value()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_encoding_is_stable() {
        assert_eq!(RolloutPhase::Ramping { step: 3 }.as_str(), "ramping");
        assert_eq!(RolloutPhase::Observing.code(), 1);
        assert_eq!(RolloutPhase::Promoted.code(), 2);
        assert_eq!(RolloutPhase::RolledBack.code(), 3);
        assert!(RolloutPhase::Promoted.is_terminal());
        assert!(RolloutPhase::RolledBack.is_terminal());
        assert!(!RolloutPhase::Ramping { step: 0 }.is_terminal());
        assert!(!RolloutPhase::Observing.is_terminal());
    }

    #[test]
    fn decision_serializes() {
        let d = Decision {
            at_ms: 1200,
            phase: "rolled_back",
            fraction: 0.0,
            action: "rollback",
            reason: "gate max_flip_rate breached".into(),
            gates: vec![GateEval {
                gate: "max_flip_rate",
                observed: 0.4,
                limit: 0.01,
                pass: false,
            }],
        };
        let v = d.to_value();
        assert_eq!(v.get("action").and_then(|a| a.as_str()), Some("rollback"));
        let gates = v.get("gates").and_then(|g| g.as_array()).map(|g| g.len());
        assert_eq!(gates, Some(1));
    }
}
