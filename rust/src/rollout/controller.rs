//! The rollout controller: per-model staged-deployment state machines
//! plus the deterministic traffic splitter the dispatch path consults.
//!
//! Ownership: the [`crate::registry::ModelRegistry`] owns one
//! [`RolloutPlane`]; the registry's dispatch path calls
//! [`RolloutPlane::route`] per default-routed request, and a per-rollout
//! driver thread calls [`RolloutPlane::tick`] to expire observation
//! windows. Pin/unpin and baseline retention stay in the registry — the
//! plane only decides, it never loads or evicts models.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::{Decision, GateEval, RolloutPhase};
use crate::config::RolloutConfig;
use crate::coordinator::backend::{BackendKind, ExecOptions};
use crate::coordinator::metrics::{Metrics, ShadowMetrics};
use crate::coordinator::shadow::{ShadowExec, ShadowState};
use crate::error::{Error, Result};
use crate::registry::ServedModel;
use crate::util::json::{arr, obj, Value};
use crate::util::sync::{LockExt, RwLockExt};

/// Bounded decision history per rollout (newest kept).
const MAX_DECISIONS: usize = 64;

// Phase codes mirrored into an atomic so the splitter never takes the
// state lock; values match [`RolloutPhase::code`].
const CODE_PROMOTED: usize = 2;
const CODE_ROLLED_BACK: usize = 3;

/// Which side of the split serves a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Canary,
    Baseline,
}

/// What one controller tick did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickOutcome {
    /// No rollout for that model (the driver should stop).
    Gone,
    /// Window still open, or the rollout is already terminal.
    Idle,
    /// Window expired without enough canary samples; extended.
    Extended,
    /// All gates passed; ramp advanced to the next step.
    Advanced,
    Promoted,
    RolledBack,
}

/// Mutable state-machine state, guarded by one mutex (never held across
/// an inference call or any blocking work).
struct State {
    phase: RolloutPhase,
    window_started: Instant,
    /// Windows evaluated (decisions made).
    windows: u64,
    /// Windows that expired without `min_samples` canary rows.
    windows_extended: u64,
    /// Per-window latency stats, one per side; replaced wholesale at
    /// every window boundary so a window's percentiles never mix with
    /// the previous window's.
    canary_win: Arc<Metrics>,
    baseline_win: Arc<Metrics>,
    /// Carried-forward baseline p99 (µs): the latency-regression
    /// reference when the current window starves the baseline (e.g. the
    /// full-traffic `Observing` window).
    baseline_p99_ref_us: Option<u64>,
    decisions: Vec<Decision>,
}

/// One staged deployment: `baseline_id → candidate_id` for `name`.
pub struct Rollout {
    pub name: String,
    pub baseline_id: String,
    pub candidate_id: String,
    cfg: RolloutConfig,
    /// The previously-live pipeline, retained warm so a rollback is an
    /// atomic repoint (and so LRU eviction can never race it). Dropped
    /// on promotion.
    baseline: Mutex<Option<Arc<ServedModel>>>,
    /// Off-response-path divergence mirror: every candidate-served row
    /// is re-executed by the baseline and compared.
    mirror: Arc<ShadowState>,
    /// Cumulative divergence for this (baseline, candidate) pair —
    /// created fresh per rollout, so a new rollout never inherits a
    /// previous candidate's flip/MAE reservoirs.
    div_cum: Arc<ShadowMetrics>,
    /// Current-window divergence; reset at every window boundary.
    div_win: Arc<ShadowMetrics>,
    started: Instant,
    /// Splitter counter (the shadow sampler's floor-fraction idiom).
    seen: AtomicU64,
    /// Current canary fraction as f64 bits, for lock-free splits.
    fraction_bits: AtomicU64,
    /// Mirror of `State::phase` for lock-free routing.
    phase_code: AtomicUsize,
    canary_requests: AtomicU64,
    baseline_requests: AtomicU64,
    /// Set by the registry when it pinned the model at start (so it
    /// only unpins what it pinned), cleared once terminal cleanup ran.
    pub needs_cleanup: AtomicBool,
    state: Mutex<State>,
}

fn fraction_for(cfg: &RolloutConfig, phase: RolloutPhase) -> f64 {
    match phase {
        RolloutPhase::Ramping { step } => {
            cfg.ramp.get(step).copied().unwrap_or(1.0).clamp(0.0, 1.0)
        }
        RolloutPhase::Observing | RolloutPhase::Promoted => 1.0,
        RolloutPhase::RolledBack => 0.0,
    }
}

impl Rollout {
    /// Build a rollout in its initial phase. `exec` runs one mirrored
    /// row on the baseline and compares it against the candidate's
    /// served logits (constructed by the registry, which knows how to
    /// run inference); `mirror_kind` is the baseline's backend kind
    /// (control-plane visibility only).
    pub fn new(
        name: &str,
        baseline: Arc<ServedModel>,
        candidate_id: &str,
        mirror_kind: BackendKind,
        mut exec: ShadowExec,
        cfg: &RolloutConfig,
    ) -> Arc<Rollout> {
        let baseline_id = baseline.id.clone();
        let div_cum = Arc::new(ShadowMetrics::new());
        let div_win = Arc::new(ShadowMetrics::new());
        // the wrapper double-records each observation into the window
        // metrics; the mirror worker itself records into the cumulative
        // pair metrics it owns
        let win = div_win.clone();
        let wrapped: ShadowExec = Box::new(move |job| match exec(job) {
            Ok(obs) => {
                win.record_mirror(obs.flip, obs.mae, &obs.layer_err);
                Ok(obs)
            }
            Err(e) => {
                win.record_error();
                Err(e)
            }
        });
        let mirror = ShadowState::spawn_with_metrics(
            mirror_kind,
            1.0,
            cfg.queue,
            wrapped,
            div_cum.clone(),
        );
        let phase = if cfg.ramp.is_empty() {
            RolloutPhase::Observing
        } else {
            RolloutPhase::Ramping { step: 0 }
        };
        let fraction = fraction_for(cfg, phase);
        let start = Decision {
            at_ms: 0,
            phase: phase.as_str(),
            fraction,
            action: "start",
            reason: format!("rollout {baseline_id} -> {candidate_id}"),
            gates: Vec::new(),
        };
        Arc::new(Rollout {
            name: name.to_string(),
            baseline_id,
            candidate_id: candidate_id.to_string(),
            cfg: cfg.clone(),
            baseline: Mutex::new(Some(baseline)),
            mirror,
            div_cum,
            div_win,
            started: Instant::now(),
            seen: AtomicU64::new(0),
            fraction_bits: AtomicU64::new(fraction.to_bits()),
            phase_code: AtomicUsize::new(phase.code() as usize),
            canary_requests: AtomicU64::new(0),
            baseline_requests: AtomicU64::new(0),
            needs_cleanup: AtomicBool::new(true),
            state: Mutex::new(State {
                phase,
                window_started: Instant::now(),
                windows: 0,
                windows_extended: 0,
                canary_win: Arc::new(Metrics::new()),
                baseline_win: Arc::new(Metrics::new()),
                baseline_p99_ref_us: None,
                decisions: vec![start],
            }),
        })
    }

    pub fn phase(&self) -> RolloutPhase {
        self.state.lock_recover().phase
    }

    pub fn is_terminal(&self) -> bool {
        self.phase().is_terminal()
    }

    /// Current canary fraction (lock-free).
    pub fn fraction(&self) -> f64 {
        f64::from_bits(self.fraction_bits.load(Ordering::Relaxed))
    }

    /// Route one default-routed request. Deterministic counter-based
    /// splitter: request `n` goes to the canary when the cumulative
    /// target `floor((n+1)·f)` advances — exactly a fraction `f`,
    /// evenly spread, no RNG on the serving path. Rolled-back rollouts
    /// send everything to the baseline.
    pub fn split(&self) -> Split {
        match self.phase_code.load(Ordering::Relaxed) {
            CODE_ROLLED_BACK => Split::Baseline,
            CODE_PROMOTED => Split::Canary,
            _ => {
                let n = self.seen.fetch_add(1, Ordering::Relaxed);
                let f = self.fraction();
                if ((n + 1) as f64 * f).floor() > (n as f64 * f).floor() {
                    Split::Canary
                } else {
                    Split::Baseline
                }
            }
        }
    }

    /// The retained baseline pipeline (`None` once promoted).
    pub fn baseline_model(&self) -> Option<Arc<ServedModel>> {
        self.baseline.lock_recover().clone()
    }

    /// Record a candidate-served request's latency into the current
    /// window.
    pub fn record_canary(&self, latency: Duration) {
        self.canary_requests.fetch_add(1, Ordering::Relaxed);
        let m = self.state.lock_recover().canary_win.clone();
        m.record_request(latency, Duration::ZERO);
    }

    /// Record a baseline-served request's latency into the current
    /// window.
    pub fn record_baseline(&self, latency: Duration) {
        self.baseline_requests.fetch_add(1, Ordering::Relaxed);
        let m = self.state.lock_recover().baseline_win.clone();
        m.record_request(latency, Duration::ZERO);
    }

    /// Queue a candidate-served row for off-path divergence mirroring
    /// on the baseline (non-blocking; overflow drops and counts).
    pub fn mirror_canary(&self, features: Vec<f32>, canary: Vec<f32>, opts: ExecOptions) {
        self.mirror.enqueue(features, canary, opts);
    }

    /// Evaluate the current window if it has expired. Called by the
    /// driver thread; safe to call concurrently (single state lock).
    pub fn evaluate(&self) -> TickOutcome {
        let mut g = self.state.lock_recover();
        if g.phase.is_terminal() {
            return TickOutcome::Idle;
        }
        if g.window_started.elapsed() < Duration::from_millis(self.cfg.window_ms) {
            return TickOutcome::Idle;
        }
        let canary = g.canary_win.report();
        let baseline = g.baseline_win.report();
        // refresh the carried-forward latency reference whenever the
        // baseline side saw enough traffic this window
        if baseline.requests >= self.cfg.min_samples as u64 {
            g.baseline_p99_ref_us = Some(baseline.latency_p99_us);
        }
        if canary.requests < self.cfg.min_samples as u64 {
            // not enough evidence to decide either way — extend (at
            // fraction 0.0 this is the steady state: the splitter runs
            // but the canary never accumulates samples)
            g.windows_extended += 1;
            g.window_started = Instant::now();
            return TickOutcome::Extended;
        }
        let div = self.div_win.report();
        let lat_ratio = match g.baseline_p99_ref_us {
            Some(b) if b > 0 => canary.latency_p99_us as f64 / b as f64,
            // no baseline reference yet: the latency gate cannot
            // evaluate, and divergence gates carry the window
            _ => 0.0,
        };
        let gates = vec![
            GateEval {
                gate: "max_flip_rate",
                observed: div.flip_rate,
                limit: self.cfg.max_flip_rate,
                pass: div.flip_rate <= self.cfg.max_flip_rate,
            },
            GateEval {
                gate: "max_logit_mae_p99",
                observed: div.logit_mae_p99,
                limit: self.cfg.max_logit_mae_p99,
                pass: div.logit_mae_p99 <= self.cfg.max_logit_mae_p99,
            },
            GateEval {
                gate: "max_latency_regression",
                observed: lat_ratio,
                limit: self.cfg.max_latency_regression,
                pass: lat_ratio <= self.cfg.max_latency_regression,
            },
        ];
        g.windows += 1;
        if let Some(breach) = gates.iter().find(|x| !x.pass) {
            let reason = format!(
                "gate {} breached: observed {:.6} > limit {:.6}",
                breach.gate, breach.observed, breach.limit
            );
            self.transition(&mut g, RolloutPhase::RolledBack, "rollback", reason, gates);
            return TickOutcome::RolledBack;
        }
        let (next, action, outcome) = match g.phase {
            RolloutPhase::Ramping { step } if step + 1 < self.cfg.ramp.len() => (
                RolloutPhase::Ramping { step: step + 1 },
                "advance",
                TickOutcome::Advanced,
            ),
            RolloutPhase::Ramping { .. } => {
                (RolloutPhase::Observing, "advance", TickOutcome::Advanced)
            }
            RolloutPhase::Observing => {
                (RolloutPhase::Promoted, "promote", TickOutcome::Promoted)
            }
            // unreachable: terminal phases returned above
            other => (other, "advance", TickOutcome::Idle),
        };
        let reason = "all gates passed for a full window".to_string();
        self.transition(&mut g, next, action, reason, gates);
        if next == RolloutPhase::Promoted {
            // promotion retires the override entirely: the candidate is
            // already the manifest default, so the warm baseline can go
            *self.baseline.lock_recover() = None;
        }
        outcome
    }

    /// Operator-initiated instant rollback (`rollout_abort`).
    pub fn abort(&self, reason: &str) -> Result<()> {
        let mut g = self.state.lock_recover();
        if g.phase.is_terminal() {
            return Err(Error::Serving(format!(
                "rollout for '{}' already finished: {}",
                self.name,
                g.phase.as_str()
            )));
        }
        self.transition(
            &mut g,
            RolloutPhase::RolledBack,
            "abort",
            reason.to_string(),
            Vec::new(),
        );
        Ok(())
    }

    /// Move to `to`, record the decision, and open a fresh window (new
    /// latency stats, divergence window reset).
    fn transition(
        &self,
        g: &mut State,
        to: RolloutPhase,
        action: &'static str,
        reason: String,
        gates: Vec<GateEval>,
    ) {
        g.phase = to;
        let fraction = fraction_for(&self.cfg, to);
        self.fraction_bits.store(fraction.to_bits(), Ordering::Relaxed);
        self.phase_code.store(to.code() as usize, Ordering::Relaxed);
        g.window_started = Instant::now();
        g.canary_win = Arc::new(Metrics::new());
        g.baseline_win = Arc::new(Metrics::new());
        self.div_win.reset();
        g.decisions.push(Decision {
            at_ms: self.started.elapsed().as_millis() as u64,
            phase: to.as_str(),
            fraction,
            action,
            reason,
            gates,
        });
        if g.decisions.len() > MAX_DECISIONS {
            let excess = g.decisions.len() - MAX_DECISIONS;
            g.decisions.drain(..excess);
        }
    }

    /// Full status (state machine, window, cumulative divergence,
    /// decision history) — the `rollout_status` body for this model.
    pub fn status_value(&self) -> Value {
        let (phase, windows, extended, ref_us, decisions, canary_win, baseline_win) = {
            let g = self.state.lock_recover();
            (
                g.phase,
                g.windows,
                g.windows_extended,
                g.baseline_p99_ref_us,
                g.decisions.clone(),
                g.canary_win.clone(),
                g.baseline_win.clone(),
            )
        };
        // reports snapshot internally; never under the state lock
        let cw = canary_win.report();
        let bw = baseline_win.report();
        let step = match phase {
            RolloutPhase::Ramping { step } => step as i64,
            _ => self.cfg.ramp.len() as i64,
        };
        let mut fields = vec![
            ("model", Value::Str(self.name.clone())),
            ("baseline", Value::Str(self.baseline_id.clone())),
            ("candidate", Value::Str(self.candidate_id.clone())),
            (
                "pair",
                Value::Str(format!("{}->{}", self.baseline_id, self.candidate_id)),
            ),
            ("phase", Value::Str(phase.as_str().to_string())),
            ("phase_code", Value::Int(phase.code())),
            ("step", Value::Int(step)),
            ("steps", Value::Int(self.cfg.ramp.len() as i64)),
            ("fraction", Value::Float(self.fraction())),
            ("windows", Value::Int(windows as i64)),
            ("windows_extended", Value::Int(extended as i64)),
            (
                "canary_requests",
                Value::Int(self.canary_requests.load(Ordering::Relaxed) as i64),
            ),
            (
                "baseline_requests",
                Value::Int(self.baseline_requests.load(Ordering::Relaxed) as i64),
            ),
            ("elapsed_ms", Value::Int(self.started.elapsed().as_millis() as i64)),
            ("divergence", self.div_cum.report().to_value()),
            (
                "window",
                obj(vec![
                    ("canary_requests", Value::Int(cw.requests as i64)),
                    ("baseline_requests", Value::Int(bw.requests as i64)),
                    ("canary_p99_us", Value::Int(cw.latency_p99_us as i64)),
                    (
                        "baseline_p99_ref_us",
                        match ref_us {
                            Some(us) => Value::Int(us as i64),
                            None => Value::Null,
                        },
                    ),
                ]),
            ),
        ];
        fields.push((
            "decisions",
            arr(decisions.iter().map(|d| d.to_value()).collect()),
        ));
        obj(fields)
    }

    /// Numeric-only summary for the Prometheus-rendered `rollout`
    /// metrics section (no decision history — histories are served by
    /// `rollout_status`, not scraped).
    pub fn prom_value(&self) -> Value {
        let (phase, windows, extended) = {
            let g = self.state.lock_recover();
            (g.phase, g.windows, g.windows_extended)
        };
        let div = self.div_cum.report();
        obj(vec![
            ("phase_code", Value::Int(phase.code())),
            ("fraction", Value::Float(self.fraction())),
            ("windows", Value::Int(windows as i64)),
            ("windows_extended", Value::Int(extended as i64)),
            (
                "canary_requests",
                Value::Int(self.canary_requests.load(Ordering::Relaxed) as i64),
            ),
            (
                "baseline_requests",
                Value::Int(self.baseline_requests.load(Ordering::Relaxed) as i64),
            ),
            ("flip_rate", Value::Float(div.flip_rate)),
            ("logit_mae_p99", Value::Float(div.logit_mae_p99)),
            ("mirror_dropped", Value::Int(div.dropped as i64)),
            ("mirror_errors", Value::Int(div.errors as i64)),
        ])
    }
}

/// All rollouts on this node, keyed by model name (at most one per
/// model — a model cannot ramp two candidates at once).
pub struct RolloutPlane {
    cfg: RolloutConfig,
    entries: RwLock<BTreeMap<String, Arc<Rollout>>>,
    /// Count of entries that still override routing (anything but
    /// `Promoted`); lets the dispatch fast path skip the map read
    /// entirely when no rollout is running.
    routing: AtomicUsize,
}

impl RolloutPlane {
    pub fn new(cfg: RolloutConfig) -> Self {
        Self {
            cfg,
            entries: RwLock::new(BTreeMap::new()),
            routing: AtomicUsize::new(0),
        }
    }

    pub fn cfg(&self) -> &RolloutConfig {
        &self.cfg
    }

    fn recount(&self, g: &BTreeMap<String, Arc<Rollout>>) {
        let n = g
            .values()
            .filter(|r| r.phase_code.load(Ordering::Relaxed) != CODE_PROMOTED)
            .count();
        self.routing.store(n, Ordering::Relaxed);
    }

    /// Start a rollout for `name`. Fails if one is already in progress;
    /// a terminal record is replaced (with fresh pair-keyed divergence
    /// metrics — nothing is inherited).
    pub fn start(
        &self,
        name: &str,
        baseline: Arc<ServedModel>,
        candidate_id: &str,
        mirror_kind: BackendKind,
        exec: ShadowExec,
    ) -> Result<Arc<Rollout>> {
        let mut g = self.entries.write_recover();
        if let Some(existing) = g.get(name) {
            if !existing.is_terminal() {
                return Err(Error::Serving(format!(
                    "rollout already in progress for '{name}' ({} -> {})",
                    existing.baseline_id, existing.candidate_id
                )));
            }
        }
        let ro = Rollout::new(name, baseline, candidate_id, mirror_kind, exec, &self.cfg);
        g.insert(name.to_string(), ro.clone());
        self.recount(&g);
        Ok(ro)
    }

    /// The rollout for `name`, if any (terminal records included).
    pub fn get(&self, name: &str) -> Option<Arc<Rollout>> {
        self.entries.read_recover().get(name).cloned()
    }

    /// The rollout currently overriding `name`'s routing, if any
    /// (everything but `Promoted` overrides). The fast path is a single
    /// relaxed load when nothing is rolling out.
    pub fn active(&self, name: &str) -> Option<Arc<Rollout>> {
        if self.routing.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let ro = self.entries.read_recover().get(name).cloned()?;
        if ro.phase_code.load(Ordering::Relaxed) == CODE_PROMOTED {
            return None;
        }
        Some(ro)
    }

    /// Routing decision for one default-routed request on `name`.
    /// `None` means serve normally (no rollout, or promoted).
    pub fn route(&self, name: &str) -> Option<(Arc<Rollout>, Split)> {
        let ro = self.active(name)?;
        let split = ro.split();
        Some((ro, split))
    }

    /// Every rollout record (metrics attachment).
    pub fn all(&self) -> Vec<Arc<Rollout>> {
        self.entries.read_recover().values().cloned().collect()
    }

    /// Remove `name`'s record regardless of phase (supersede path: the
    /// override must not shadow a newly published version). Returns the
    /// removed rollout.
    pub fn remove(&self, name: &str) -> Option<Arc<Rollout>> {
        let mut g = self.entries.write_recover();
        let ro = g.remove(name);
        self.recount(&g);
        ro
    }

    /// Drive `name`'s window clock once.
    pub fn tick(&self, name: &str) -> TickOutcome {
        let Some(ro) = self.get(name) else {
            return TickOutcome::Gone;
        };
        let out = ro.evaluate();
        if out == TickOutcome::Promoted {
            self.recount(&self.entries.read_recover());
        }
        out
    }

    /// Operator-initiated rollback.
    pub fn abort(&self, name: &str, reason: &str) -> Result<Arc<Rollout>> {
        let ro = self.get(name).ok_or_else(|| {
            Error::Serving(format!("no rollout for model '{name}'"))
        })?;
        ro.abort(reason)?;
        Ok(ro)
    }

    /// Drop a terminal rollout record (returns its final status).
    pub fn clear(&self, name: &str) -> Result<Value> {
        let mut g = self.entries.write_recover();
        let Some(ro) = g.get(name) else {
            return Err(Error::Serving(format!("no rollout for model '{name}'")));
        };
        if !ro.is_terminal() {
            return Err(Error::Serving(format!(
                "rollout already in progress for '{name}' — abort it before clearing"
            )));
        }
        let status = ro.status_value();
        g.remove(name);
        self.recount(&g);
        Ok(status)
    }

    /// `rollout_status` body: per-model status objects keyed by name.
    /// With `name` given, only that model (error if absent).
    pub fn status(&self, name: Option<&str>) -> Result<Value> {
        let handles: Vec<Arc<Rollout>> = {
            let g = self.entries.read_recover();
            match name {
                Some(n) => match g.get(n) {
                    Some(ro) => vec![ro.clone()],
                    None => {
                        return Err(Error::Serving(format!(
                            "no rollout for model '{n}'"
                        )))
                    }
                },
                None => g.values().cloned().collect(),
            }
        };
        let mut fields = Vec::new();
        let values: Vec<(String, Value)> = handles
            .iter()
            .map(|ro| (ro.name.clone(), ro.status_value()))
            .collect();
        for (n, v) in &values {
            fields.push((n.as_str(), v.clone()));
        }
        Ok(obj(vec![("rollouts", obj(fields))]))
    }

    /// Numeric summaries for the metrics `rollout` section (empty map
    /// when nothing ever rolled out → the section is omitted upstream).
    pub fn prom_overlay(&self) -> Option<Value> {
        let handles: Vec<Arc<Rollout>> =
            self.entries.read_recover().values().cloned().collect();
        if handles.is_empty() {
            return None;
        }
        let values: Vec<(String, Value)> = handles
            .iter()
            .map(|ro| (ro.name.clone(), ro.prom_value()))
            .collect();
        let fields: Vec<(&str, Value)> =
            values.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
        Some(obj(fields))
    }

    /// Names of rollouts that still need terminal cleanup checks (the
    /// registry's reload path uses this to keep drivers honest).
    pub fn names(&self) -> Vec<String> {
        self.entries.read_recover().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with_ramp(ramp: Vec<f64>) -> RolloutConfig {
        RolloutConfig { ramp, ..RolloutConfig::default() }
    }

    #[test]
    fn fraction_follows_the_phase() {
        let cfg = cfg_with_ramp(vec![0.05, 0.25, 0.5]);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Ramping { step: 0 }), 0.05);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Ramping { step: 2 }), 0.5);
        // a step past the schedule behaves like the observing window
        assert_eq!(fraction_for(&cfg, RolloutPhase::Ramping { step: 9 }), 1.0);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Observing), 1.0);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Promoted), 1.0);
        assert_eq!(fraction_for(&cfg, RolloutPhase::RolledBack), 0.0);
    }

    #[test]
    fn fraction_clamps_misconfigured_steps() {
        let cfg = cfg_with_ramp(vec![-0.5, 1.5]);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Ramping { step: 0 }), 0.0);
        assert_eq!(fraction_for(&cfg, RolloutPhase::Ramping { step: 1 }), 1.0);
    }

    /// The splitter's floor identity: of any `n` consecutive requests,
    /// exactly `⌊n·f⌋` advance the cumulative target — the property the
    /// dispatch-path split relies on (`Rollout::split` applies it to a
    /// shared counter; the live-TCP assertion is in tests/rollout.rs).
    #[test]
    fn floor_identity_yields_exact_fractions() {
        for &f in &[0.0, 0.05, 0.25, 0.5, 0.75, 1.0] {
            for n in [1u64, 7, 64, 200, 1000] {
                let canary = (0..n)
                    .filter(|&i| ((i + 1) as f64 * f).floor() > (i as f64 * f).floor())
                    .count() as u64;
                assert_eq!(canary, (n as f64 * f).floor() as u64, "f={f} n={n}");
            }
        }
    }
}
