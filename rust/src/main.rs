//! `kan-edge` CLI: the leader entrypoint.
//!
//! Subcommands map onto the paper's artifacts:
//! * `serve`     — multi-model edge inference (TCP JSON-lines) over the
//!   model registry; requests pick a variant with `"model"`
//! * `models`    — list / inspect registered model versions
//! * `publish`   — publish a weights file as a new model version
//! * `eval`      — accuracy of a model on the artifact test set per backend
//! * `neurosim`  — KAN-NeuroSim constraint search (Fig 9 / Fig 13)
//! * `quantize`  — inspect ASP-KAN-HAQ geometry for a (G, K, n) point
//! * `inputgen`  — the Fig 11 WL input generator comparison
//! * `sam`       — KAN-SAM vs uniform mapping accuracy (Fig 12 single point)
//! * `fig10`     — the Fig 10 ASP-vs-conventional sweep
//! * `info`      — artifact manifest summary
//!
//! Argument parsing is hand-rolled (the offline image carries no clap):
//! `--key value` / `--flag` pairs after the subcommand.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use kan_edge::acim::{AcimOptions, ArrayConfig};
use kan_edge::circuits::{fig10_sweep, fig11_comparison, Tech};
use kan_edge::client::KanClient;
use kan_edge::config::AppConfig;
use kan_edge::coordinator::{build_acim_with_calib, build_backend, tcp_limits, Dispatch};
use kan_edge::error::Result;
use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::mapping::MappingStrategy;
use kan_edge::neurosim::{search, HwConstraints};
use kan_edge::quant::{AspSpec, ShLut};
use kan_edge::registry::{spawn_reload_thread, ModelRegistry};

const USAGE: &str = "\
kan-edge — KAN edge-inference accelerator stack

USAGE: kan-edge [--config FILE] [--artifacts DIR] <command> [options]

COMMANDS:
  serve     --addr HOST:PORT [--model NAME]    multi-model TCP serving
  models    [--model NAME]                     list / inspect registry
  publish   --weights FILE [--model N] [--version V]
                                               publish a new model version
  bench-net [--requests N] [--batch B] [--window W]
                                               served throughput: v1 vs v2
  eval      --model NAME --backend B           accuracy on the test set
  neurosim  --budget minimal|moderate|none     Fig 9/13 constraint search
  quantize  --g G --k K --n-bits N             ASP-KAN-HAQ geometry
  inputgen  --bits N                           Fig 11 generator comparison
  sam       --g G --array ROWS                 Fig 12 mapping comparison
  fig10                                        Fig 10 quantization sweep
  cost      --g G --dims a,b,c --tm-n N        accelerator cost estimate
  stats                                        ACIM calibration statistics
  info                                         artifact manifest summary

The endpoint speaks two protocols, auto-detected per connection (see
docs/PROTOCOL.md): v1 JSON lines, where the optional \"model\" field
routes to a variant (\"name\" or pinned \"name@version\"):
  {\"model\": \"kan2\", \"features\": [...]}
and framed v2 (magic \"KAN2\") with request ids, pipelining, batch
submit and control verbs (hello/list_models/model_info/metrics/health),
spoken by kan_edge::client::KanClient.
";

/// Parsed command line: subcommand + `--key value` options.
struct Args {
    cmd: String,
    opts: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> std::result::Result<Args, String> {
        let mut cmd = None;
        let mut opts = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                opts.insert(key.to_string(), val);
            } else if cmd.is_none() {
                cmd = Some(a.clone());
            } else {
                return Err(format!("unexpected argument '{a}'"));
            }
            i += 1;
        }
        Ok(Args { cmd: cmd.unwrap_or_else(|| "help".into()), opts })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.opts
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.opts
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.cmd == "help" || args.opts.contains_key("help") {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg_path = args.opts.get("config").map(Path::new);
    let mut cfg = AppConfig::load(cfg_path)?;
    if let Some(dir) = args.opts.get("artifacts") {
        cfg.artifacts.dir = dir.clone();
    }
    match args.cmd.as_str() {
        "serve" => serve(
            &cfg,
            &args.get("model", &cfg.artifacts.model.clone()),
            &args.get("addr", "127.0.0.1:7777"),
        ),
        "models" => models_cmd(&cfg, args.opts.get("model").map(|s| s.as_str())),
        "publish" => publish_cmd(&cfg, args),
        "bench-net" => bench_net_cmd(&cfg, args),
        "eval" => eval(
            &cfg,
            &args.get("model", "kan1"),
            &args.get("backend", "digital"),
        ),
        "neurosim" => neurosim_cmd(&cfg, &args.get("budget", "minimal")),
        "quantize" => quantize_cmd(
            args.get_u32("g", 5),
            args.get_u32("k", 3),
            args.get_u32("n-bits", 8),
        ),
        "inputgen" => {
            print_inputgen(args.get_u32("bits", 6), &cfg.hardware.tech);
            Ok(())
        }
        "sam" => sam_cmd(&cfg, args.get_u32("g", 15), args.get_usize("array", 256)),
        "fig10" => fig10_cmd(&cfg),
        "cost" => cost_cmd(&cfg, args),
        "stats" => stats_cmd(),
        "info" => info_cmd(&cfg),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn serve(cfg: &AppConfig, model: &str, addr: &str) -> Result<()> {
    // the default model comes from --model / config
    let mut cfg = cfg.clone();
    cfg.artifacts.model = model.to_string();
    let registry = ModelRegistry::open(&cfg)?;

    // eager-load the preload set (default model when unset); the default
    // must come up or serving is pointless, the rest load lazily on miss
    let mut preload = cfg.registry.preload.clone();
    if !preload.contains(&cfg.artifacts.model) {
        preload.insert(0, cfg.artifacts.model.clone());
    }
    for name in &preload {
        match registry.ensure_loaded(name) {
            Ok(served) => println!("loaded {} [{}]", served.id, cfg.server.backend),
            Err(e) if name == &cfg.artifacts.model => return Err(e),
            Err(e) => eprintln!("warning: preload of '{name}' failed: {e}"),
        }
    }

    if cfg.registry.reload_poll_ms > 0 {
        spawn_reload_thread(
            &registry,
            std::time::Duration::from_millis(cfg.registry.reload_poll_ms),
        );
    }
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = kan_edge::coordinator::TcpServer::spawn_with_limits(
        addr,
        target,
        tcp_limits(&cfg),
    )?;
    println!(
        "kan-edge serving {} model(s) on {} (default {model}, protocols v1+v2, \
         hot-reload {}; Ctrl-C to stop)",
        registry.model_names().len(),
        server.addr,
        if cfg.registry.reload_poll_ms > 0 { "on" } else { "off" },
    );
    // serve until the process is killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn models_cmd(cfg: &AppConfig, inspect: Option<&str>) -> Result<()> {
    let registry = ModelRegistry::open(cfg)?;
    let models = registry.models();
    match inspect {
        Some(name) => {
            let info = models
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| {
                    kan_edge::Error::Registry(format!(
                        "model '{name}' not in manifest (have: {:?})",
                        registry.model_names()
                    ))
                })?;
            println!("{}@{} [{}]", info.name, info.meta.version, info.kind);
            println!("  dims:     {:?} ({} params)", info.dims, info.num_params);
            println!("  weights:  {}", info.weights);
            println!(
                "  digest:   {}",
                info.meta.digest.as_deref().unwrap_or("(none, schema v1)")
            );
            if let Some(q) = &info.meta.quant {
                println!("  quant:    G={} K={} n_bits={}", q.g, q.k, q.n_bits);
            }
            if let Some(a) = info.meta.accuracy {
                println!("  accuracy: {a:.4}");
            }
            if let Some(h) = &info.meta.hw_cost {
                println!(
                    "  hw cost:  {:.4} mm2, {:.1} pJ, {:.0} ns",
                    h.area_mm2, h.energy_pj, h.latency_ns
                );
            }
        }
        None => {
            println!(
                "{:<20} {:>4} {:<6} {:>9} {:>9}  {}",
                "model", "ver", "kind", "params", "acc", "digest"
            );
            for m in &models {
                println!(
                    "{:<20} {:>4} {:<6} {:>9} {:>9}  {}",
                    m.name,
                    m.meta.version,
                    m.kind,
                    m.num_params,
                    m.meta
                        .accuracy
                        .map(|a| format!("{a:.4}"))
                        .unwrap_or_else(|| "-".into()),
                    m.meta.digest.as_deref().unwrap_or("-"),
                );
            }
        }
    }
    Ok(())
}

fn publish_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    let weights = args.opts.get("weights").ok_or_else(|| {
        kan_edge::Error::Registry("publish requires --weights FILE".into())
    })?;
    let version = match args.opts.get("version") {
        None => None,
        Some(v) => Some(v.parse::<u32>().map_err(|_| {
            kan_edge::Error::Registry(format!(
                "--version must be an unsigned integer (got '{v}')"
            ))
        })?),
    };
    // publishing into a fresh directory bootstraps an empty v2 manifest
    let dir = Path::new(&cfg.artifacts.dir);
    if !dir.join("manifest.json").exists() {
        kan_edge::registry::ModelManifest::empty().save(dir)?;
    }
    let registry = ModelRegistry::open(cfg)?;
    let (name, meta) = registry.publish_file(
        Path::new(weights),
        args.opts.get("model").map(|s| s.as_str()),
        version,
    )?;
    println!(
        "published {name}@{} (digest {})",
        meta.version,
        meta.digest.as_deref().unwrap_or("?")
    );
    if let Some(a) = meta.accuracy {
        println!("  accuracy: {a:.4}");
    }
    if let Some(h) = &meta.hw_cost {
        println!(
            "  hw cost:  {:.4} mm2, {:.1} pJ, {:.0} ns",
            h.area_mm2, h.energy_pj, h.latency_ns
        );
    }
    Ok(())
}

/// `(requests, batches)` served so far by the (single) bench model;
/// `(0, 0)` before its pipeline first loads.
fn served_counts(client: &mut KanClient) -> Result<(i64, i64)> {
    let body = client.metrics()?;
    let report = body
        .field("models")?
        .as_object()
        .and_then(|m| m.values().next())
        .cloned();
    Ok(match report {
        Some(r) => (
            r.get("requests").and_then(|v| v.as_i64()).unwrap_or(0),
            r.get("batches").and_then(|v| v.as_i64()).unwrap_or(0),
        ),
        None => (0, 0),
    })
}

fn mean_batch_delta(prev: (i64, i64), now: (i64, i64)) -> f64 {
    let dreq = (now.0 - prev.0) as f64;
    let dbatch = (now.1 - prev.1) as f64;
    if dbatch > 0.0 {
        dreq / dbatch
    } else {
        0.0
    }
}

/// Self-contained network benchmark: publish a tiny synthetic KAN into
/// a temp registry, serve it on an ephemeral port (digital backend),
/// and measure served throughput over one connection in three modes —
/// v1 JSON lines (one request in flight), v2 pipelined submit/poll,
/// and v2 whole-batch submit. The per-phase "mean batch" column is the
/// batch occupancy the *server* saw, showing that v2 lets a single
/// connection feed the dynamic batcher multi-row batches.
fn bench_net_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::time::Instant;

    let requests = args.get_usize("requests", 2000).max(1);
    let batch = args.get_usize("batch", 16).max(1);
    let window = args.get_usize("window", 32).max(1);

    // per-process dir: concurrent bench-net runs must not wipe each
    // other's live registry mid-benchmark
    let dir = std::env::temp_dir().join(format!("kan_edge_bench_net_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    kan_edge::registry::ModelManifest::empty().save(&dir)?;
    let mut cfg = cfg.clone();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = "bench".into();
    cfg.server.backend = "digital".into();
    let registry = ModelRegistry::open(&cfg)?;
    let src = dir.join("bench.incoming.json");
    std::fs::write(&src, kan_edge::kan::checkpoint::synthetic_checkpoint_json("bench", 0))?;
    registry.publish_file(&src, None, None)?;

    let target: Arc<dyn Dispatch> = registry.clone();
    let server = kan_edge::coordinator::TcpServer::spawn_with_limits(
        "127.0.0.1:0",
        target,
        tcp_limits(&cfg),
    )?;
    println!(
        "bench-net: {requests} requests per mode, digital backend, {}",
        server.addr
    );
    let features = vec![0.5f32, 0.5];
    // separate control connection: reads (requests, batches) deltas
    // between phases for the exact per-phase batch occupancy
    let mut probe = KanClient::connect(server.addr)?;
    let mut last = served_counts(&mut probe)?;

    // v1: JSON lines, the connection blocks until each reply arrives
    let t0 = Instant::now();
    {
        let conn = std::net::TcpStream::connect(server.addr)?;
        let mut w = conn.try_clone()?;
        let mut r = BufReader::new(conn);
        let mut line = String::new();
        for _ in 0..requests {
            w.write_all(b"{\"features\":[0.5,0.5]}\n")?;
            line.clear();
            r.read_line(&mut line)?;
        }
    }
    let v1_secs = t0.elapsed().as_secs_f64();
    let now = served_counts(&mut probe)?;
    let v1_mean = mean_batch_delta(last, now);
    last = now;

    // v2 pipelined: keep `window` requests in flight on one connection.
    // Clamp to the negotiated cap: beyond it the server reader stops
    // pulling frames, and submitting without polling past that point
    // would deadlock both directions once the socket buffers fill.
    let mut client = KanClient::connect(server.addr)?;
    let window = window.min(client.server_info().max_in_flight);
    let t0 = Instant::now();
    let (mut submitted, mut done) = (0usize, 0usize);
    while done < requests {
        while submitted < requests && submitted - done < window {
            client.submit(None, &features)?;
            submitted += 1;
        }
        let (_id, outcome) = client.poll()?;
        outcome?;
        done += 1;
    }
    let v2p_secs = t0.elapsed().as_secs_f64();
    let now = served_counts(&mut probe)?;
    let v2p_mean = mean_batch_delta(last, now);
    last = now;

    // v2 batch submit: whole `rows` batches in one frame
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < requests {
        let n = batch.min(requests - done);
        let rows: Vec<Vec<f32>> = vec![features.clone(); n];
        client.infer_batch(None, rows)?;
        done += n;
    }
    let v2b_secs = t0.elapsed().as_secs_f64();
    let now = served_counts(&mut probe)?;
    let v2b_mean = mean_batch_delta(last, now);

    println!(
        "{:<24} {:>9} {:>9} {:>11} {:>11}",
        "mode", "requests", "wall(s)", "req/s", "mean batch"
    );
    let table = [
        ("v1 single-request".to_string(), v1_secs, v1_mean),
        (format!("v2 pipelined (w={window})"), v2p_secs, v2p_mean),
        (format!("v2 batch (b={batch})"), v2b_secs, v2b_mean),
    ];
    for (name, secs, mean) in table {
        println!(
            "{:<24} {:>9} {:>9.2} {:>11.0} {:>11.2}",
            name,
            requests,
            secs,
            requests as f64 / secs.max(1e-9),
            mean
        );
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn eval(cfg: &AppConfig, model: &str, backend: &str) -> Result<()> {
    let dir = Path::new(&cfg.artifacts.dir);
    let manifest = Manifest::load(dir)?;
    let ds = Dataset::load(dir)?;
    let entry = manifest.models.get(model).ok_or_else(|| {
        kan_edge::Error::Artifact(format!("model '{model}' not in manifest"))
    })?;
    let acc = match (backend, entry.kind.as_str()) {
        (_, "mlp") => {
            kan_edge::baseline::MlpModel::load(dir.join(&entry.weights))?.accuracy(&ds)
        }
        ("digital", _) => QuantKanModel::load(dir.join(&entry.weights))?.accuracy(&ds),
        ("acim", _) => {
            let qk = QuantKanModel::load(dir.join(&entry.weights))?;
            build_acim_with_calib(&qk, cfg.hardware.acim, &ds, MappingStrategy::Sam)?
                .accuracy(&ds)
        }
        ("pjrt", _) => {
            let mut cfg2 = cfg.clone();
            cfg2.server.backend = "pjrt".into();
            let be = build_backend(&cfg2, &manifest, model)?;
            eval_backend(be, &ds)
        }
        (other, _) => {
            return Err(kan_edge::Error::Config(format!("unknown backend '{other}'")))
        }
    };
    println!("{model} [{backend}] accuracy = {acc:.4}");
    Ok(())
}

fn eval_backend(be: Arc<dyn kan_edge::coordinator::InferBackend>, ds: &Dataset) -> f64 {
    let rows: Vec<Vec<f32>> = ds.test_rows().map(|(r, _)| r.to_vec()).collect();
    let labels: Vec<u32> = ds.test_rows().map(|(_, y)| y).collect();
    let outs = be.infer_batch(rows).expect("inference failed");
    let correct = outs
        .iter()
        .zip(&labels)
        .filter(|(o, &y)| {
            kan_edge::kan::argmax(&o.iter().map(|&v| v as f64).collect::<Vec<_>>())
                == y as usize
        })
        .count();
    correct as f64 / labels.len().max(1) as f64
}

fn neurosim_cmd(cfg: &AppConfig, budget: &str) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts.dir)?;
    let constraints = match budget {
        "minimal" => HwConstraints::minimal(),
        "moderate" => HwConstraints::moderate(),
        "none" => HwConstraints::default(),
        _ => cfg.neurosim.constraints,
    };
    let out = search(
        &[17, 1, 14],
        &manifest.sweep,
        &cfg.neurosim.tm_modes,
        &constraints,
        &cfg.hardware.tech,
    )?;
    println!(
        "{:>4} {:>4} {:>9} {:>11} {:>11} {:>11} {:>8}",
        "G", "N", "acc", "area(mm2)", "energy(pJ)", "lat(ns)", "admit"
    );
    for c in &out.candidates {
        println!(
            "{:>4} {:>4} {:>9.4} {:>11.4} {:>11.1} {:>11.0} {:>8}",
            c.g,
            c.tm_n,
            c.accuracy,
            c.report.area_mm2,
            c.report.energy_pj,
            c.report.latency_ns,
            c.admitted
        );
    }
    match out.best {
        Some(b) => println!(
            "\nbest: G={} N={} acc={:.4} ({} params)",
            b.g, b.tm_n, b.accuracy, b.report.num_params
        ),
        None => println!("\nno admissible design point under this budget"),
    }
    Ok(())
}

fn quantize_cmd(g: u32, k: u32, n_bits: u32) -> Result<()> {
    let spec = AspSpec::build(g, k, n_bits, 0.0, 1.0)?;
    let lut = ShLut::build(&spec, n_bits);
    println!("ASP-KAN-HAQ geometry for G={g}, K={k}, n={n_bits}:");
    println!(
        "  LD = {} (L = {} levels/interval)",
        spec.ld,
        spec.levels_per_interval()
    );
    println!("  code range R = G*2^LD = {}", spec.range());
    println!("  basis functions G+K = {}", spec.num_basis());
    println!(
        "  SH-LUT: {} rows x {} cols = {} stored entries ({} full)",
        lut.hemi.len(),
        k + 1,
        lut.stored_entries(),
        lut.full_rows() * (k as usize + 1)
    );
    println!(
        "  decoders: ({}-bit global) + ({}-bit local) instead of one {n_bits}-bit",
        n_bits - spec.ld,
        spec.ld
    );
    Ok(())
}

fn print_inputgen(bits: u32, tech: &Tech) {
    println!(
        "{:<14} {:>10} {:>10} {:>9} {:>10} {:>8}",
        "generator", "area(um2)", "power(uW)", "lat(ns)", "margin(mV)", "FOM(x)"
    );
    let reports = fig11_comparison(bits, tech);
    let tm_fom = reports.last().unwrap().fom();
    for r in &reports {
        println!(
            "{:<14} {:>10.1} {:>10.1} {:>9.1} {:>10.1} {:>8.2}",
            r.name,
            r.area_um2,
            r.power_uw,
            r.latency_ns,
            r.noise_margin_v * 1e3,
            r.fom() / tm_fom
        );
    }
}

fn sam_cmd(cfg: &AppConfig, g: u32, array: usize) -> Result<()> {
    let dir = Path::new(&cfg.artifacts.dir);
    let ds = Dataset::load(dir)?;
    let path = dir.join(format!("sweep/kan_g{g}.weights.json"));
    let qk = QuantKanModel::load(&path)?;
    let sw_acc = qk.accuracy(&ds);
    let opts = AcimOptions {
        array: ArrayConfig { rows: array, ..cfg.hardware.acim.array },
        ..cfg.hardware.acim
    };
    let uni =
        build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Uniform)?.accuracy(&ds);
    let sam = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Sam)?.accuracy(&ds);
    println!("G={g}, array={array}:");
    println!("  software (quantized, ideal) accuracy: {sw_acc:.4}");
    println!(
        "  ACIM uniform mapping: {uni:.4} (degradation {:.4})",
        sw_acc - uni
    );
    println!(
        "  ACIM KAN-SAM mapping: {sam:.4} (degradation {:.4})",
        sw_acc - sam
    );
    if sw_acc - sam > 1e-9 {
        println!(
            "  degradation reduction: {:.2}x",
            (sw_acc - uni) / (sw_acc - sam)
        );
    }
    Ok(())
}

fn fig10_cmd(cfg: &AppConfig) -> Result<()> {
    let rows = fig10_sweep(&[8, 16, 32, 64], 3, 8, &cfg.hardware.tech)?;
    println!("{:>4} {:>12} {:>14}", "G", "area-red(x)", "energy-red(x)");
    for r in &rows {
        println!(
            "{:>4} {:>12.2} {:>14.2}",
            r.g, r.area_reduction, r.energy_reduction
        );
    }
    let n = rows.len() as f64;
    println!(
        "avg: area {:.2}x (paper 40.14x), energy {:.2}x (paper 5.59x)",
        rows.iter().map(|r| r.area_reduction).sum::<f64>() / n,
        rows.iter().map(|r| r.energy_reduction).sum::<f64>() / n
    );
    Ok(())
}

fn cost_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    use kan_edge::neurosim::{estimate_kan, estimate_mlp, KanArch, MlpArch};
    let dims: Vec<usize> = args
        .get("dims", "17,1,14")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let kind = args.get("kind", "kan");
    let report = match kind.as_str() {
        "mlp" => estimate_mlp(&MlpArch::new(dims), &cfg.hardware.tech)?,
        _ => {
            let mut arch = KanArch::new(dims, args.get_u32("g", 5));
            arch.tm_n = args.get_u32("tm-n", 3);
            estimate_kan(&arch, &cfg.hardware.tech)?
        }
    };
    println!("{}", kan_edge::util::json::obj(vec![
        ("name", kan_edge::util::json::Value::Str(report.name.clone())),
        ("area_mm2", report.area_mm2.into()),
        ("energy_pj", report.energy_pj.into()),
        ("latency_ns", report.latency_ns.into()),
        ("num_params", report.num_params.into()),
    ]));
    Ok(())
}

fn stats_cmd() -> Result<()> {
    println!("ACIM calibration statistics (synthetic 'measured-chip' tables,");
    println!("DESIGN.md section 4; regenerated from the resistive-ladder model):
");
    println!(
        "{:>6} {:>12} {:>12}  {}",
        "rows", "mean err", "sigma err", "attenuation by distance decile"
    );
    for s in kan_edge::acim::measured_table(0xCA11B) {
        let profile: Vec<String> =
            s.row_attenuation.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{:>6} {:>12.5} {:>12.5}  [{}]",
            s.rows,
            s.mean_rel_error,
            s.sigma_rel_error,
            profile.join(", ")
        );
    }
    Ok(())
}

fn info_cmd(cfg: &AppConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts.dir)?;
    println!(
        "artifacts: {} (build {:.0}s)",
        cfg.artifacts.dir,
        manifest.build_seconds.unwrap_or(0.0)
    );
    println!(
        "dataset: {} features, {} classes, {}/{}/{} train/val/test",
        manifest.dataset.num_features,
        manifest.dataset.num_classes,
        manifest.dataset.train,
        manifest.dataset.val,
        manifest.dataset.test
    );
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let m = &manifest.models[name];
        println!(
            "  {name}: {:?} {} params, val {:.4}, test {:.4}",
            m.dims,
            m.num_params,
            m.val_acc,
            m.quant_test_acc.or(m.test_acc).unwrap_or(f64::NAN)
        );
    }
    println!(
        "sweep (Fig 12): G = {:?}",
        manifest.sweep.iter().map(|s| s.g).collect::<Vec<_>>()
    );
    Ok(())
}
