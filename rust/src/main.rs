//! `kan-edge` CLI: the leader entrypoint.
//!
//! Subcommands map onto the paper's artifacts:
//! * `serve`     — multi-model edge inference (TCP JSON-lines) over the
//!   model registry; requests pick a variant with `"model"`
//! * `route`     — cluster front-router: consistent-hash sharding over a
//!   set of `serve` nodes with replication and hedged retries
//! * `models`    — list / inspect registered model versions
//! * `publish`   — publish a weights file as a new model version
//! * `eval`      — accuracy of a model on the artifact test set per backend
//! * `tune-engine` — autotune the batch-major engine execution knobs
//! * `neurosim`  — KAN-NeuroSim constraint search (Fig 9 / Fig 13)
//! * `quantize`  — inspect ASP-KAN-HAQ geometry for a (G, K, n) point
//! * `inputgen`  — the Fig 11 WL input generator comparison
//! * `sam`       — KAN-SAM vs uniform mapping accuracy (Fig 12 single point)
//! * `fig10`     — the Fig 10 ASP-vs-conventional sweep
//! * `info`      — artifact manifest summary
//!
//! Argument parsing is hand-rolled (the offline image carries no clap):
//! `--key value` / `--flag` pairs after the subcommand.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use kan_edge::acim::{AcimOptions, ArrayConfig};
use kan_edge::circuits::{fig10_sweep, fig11_comparison, Tech};
use kan_edge::client::{CallOptions, KanClient};
use kan_edge::config::AppConfig;
use kan_edge::coordinator::{
    build_acim_with_calib, build_session, tcp_limits, BackendKind, Dispatch,
    ExecutionSession,
};
use kan_edge::error::Result;
use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::mapping::MappingStrategy;
use kan_edge::neurosim::{search, HwConstraints};
use kan_edge::quant::{AspSpec, ShLut};
use kan_edge::registry::{spawn_reload_thread, ModelRegistry};

const USAGE: &str = "\
kan-edge — KAN edge-inference accelerator stack

USAGE: kan-edge [--config FILE] [--artifacts DIR] <command> [options]

COMMANDS:
  serve     --addr HOST:PORT [--model NAME] [--node-id ID]
                                               multi-model TCP serving; the
                                               node id (generated + persisted
                                               in the artifacts dir when not
                                               given) names this node in
                                               cluster rollups
  route     --nodes H1:P1,H2:P2,... [--addr HOST:PORT]
                                               cluster front-router over the
                                               given serve nodes: consistent-
                                               hash placement, on-demand
                                               artifact replication, hedged
                                               retries (see docs/CLUSTER.md;
                                               [cluster] config section)
  models    [--model NAME] [--addr HOST:PORT]  list / inspect registry
                                               (--addr lists a live server's
                                               models, annotated with any
                                               active rollout)
  publish   --weights FILE [--model N] [--version V] | --synthetic [--model N]
                                               publish a new model version
                                               (--synthetic generates a tiny
                                               deterministic KAN checkpoint)
  rollout   start MODEL@VER --baseline MODEL@VER [--addr HOST:PORT]
            status [MODEL] | abort MODEL | clear MODEL  [--json]
                                               staged canary deployment with
                                               SLO-gated auto-promote and
                                               instant auto-rollback
                                               (docs/ROLLOUT.md; [rollout]
                                               config section)
  bench-net [--requests N] [--batch B] [--window W]
            [--tenants T] [--mix-requests M] [--mix-batch R]
            [--mix-queue Q] [--json FILE] [--skip-mixed] [--mixed-only]
            [--skip-hotpath] [--skip-shadow] [--skip-trace] [--skip-cluster]
            [--skip-rollout]
                                               served throughput: v1 vs v2,
                                               the digital engine-off-vs-on
                                               hot-path phase, the digital-
                                               vs-ACIM shadow-divergence
                                               phase, the request-tracing
                                               overhead phase, the routed-vs-
                                               direct cluster phase (3 nodes
                                               + router, hedging vs a slow
                                               replica), the rollout canary
                                               phase (split overhead at
                                               fraction 0), plus the mixed-
                                               tenant fifo-vs-drr fairness
                                               comparison
  metrics   [--addr HOST:PORT] [--prom] [--demo]
                                               scrape a server's metrics as
                                               JSON or Prometheus text;
                                               --demo serves + drives an
                                               in-process model first
  eval      --model NAME --backend B           accuracy on the test set
                                               (B: digital = planned engine,
                                               digital-ref = scalar golden
                                               reference, acim, pjrt)
  tune-engine [--model NAME] [--batch B] [--target-ms MS] [--json FILE]
                                               sweep the batch-major engine
                                               knobs (block, grouping
                                               threshold, fusion budget) on
                                               the named model (synthetic
                                               fallback when artifacts are
                                               missing) and merge the report
                                               into FILE (default
                                               BENCH_hotpath.json); see
                                               docs/PERFORMANCE.md
  neurosim  --budget minimal|moderate|none     Fig 9/13 constraint search
  quantize  --g G --k K --n-bits N             ASP-KAN-HAQ geometry
  inputgen  --bits N                           Fig 11 generator comparison
  sam       --g G --array ROWS                 Fig 12 mapping comparison
  fig10                                        Fig 10 quantization sweep
  cost      --g G --dims a,b,c --tm-n N        accelerator cost estimate
  lint      [--root DIR] [--json FILE]         repo-native static analysis:
                                               lock discipline, panic policy,
                                               hot-path allocations, doc
                                               drift (docs/ANALYSIS.md);
                                               exits 1 on findings
  stats                                        ACIM calibration statistics
  info                                         artifact manifest summary

The endpoint speaks two protocols, auto-detected per connection (see
docs/PROTOCOL.md): v1 JSON lines, where the optional \"model\" field
routes to a variant (\"name\" or pinned \"name@version\"):
  {\"model\": \"kan2\", \"features\": [...]}
and framed v2 (magic \"KAN2\") with request ids, pipelining, batch
submit and control verbs (hello/list_models/model_info/metrics/
metrics_prom/trace/health/rollout_start/rollout_status/rollout_abort/
rollout_clear), spoken by kan_edge::client::KanClient.

Structured logs go to stderr as JSON lines; the level comes from the
[observability] config section and the KAN_EDGE_LOG env var (error|
warn|info|debug, env wins). See docs/OBSERVABILITY.md.
";

/// Parsed command line: subcommand + positional words + `--key value`
/// options (`rollout start name@2` carries the action and model spec
/// as positionals).
struct Args {
    cmd: String,
    pos: Vec<String>,
    opts: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> std::result::Result<Args, String> {
        let mut cmd = None;
        let mut pos = Vec::new();
        let mut opts = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                opts.insert(key.to_string(), val);
            } else if cmd.is_none() {
                cmd = Some(a.clone());
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { cmd: cmd.unwrap_or_else(|| "help".into()), pos, opts })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.opts
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.opts
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.cmd == "help" || args.opts.contains_key("help") {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg_path = args.opts.get("config").map(Path::new);
    let mut cfg = AppConfig::load(cfg_path)?;
    if let Some(dir) = args.opts.get("artifacts") {
        cfg.artifacts.dir = dir.clone();
    }
    // structured logging: config sets the level, KAN_EDGE_LOG overrides
    if let Some(l) = kan_edge::obs::log::Level::parse(&cfg.observability.log_level) {
        kan_edge::obs::log::set_level(l);
    }
    kan_edge::obs::log::init_from_env();
    match args.cmd.as_str() {
        "serve" => serve(
            &cfg,
            &args.get("model", &cfg.artifacts.model.clone()),
            &args.get("addr", "127.0.0.1:7777"),
            args.opts.get("node-id").cloned(),
        ),
        "route" => route_cmd(&cfg, args),
        "models" => models_cmd(&cfg, args),
        "metrics" => metrics_cmd(&cfg, args),
        "publish" => publish_cmd(&cfg, args),
        "rollout" => rollout_cmd(args),
        "bench-net" => bench_net_cmd(&cfg, args),
        "tune-engine" => tune_engine_cmd(&cfg, args),
        "eval" => eval(
            &cfg,
            &args.get("model", "kan1"),
            &args.get("backend", "digital"),
        ),
        "neurosim" => neurosim_cmd(&cfg, &args.get("budget", "minimal")),
        "quantize" => quantize_cmd(
            args.get_u32("g", 5),
            args.get_u32("k", 3),
            args.get_u32("n-bits", 8),
        ),
        "inputgen" => {
            print_inputgen(args.get_u32("bits", 6), &cfg.hardware.tech);
            Ok(())
        }
        "sam" => sam_cmd(&cfg, args.get_u32("g", 15), args.get_usize("array", 256)),
        "fig10" => fig10_cmd(&cfg),
        "cost" => cost_cmd(&cfg, args),
        "lint" => lint_cmd(args),
        "stats" => stats_cmd(),
        "info" => info_cmd(&cfg),
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Resolve this node's stable cluster identity: `--node-id` wins, else
/// the `node_id` file persisted next to the artifacts (written on first
/// start, so restarts keep the same identity while `uptime_s` resets).
fn resolve_node_id(artifacts_dir: &Path, explicit: Option<String>) -> String {
    if let Some(id) = explicit {
        return id;
    }
    let path = artifacts_dir.join("node_id");
    if let Ok(s) = std::fs::read_to_string(&path) {
        let s = s.trim().to_string();
        if !s.is_empty() {
            return s;
        }
    }
    let entropy = format!(
        "{}:{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
    );
    let generated = format!(
        "node-{:08x}",
        kan_edge::registry::digest::fnv64(entropy.as_bytes()) as u32
    );
    // best-effort persistence: a read-only artifacts dir just means a
    // fresh id per start
    let _ = std::fs::write(&path, &generated);
    generated
}

fn serve(
    cfg: &AppConfig,
    model: &str,
    addr: &str,
    node_id: Option<String>,
) -> Result<()> {
    // the default model comes from --model / config
    let mut cfg = cfg.clone();
    cfg.artifacts.model = model.to_string();
    let registry = ModelRegistry::open(&cfg)?;

    // eager-load the preload set (default model when unset); the default
    // must come up or serving is pointless, the rest load lazily on miss
    let mut preload = cfg.registry.preload.clone();
    if !preload.contains(&cfg.artifacts.model) {
        preload.insert(0, cfg.artifacts.model.clone());
    }
    for name in &preload {
        match registry.ensure_loaded(name) {
            Ok(served) => println!("loaded {} [{}]", served.id, cfg.server.backend),
            Err(e) if name == &cfg.artifacts.model => return Err(e),
            Err(e) => kan_edge::obs::log::warn(
                "serve",
                &format!("preload of '{name}' failed: {e}"),
            ),
        }
    }

    if cfg.registry.reload_poll_ms > 0 {
        spawn_reload_thread(
            &registry,
            std::time::Duration::from_millis(cfg.registry.reload_poll_ms),
        );
    }
    let node = resolve_node_id(Path::new(&cfg.artifacts.dir), node_id);
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = kan_edge::coordinator::TcpServer::spawn_with_identity(
        addr,
        target,
        tcp_limits(&cfg),
        kan_edge::coordinator::router::trace_hub(&cfg),
        Some(kan_edge::coordinator::NodeIdentity::new(node.clone())),
    )?;
    println!(
        "kan-edge serving {} model(s) on {} (default {model}, node {node}, \
         protocols v1+v2, hot-reload {}, tracing {}; Ctrl-C to stop)",
        registry.model_names().len(),
        server.addr,
        if cfg.registry.reload_poll_ms > 0 { "on" } else { "off" },
        if cfg.observability.sample_every > 0 {
            format!("1-in-{}", cfg.observability.sample_every)
        } else {
            "off".into()
        },
    );
    // serve until the process is killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// The cluster front-router: place models on `--nodes` (or the
/// `cluster.nodes` config list) by consistent hashing and serve the
/// ordinary v1+v2 endpoint on `--addr` — clients cannot tell the
/// router from a single node. See `docs/CLUSTER.md`.
fn route_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    let mut cfg = cfg.clone();
    if let Some(nodes) = args.opts.get("nodes") {
        cfg.cluster.nodes = nodes
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    let addr = args.get("addr", "127.0.0.1:7700");
    let router = kan_edge::cluster::ClusterRouter::new(
        cfg.cluster.nodes.clone(),
        cfg.cluster.router_options(),
    )?;
    let target: Arc<dyn Dispatch> = router;
    let server = kan_edge::coordinator::TcpServer::spawn_with_identity(
        &addr,
        target,
        tcp_limits(&cfg),
        kan_edge::coordinator::router::trace_hub(&cfg),
        Some(kan_edge::coordinator::NodeIdentity::new(args.get("node-id", "router"))),
    )?;
    println!(
        "kan-edge routing {} node(s) on {} (replication {}, hedging {}; \
         Ctrl-C to stop)",
        cfg.cluster.nodes.len(),
        server.addr,
        cfg.cluster.replication,
        if cfg.cluster.hedge { "on" } else { "off" },
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn models_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    let inspect = args.opts.get("model").map(|s| s.as_str());
    // --addr: list a live server's models over the wire, with any
    // active rollout annotated per name (docs/ROLLOUT.md)
    if let Some(addr) = args.opts.get("addr") {
        return models_remote(addr, inspect);
    }
    let registry = ModelRegistry::open(cfg)?;
    let models = registry.models();
    match inspect {
        Some(name) => {
            let info = models
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| {
                    kan_edge::Error::Registry(format!(
                        "model '{name}' not in manifest (have: {:?})",
                        registry.model_names()
                    ))
                })?;
            println!("{}@{} [{}]", info.name, info.meta.version, info.kind);
            println!("  dims:     {:?} ({} params)", info.dims, info.num_params);
            println!("  weights:  {}", info.weights);
            println!(
                "  digest:   {}",
                info.meta.digest.as_deref().unwrap_or("(none, schema v1)")
            );
            if let Some(q) = &info.meta.quant {
                println!("  quant:    G={} K={} n_bits={}", q.g, q.k, q.n_bits);
            }
            if let Some(a) = info.meta.accuracy {
                println!("  accuracy: {a:.4}");
            }
            if let Some(h) = &info.meta.hw_cost {
                println!(
                    "  hw cost:  {:.4} mm2, {:.1} pJ, {:.0} ns",
                    h.area_mm2, h.energy_pj, h.latency_ns
                );
            }
        }
        None => {
            println!(
                "{:<20} {:>4} {:<6} {:>9} {:>9}  {}",
                "model", "ver", "kind", "params", "acc", "digest"
            );
            for m in &models {
                println!(
                    "{:<20} {:>4} {:<6} {:>9} {:>9}  {}",
                    m.name,
                    m.meta.version,
                    m.kind,
                    m.num_params,
                    m.meta
                        .accuracy
                        .map(|a| format!("{a:.4}"))
                        .unwrap_or_else(|| "-".into()),
                    m.meta.digest.as_deref().unwrap_or("-"),
                );
            }
        }
    }
    Ok(())
}

/// Remote `models --addr`: `list_models` over the wire plus the active
/// rollout map, so an operator sees which names are mid-rollout without
/// scraping metrics.
fn models_remote(addr: &str, inspect: Option<&str>) -> Result<()> {
    use kan_edge::util::json::Value;
    let mut client = KanClient::connect(addr)?;
    let models = client.list_models()?;
    // older endpoints (or ones with no registry) refuse the verb; the
    // listing still works, just without rollout annotations
    let rollouts = client
        .rollout_status(None)
        .ok()
        .and_then(|b| b.get("rollouts").cloned())
        .unwrap_or(Value::Null);
    let rollout_of = |name: &str| -> Option<String> {
        let ro = rollouts.get(name)?;
        let phase = ro.get("phase").and_then(|v| v.as_str())?.to_string();
        let frac = ro.get("fraction").and_then(|v| v.as_f64()).unwrap_or(0.0);
        Some(format!("{phase} f={frac:.2}"))
    };
    println!(
        "{:<20} {:>4} {:<6} {:>9} {:>5}  {:<22} {}",
        "model", "ver", "kind", "params", "live", "rollout", "digest"
    );
    for m in &models {
        if inspect.is_some_and(|n| n != m.name) {
            continue;
        }
        println!(
            "{:<20} {:>4} {:<6} {:>9} {:>5}  {:<22} {}",
            m.name,
            m.version,
            m.kind,
            m.num_params,
            if m.live { "yes" } else { "no" },
            rollout_of(&m.name).unwrap_or_else(|| "-".into()),
            m.digest.as_deref().unwrap_or("-"),
        );
    }
    Ok(())
}

/// `rollout` subcommand: drive the v2 `rollout_*` control verbs against
/// a serving endpoint (node or cluster router). Actions:
/// `start MODEL@VER --baseline MODEL@VER`, `status [MODEL]`,
/// `abort MODEL`, `clear MODEL`; `--json` prints the raw body.
fn rollout_cmd(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7777");
    let action = args.pos.first().map(|s| s.as_str()).unwrap_or("status");
    let model = args.pos.get(1).map(|s| s.as_str());
    let need_model = || -> Result<&str> {
        model.ok_or_else(|| {
            kan_edge::Error::Serving(format!(
                "rollout {action} needs a model (kan-edge rollout {action} NAME)"
            ))
        })
    };
    let mut client = KanClient::connect(addr.as_str())?;
    let body = match action {
        "start" => {
            let spec = need_model()?;
            let baseline = args.opts.get("baseline").ok_or_else(|| {
                kan_edge::Error::Serving(
                    "rollout start needs --baseline MODEL@VERSION (the warm \
                     standby to fall back to)"
                        .into(),
                )
            })?;
            client.rollout_start(spec, baseline)?
        }
        "status" => client.rollout_status(model)?,
        "abort" => client.rollout_abort(need_model()?)?,
        "clear" => client.rollout_clear(need_model()?)?,
        other => {
            return Err(kan_edge::Error::Serving(format!(
                "unknown rollout action '{other}' (start|status|abort|clear)"
            )))
        }
    };
    if args.opts.contains_key("json") {
        println!("{body}");
    } else {
        print_rollouts(&body);
    }
    Ok(())
}

/// Human rendering of a `rollout_*` response body (`{"rollouts": ...}`).
fn print_rollouts(body: &kan_edge::util::json::Value) {
    let Some(rollouts) = body.get("rollouts").and_then(|v| v.as_object()) else {
        println!("{body}");
        return;
    };
    if rollouts.is_empty() {
        println!("no active rollouts");
        return;
    }
    let geti = |v: &kan_edge::util::json::Value, k: &str| -> i64 {
        v.get(k).and_then(|x| x.as_i64()).unwrap_or(0)
    };
    let getf = |v: &kan_edge::util::json::Value, k: &str| -> f64 {
        v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0)
    };
    let gets = |v: &kan_edge::util::json::Value, k: &str| -> String {
        v.get(k).and_then(|x| x.as_str()).unwrap_or("-").to_string()
    };
    for (name, ro) in rollouts {
        println!(
            "{name}: {} (canary {} vs baseline {})",
            gets(ro, "phase"),
            gets(ro, "candidate"),
            gets(ro, "baseline"),
        );
        println!(
            "  step {}/{} fraction {:.2}; {} window(s) (+{} extended); \
             {} canary / {} baseline requests; {:.1}s elapsed",
            geti(ro, "step") + 1,
            geti(ro, "steps"),
            getf(ro, "fraction"),
            geti(ro, "windows"),
            geti(ro, "windows_extended"),
            geti(ro, "canary_requests"),
            geti(ro, "baseline_requests"),
            getf(ro, "elapsed_ms") / 1000.0,
        );
        if let Some(div) = ro.get("divergence") {
            println!(
                "  divergence: flip_rate {:.4}, logit MAE p99 {:.5} \
                 ({} sampled, {} dropped, {} errors)",
                getf(div, "flip_rate"),
                getf(div, "logit_mae_p99"),
                geti(div, "sampled"),
                geti(div, "dropped"),
                geti(div, "errors"),
            );
        }
        if let Some(decisions) = ro.get("decisions").and_then(|v| v.as_array()) {
            println!("  decisions:");
            for d in decisions {
                println!(
                    "    [{:>8}ms] {:<9} f={:.2} {:<10} {}",
                    geti(d, "at_ms"),
                    gets(d, "phase"),
                    getf(d, "fraction"),
                    gets(d, "action"),
                    gets(d, "reason"),
                );
            }
        }
    }
}

fn publish_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    let synthetic = args.opts.contains_key("synthetic");
    let weights = match args.opts.get("weights") {
        Some(w) => Some(w.clone()),
        None if synthetic => None,
        None => {
            return Err(kan_edge::Error::Registry(
                "publish requires --weights FILE (or --synthetic)".into(),
            ))
        }
    };
    let version = match args.opts.get("version") {
        None => None,
        Some(v) => Some(v.parse::<u32>().map_err(|_| {
            kan_edge::Error::Registry(format!(
                "--version must be an unsigned integer (got '{v}')"
            ))
        })?),
    };
    // publishing into a fresh directory bootstraps an empty v2 manifest
    let dir = Path::new(&cfg.artifacts.dir);
    if !dir.join("manifest.json").exists() {
        std::fs::create_dir_all(dir)?;
        kan_edge::registry::ModelManifest::empty().save(dir)?;
    }
    let registry = ModelRegistry::open(cfg)?;
    // --synthetic: generate a tiny deterministic checkpoint (same fixture
    // the tests use) and publish it — lets CI bring up a cluster node
    // with a servable model without shipping weight files around
    let mut staged: Option<std::path::PathBuf> = None;
    let weights = match weights {
        Some(w) => std::path::PathBuf::from(w),
        None => {
            let name = args.get("model", "synthetic");
            let path = dir.join(format!(".synthetic-{}.incoming.json", std::process::id()));
            std::fs::write(
                &path,
                kan_edge::kan::checkpoint::synthetic_checkpoint_json(&name, 0),
            )?;
            staged = Some(path.clone());
            path
        }
    };
    let publish = registry.publish_file(
        &weights,
        args.opts.get("model").map(|s| s.as_str()),
        version,
    );
    if let Some(p) = staged {
        let _ = std::fs::remove_file(p);
    }
    let (name, meta) = publish?;
    println!(
        "published {name}@{} (digest {})",
        meta.version,
        meta.digest.as_deref().unwrap_or("?")
    );
    if let Some(a) = meta.accuracy {
        println!("  accuracy: {a:.4}");
    }
    if let Some(h) = &meta.hw_cost {
        println!(
            "  hw cost:  {:.4} mm2, {:.1} pJ, {:.0} ns",
            h.area_mm2, h.energy_pj, h.latency_ns
        );
    }
    Ok(())
}

/// Scrape a serving endpoint's metrics. `--prom` renders the Prometheus
/// exposition text (the `metrics_prom` verb) and re-validates it
/// client-side before printing — an unparseable scrape is a hard error,
/// which is what CI keys on. The default prints the `metrics` JSON
/// body. `--demo` publishes a synthetic model into a temp registry,
/// serves it in-process with tracing at 1-in-1, drives a few dozen
/// requests, and scrapes that — an exposition-plane smoke test needing
/// no running deployment.
fn metrics_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    let prom = args.opts.contains_key("prom");
    let scrape = |client: &mut KanClient| -> Result<String> {
        if prom {
            let text = client.metrics_prom()?;
            kan_edge::obs::prom::validate(&text).map_err(|e| {
                kan_edge::Error::Serving(format!(
                    "metrics_prom returned invalid exposition text: {e}"
                ))
            })?;
            Ok(text)
        } else {
            Ok(client.metrics()?.to_string())
        }
    };
    let out = if args.opts.contains_key("demo") {
        let mut cfg = cfg.clone();
        cfg.observability.sample_every = 1; // trace every demo request
        let (dir, server) = spawn_bench_server(&cfg, "metrics_demo")?;
        let mut client = KanClient::connect(server.addr)?;
        let mut lg = kan_edge::data::LoadGen::new(0x0B5, 2);
        for _ in 0..32 {
            client.infer(&lg.next_vec())?;
        }
        let text = scrape(&mut client);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
        text?
    } else {
        let addr = args.get("addr", "127.0.0.1:7777");
        let mut client = KanClient::connect(addr.as_str())?;
        scrape(&mut client)?
    };
    println!("{out}");
    Ok(())
}

/// `(requests, batches)` served so far by the (single) bench model;
/// `(0, 0)` before its pipeline first loads.
fn served_counts(client: &mut KanClient) -> Result<(i64, i64)> {
    let body = client.metrics()?;
    let report = body
        .field("models")?
        .as_object()
        .and_then(|m| m.values().next())
        .cloned();
    Ok(match report {
        Some(r) => (
            r.get("requests").and_then(|v| v.as_i64()).unwrap_or(0),
            r.get("batches").and_then(|v| v.as_i64()).unwrap_or(0),
        ),
        None => (0, 0),
    })
}

fn mean_batch_delta(prev: (i64, i64), now: (i64, i64)) -> f64 {
    let dreq = (now.0 - prev.0) as f64;
    let dbatch = (now.1 - prev.1) as f64;
    if dbatch > 0.0 {
        dreq / dbatch
    } else {
        0.0
    }
}

/// Fresh temp registry serving one synthetic "bench" model over an
/// ephemeral TCP port with `cfg`'s server/scheduler knobs.
fn spawn_bench_server(
    cfg: &AppConfig,
    tag: &str,
) -> Result<(std::path::PathBuf, kan_edge::coordinator::TcpServer)> {
    spawn_bench_server_with(
        cfg,
        tag,
        &kan_edge::kan::checkpoint::synthetic_checkpoint_json("bench", 0),
    )
}

/// Fresh temp registry with one published synthetic "bench" model — the
/// building block of the bench servers and the cluster-phase nodes.
/// Returns the registry dir, the adjusted config, and the open registry.
fn bench_registry_with(
    cfg: &AppConfig,
    tag: &str,
    ckpt_json: &str,
) -> Result<(std::path::PathBuf, AppConfig, Arc<ModelRegistry>)> {
    // per-process, per-phase dir: concurrent bench-net runs must not
    // wipe each other's live registry mid-benchmark
    let dir = std::env::temp_dir()
        .join(format!("kan_edge_bench_net_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    kan_edge::registry::ModelManifest::empty().save(&dir)?;
    let mut cfg = cfg.clone();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = "bench".into();
    cfg.server.backend = BackendKind::Digital;
    let registry = ModelRegistry::open(&cfg)?;
    let src = dir.join("bench.incoming.json");
    std::fs::write(&src, ckpt_json)?;
    registry.publish_file(&src, None, None)?;
    Ok((dir, cfg, registry))
}

/// Like [`spawn_bench_server`] with an explicit checkpoint JSON (must
/// name its model "bench" — the registry's default model).
fn spawn_bench_server_with(
    cfg: &AppConfig,
    tag: &str,
    ckpt_json: &str,
) -> Result<(std::path::PathBuf, kan_edge::coordinator::TcpServer)> {
    let (dir, cfg, registry) = bench_registry_with(cfg, tag, ckpt_json)?;
    let target: Arc<dyn Dispatch> = registry;
    // trace hub from cfg.observability, so bench phases can enable
    // sampling by setting `sample_every` before spawning
    let server = kan_edge::coordinator::TcpServer::spawn_with_obs(
        "127.0.0.1:0",
        target,
        tcp_limits(&cfg),
        kan_edge::coordinator::router::trace_hub(&cfg),
    )?;
    Ok((dir, server))
}

/// Digital hot-path phase: serve a realistically sized synthetic KAN
/// (dims [17, 8, 14], G=5, K=3) with the planned engine disabled vs
/// enabled and measure served v2 whole-batch throughput — the
/// end-to-end before/after of the planned execution engine
/// (`docs/ENGINE.md`; the isolated kernel numbers live in
/// `cargo bench --bench hotpath`).
fn run_hotpath_mode(
    cfg: &AppConfig,
    engine: bool,
    requests: usize,
    batch: usize,
) -> Result<f64> {
    use std::time::Instant;

    let mut cfg = cfg.clone();
    cfg.server.engine = engine;
    let ckpt = kan_edge::kan::checkpoint::synthetic_kan_checkpoint(
        "bench",
        &[17, 8, 14],
        5,
        3,
        0xB16,
    );
    let tag = if engine { "hot_on" } else { "hot_off" };
    let (dir, server) =
        spawn_bench_server_with(&cfg, tag, &ckpt.to_value().to_string())?;
    let mut client = KanClient::connect(server.addr)?;
    // deterministic *varied* rows (same stream for both modes): a constant
    // row would keep one LUT code hot and flatter the engine's caches
    let mut lg = kan_edge::data::LoadGen::new(0x40B, 17);
    client.infer(&lg.next_vec())?; // warm the pipeline
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < requests {
        let n = batch.min(requests - done);
        client.infer_batch(None, lg.batch(n))?;
        done += n;
    }
    let secs = t0.elapsed().as_secs_f64();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(requests as f64 / secs.max(1e-9))
}

/// One sampling mode of the request-tracing overhead phase: drive
/// `requests` single-row synchronous infers and report the
/// client-observed latency p50/p99 in microseconds.
fn run_trace_mode(
    cfg: &AppConfig,
    sample_every: u64,
    requests: usize,
) -> Result<(u64, u64)> {
    use std::time::Instant;

    let mut cfg = cfg.clone();
    cfg.observability.sample_every = sample_every;
    let (dir, server) = spawn_bench_server(&cfg, &format!("trace_{sample_every}"))?;
    let mut client = KanClient::connect(server.addr)?;
    let mut lg = kan_edge::data::LoadGen::new(0x7AC3, 2);
    client.infer(&lg.next_vec())?; // warm the pipeline
    let mut lat = Vec::with_capacity(requests);
    for _ in 0..requests {
        let t0 = Instant::now();
        client.infer(&lg.next_vec())?;
        lat.push(t0.elapsed().as_micros() as u64);
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    lat.sort_unstable();
    Ok((
        kan_edge::coordinator::metrics::percentile(&lat, 0.50),
        kan_edge::coordinator::metrics::percentile(&lat, 0.99),
    ))
}

/// Digital-vs-ACIM served phase: serve a synthetic KAN with the digital
/// primary mirrored by an ACIM shadow (fraction 0.5), drive digital
/// traffic plus a burst of per-request `backend: "acim"` infers, wait
/// for the mirror to drain, and report served throughput per backend
/// alongside the online divergence statistics the shadow collected —
/// the paper's non-ideal-effect numbers measured from the serving loop.
fn run_shadow_phase(
    cfg: &AppConfig,
    requests: usize,
    batch: usize,
) -> Result<kan_edge::util::json::Value> {
    use kan_edge::util::json::{obj, Value};
    use std::time::{Duration, Instant};

    let mut cfg = cfg.clone();
    cfg.server.shadow.backend = Some(BackendKind::Acim);
    cfg.server.shadow.fraction = 0.5;
    cfg.server.shadow.queue = 4096;
    // a checkpoint with real spline mass (the [2,2] routing fixture has
    // all-zero coefficients, which an analog crossbar reproduces exactly)
    let ckpt = kan_edge::kan::checkpoint::synthetic_kan_checkpoint(
        "bench",
        &[8, 8, 4],
        5,
        3,
        0x5AD,
    );
    let (dir, server) =
        spawn_bench_server_with(&cfg, "shadow", &ckpt.to_value().to_string())?;
    let mut client = KanClient::connect(server.addr)?;
    let mut lg = kan_edge::data::LoadGen::new(0x5AD0, 8);
    client.infer(&lg.next_vec())?; // load the pipeline

    // digital primary traffic (mirrored at the configured fraction)
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < requests {
        let n = batch.min(requests - done);
        client.infer_batch(None, lg.batch(n))?;
        done += n;
    }
    let digital_rps = requests as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // explicit per-request ACIM selection on the same connection
    let acim_requests = (requests / 10).max(20);
    let opts = CallOptions {
        backend: Some(BackendKind::Acim),
        seed: Some(0xCAB),
        trials: 1,
        ..CallOptions::default()
    };
    let t0 = Instant::now();
    for _ in 0..acim_requests {
        client.infer_opts(None, &lg.next_vec(), &opts)?;
    }
    let acim_rps = acim_requests as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // wait (bounded) for the mirror queue to drain so the report covers
    // every sampled row
    let shadow_of = |client: &mut KanClient| -> Result<Option<Value>> {
        let body = client.metrics()?;
        Ok(body
            .field("models")?
            .get("bench@1")
            .and_then(|m| m.get("shadow"))
            .cloned())
    };
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut shadow = shadow_of(&mut client)?;
    while Instant::now() < deadline {
        let done = shadow.as_ref().is_some_and(|s| {
            let count = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
            count("mirrored") + count("dropped") + count("errors") >= count("sampled")
        });
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
        shadow = shadow_of(&mut client)?;
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    let shadow = shadow.unwrap_or(Value::Null);

    println!(
        "\nshadow phase: digital primary + acim mirror (fraction 0.5), \
         {requests} digital + {acim_requests} acim-selected requests"
    );
    println!("  digital     {digital_rps:>11.0} req/s");
    println!("  acim        {acim_rps:>11.0} req/s (per-request backend selection)");
    if let Some(s) = shadow.as_object() {
        let geti = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        let getf = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "  mirrored {} of {} sampled ({} dropped); argmax flip rate {:.4}, \
             logit MAE mean {:.5} (p99 {:.5})",
            geti("mirrored"),
            geti("sampled"),
            geti("dropped"),
            getf("flip_rate"),
            getf("logit_mae_mean"),
            getf("logit_mae_p99"),
        );
    }
    Ok(obj(vec![
        ("digital_rps", Value::Float(digital_rps)),
        ("acim_rps", Value::Float(acim_rps)),
        ("acim_requests", Value::Int(acim_requests as i64)),
        ("divergence", shadow),
    ]))
}

/// One policy's mixed-tenant measurements.
struct MixedPolicyReport {
    policy: String,
    singleton_ops: usize,
    /// Client-observed `overloaded` rejections across all singleton
    /// tenants (each is one failed admission + backoff + retry).
    rejections: u64,
    /// Singleton latency from *first* attempt to success — retries and
    /// backoff sleeps count, because that is what the tenant experiences.
    p50_us: u64,
    p99_us: u64,
    /// Longest gap between consecutive singleton completions on any one
    /// tenant: the starvation window.
    max_starvation_us: u64,
    /// Rows the batch tenant pushed through while the singletons ran.
    batch_rows: u64,
    wall_secs: f64,
}

impl MixedPolicyReport {
    fn to_value(&self) -> kan_edge::util::json::Value {
        use kan_edge::util::json::Value;
        kan_edge::util::json::obj(vec![
            ("policy", Value::Str(self.policy.clone())),
            ("singleton_ops", Value::Int(self.singleton_ops as i64)),
            ("rejections", Value::Int(self.rejections as i64)),
            ("p50_us", Value::Int(self.p50_us as i64)),
            ("p99_us", Value::Int(self.p99_us as i64)),
            ("max_starvation_us", Value::Int(self.max_starvation_us as i64)),
            ("batch_rows", Value::Int(self.batch_rows as i64)),
            ("wall_s", Value::Float(self.wall_secs)),
        ])
    }
}

/// Mixed-tenant phase: one batch tenant loops whole-batch submits while
/// `tenants` single-row tenants each run `ops` requests on their own
/// connections, retrying with the server's `retry_after_ms` hint on
/// `overloaded`. Under `fifo` the batch holds the queue at capacity and
/// starves the singletons; under `drr` the per-connection quota caps the
/// batch's queue share and round-robin admission interleaves, so the
/// singletons see zero rejections. This is the end-to-end proof of the
/// fairness win.
fn run_mixed_policy(
    cfg: &AppConfig,
    policy: &str,
    tenants: usize,
    ops: usize,
    batch_rows: usize,
    queue: usize,
) -> Result<MixedPolicyReport> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};

    let mut cfg = cfg.clone();
    cfg.server.queue_depth = queue;
    cfg.scheduler.policy = policy.to_string();
    // the batch tenant may hold at most a quarter of the queue
    cfg.scheduler.quota = (queue / 4).max(1);
    cfg.scheduler.fairness_window = 8;
    let (dir, server) = spawn_bench_server(&cfg, &format!("mixed_{policy}"))?;
    let addr = server.addr;

    // warm up: load the pipeline before contention starts
    KanClient::connect(addr)?.infer(&[0.5, 0.5])?;

    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let batch_tenant = std::thread::spawn(move || -> Result<u64> {
        let mut client = KanClient::connect(addr)?;
        let mut total = 0u64;
        while !stop2.load(Ordering::Relaxed) {
            let rows: Vec<Vec<f32>> = vec![vec![0.5, 0.5]; batch_rows];
            match client.infer_batch(None, rows) {
                Ok(_) => total += batch_rows as u64,
                Err(kan_edge::Error::Overloaded { retry_after_ms, .. }) => {
                    std::thread::sleep(Duration::from_millis(
                        retry_after_ms.clamp(1, 20),
                    ));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(total)
    });

    let t0 = Instant::now();
    let mut singles = Vec::new();
    for _ in 0..tenants {
        singles.push(std::thread::spawn(
            move || -> Result<(Vec<u64>, u64, u64)> {
                let mut client = KanClient::connect(addr)?;
                let mut latencies = Vec::with_capacity(ops);
                let mut rejections = 0u64;
                let mut max_gap_us = 0u64;
                let mut last_done = Instant::now();
                for _ in 0..ops {
                    let start = Instant::now();
                    loop {
                        match client.infer(&[0.5, 0.5]) {
                            Ok(_) => break,
                            Err(kan_edge::Error::Overloaded {
                                retry_after_ms, ..
                            }) => {
                                rejections += 1;
                                std::thread::sleep(Duration::from_millis(
                                    retry_after_ms.clamp(1, 20),
                                ));
                            }
                            Err(e) => return Err(e),
                        }
                        if start.elapsed() > Duration::from_secs(10) {
                            return Err(kan_edge::Error::Serving(
                                "singleton starved for >10s".into(),
                            ));
                        }
                    }
                    latencies.push(start.elapsed().as_micros() as u64);
                    max_gap_us =
                        max_gap_us.max(last_done.elapsed().as_micros() as u64);
                    last_done = Instant::now();
                }
                Ok((latencies, rejections, max_gap_us))
            },
        ));
    }

    // join everything and tear the server down BEFORE propagating any
    // tenant error: an early `?` here would leak the batch tenant as a
    // busy-loop against a server that never shuts down
    let singleton_results: Vec<Result<(Vec<u64>, u64, u64)>> = singles
        .into_iter()
        .map(|h| h.join().expect("singleton tenant panicked"))
        .collect();
    let wall_secs = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let batch_result = batch_tenant.join().expect("batch tenant panicked");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    let mut latencies = Vec::with_capacity(tenants * ops);
    let mut rejections = 0u64;
    let mut max_starvation_us = 0u64;
    for r in singleton_results {
        let (lat, rej, gap) = r?;
        latencies.extend(lat);
        rejections += rej;
        max_starvation_us = max_starvation_us.max(gap);
    }
    let batch_rows_done = batch_result?;

    latencies.sort_unstable();
    Ok(MixedPolicyReport {
        policy: policy.to_string(),
        singleton_ops: latencies.len(),
        rejections,
        p50_us: kan_edge::coordinator::metrics::percentile(&latencies, 0.50),
        p99_us: kan_edge::coordinator::metrics::percentile(&latencies, 0.99),
        max_starvation_us,
        batch_rows: batch_rows_done,
        wall_secs,
    })
}

/// Dispatch wrapper injecting a runtime-adjustable delay before every
/// forwarded call — the deliberately slow replica of the cluster bench
/// phase. Everything else passes through unchanged.
struct SlowDispatch {
    inner: Arc<dyn Dispatch>,
    delay_ms: Arc<std::sync::atomic::AtomicU64>,
}

impl SlowDispatch {
    fn stall(&self) {
        let ms = self.delay_ms.load(std::sync::atomic::Ordering::Relaxed);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

impl Dispatch for SlowDispatch {
    fn dispatch(
        &self,
        client: kan_edge::coordinator::ClientId,
        route: &kan_edge::coordinator::RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, kan_edge::coordinator::RowOutput)> {
        self.stall();
        self.inner.dispatch(client, route, features)
    }

    fn dispatch_batch(
        &self,
        client: kan_edge::coordinator::ClientId,
        route: &kan_edge::coordinator::RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<kan_edge::coordinator::RowOutput>)> {
        self.stall();
        self.inner.dispatch_batch(client, route, rows)
    }

    fn model_summaries(&self) -> Vec<kan_edge::coordinator::ModelSummary> {
        self.inner.model_summaries()
    }

    fn metrics_reports(&self) -> Vec<(String, kan_edge::coordinator::MetricsReport)> {
        self.inner.metrics_reports()
    }

    fn live_model_count(&self) -> usize {
        self.inner.live_model_count()
    }

    fn pull_artifact(
        &self,
        digest: &str,
    ) -> Result<(Option<kan_edge::util::json::Value>, Vec<u8>)> {
        self.inner.pull_artifact(digest)
    }

    fn push_artifact(
        &self,
        name: &str,
        version: Option<u32>,
        digest: &str,
        data: &[u8],
    ) -> Result<String> {
        self.inner.push_artifact(name, version, digest, data)
    }

    fn rollout_start(&self, model: &str, baseline: &str) -> Result<kan_edge::util::json::Value> {
        self.inner.rollout_start(model, baseline)
    }

    fn rollout_status(&self, model: Option<&str>) -> Result<kan_edge::util::json::Value> {
        self.inner.rollout_status(model)
    }

    fn rollout_abort(&self, model: &str) -> Result<kan_edge::util::json::Value> {
        self.inner.rollout_abort(model)
    }

    fn rollout_clear(&self, model: &str) -> Result<kan_edge::util::json::Value> {
        self.inner.rollout_clear(model)
    }
}

/// Cluster phase: 3 single-model nodes behind a [`ClusterRouter`]
/// (replication 2). Measures the router-hop overhead (direct-to-primary
/// vs routed p50/p99) and then injects a 25 ms delay into the primary
/// replica to show hedged retries bounding the routed p99 far below the
/// injected latency, reporting the hedge fire/win counters.
fn run_cluster_phase(
    cfg: &AppConfig,
    requests: usize,
) -> Result<kan_edge::util::json::Value> {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Instant;

    use kan_edge::coordinator::metrics::percentile;
    use kan_edge::util::json::{obj, Value};

    const SLOW_MS: u64 = 25;
    let n = requests.clamp(40, 300);

    let mut dirs = Vec::new();
    let mut servers = Vec::new();
    let mut delays: Vec<Arc<AtomicU64>> = Vec::new();
    let mut nodes = Vec::new();
    for i in 0..3 {
        let (dir, node_cfg, registry) = bench_registry_with(
            cfg,
            &format!("cluster_{i}"),
            &kan_edge::kan::checkpoint::synthetic_checkpoint_json("bench", 0),
        )?;
        let delay = Arc::new(AtomicU64::new(0));
        let inner: Arc<dyn Dispatch> = registry;
        let target: Arc<dyn Dispatch> =
            Arc::new(SlowDispatch { inner, delay_ms: delay.clone() });
        let server = kan_edge::coordinator::TcpServer::spawn_with_identity(
            "127.0.0.1:0",
            target,
            tcp_limits(&node_cfg),
            kan_edge::coordinator::router::trace_hub(&node_cfg),
            Some(kan_edge::coordinator::NodeIdentity::new(format!("bench-node-{i}"))),
        )?;
        nodes.push(server.addr.to_string());
        dirs.push(dir);
        servers.push(server);
        delays.push(delay);
    }

    let ropts = kan_edge::cluster::RouterOptions {
        replication: 2,
        heartbeat_ms: 100,
        hedge_min_ms: 1,
        hedge_max_ms: 5,
        ..kan_edge::cluster::RouterOptions::default()
    };
    let router = kan_edge::cluster::ClusterRouter::new(nodes.clone(), ropts)?;
    let primary = router.placement("bench")[0];
    let router_target: Arc<dyn Dispatch> = router;
    let router_server =
        kan_edge::coordinator::TcpServer::spawn("127.0.0.1:0", router_target)?;

    let measure = |client: &mut KanClient, n: usize| -> Result<(u64, u64)> {
        let mut lg = kan_edge::data::LoadGen::new(0xC1A5, 2);
        client.infer_model(Some("bench"), &lg.next_vec())?; // warm
        let mut lat = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            client.infer_model(Some("bench"), &lg.next_vec())?;
            lat.push(t0.elapsed().as_micros() as u64);
        }
        lat.sort_unstable();
        Ok((percentile(&lat, 0.50), percentile(&lat, 0.99)))
    };

    // direct to the model's primary replica, then through the router
    let mut direct_client = KanClient::connect(servers[primary].addr)?;
    let (direct_p50, direct_p99) = measure(&mut direct_client, n)?;
    let mut routed_client = KanClient::connect(router_server.addr)?;
    let (routed_p50, routed_p99) = measure(&mut routed_client, n)?;

    // slow down the primary: hedged reissues to the other replica keep
    // the routed tail far below the injected delay
    delays[primary].store(SLOW_MS, Ordering::Relaxed);
    let (slow_p50, slow_p99) = measure(&mut routed_client, n)?;
    delays[primary].store(0, Ordering::Relaxed);

    let body = routed_client.metrics()?;
    let counter = |k: &str| -> i64 {
        body.get("cluster")
            .and_then(|c| c.get(k))
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
    };
    let (hedges, hedge_wins) = (counter("hedges"), counter("hedge_wins"));

    router_server.shutdown();
    for s in &servers {
        s.shutdown();
    }
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }

    println!(
        "\ncluster: 3 nodes + router, replication 2 ({n} single-row requests \
         per mode)"
    );
    println!("{:<28} {:>10} {:>10}", "mode", "p50(us)", "p99(us)");
    println!("{:<28} {:>10} {:>10}", "direct (primary node)", direct_p50, direct_p99);
    println!("{:<28} {:>10} {:>10}", "routed", routed_p50, routed_p99);
    println!(
        "{:<28} {:>10} {:>10}",
        format!("routed, primary +{SLOW_MS}ms"),
        slow_p50,
        slow_p99
    );
    if hedges > 0 {
        println!(
            "  hedges fired {hedges}, won {hedge_wins} ({:.0}% win rate); \
             injected primary latency {SLOW_MS}ms",
            100.0 * hedge_wins as f64 / hedges as f64
        );
    }
    Ok(obj(vec![
        ("requests", Value::Int(n as i64)),
        ("slow_node_ms", Value::Int(SLOW_MS as i64)),
        ("direct_p50_us", Value::Int(direct_p50 as i64)),
        ("direct_p99_us", Value::Int(direct_p99 as i64)),
        ("routed_p50_us", Value::Int(routed_p50 as i64)),
        ("routed_p99_us", Value::Int(routed_p99 as i64)),
        ("slow_routed_p50_us", Value::Int(slow_p50 as i64)),
        ("slow_routed_p99_us", Value::Int(slow_p99 as i64)),
        ("hedges", Value::Int(hedges)),
        ("hedge_wins", Value::Int(hedge_wins)),
    ]))
}

/// Rollout canary phase: price the dispatch-path splitter at fraction
/// 0. Measures single-row p50/p99 with no rollout, then publishes a v2
/// over the wire (hot-swap shelves v1 as the warm baseline), starts a
/// rollout parked at fraction 0.0 (one-step ramp of 0.0 under an
/// unreachable window), and re-measures: every request now consults the
/// splitter but none reach the canary, isolating the pure split
/// overhead. The documented target (`docs/ROLLOUT.md`) is no measurable
/// p99 regression.
fn run_rollout_phase(
    cfg: &AppConfig,
    requests: usize,
) -> Result<kan_edge::util::json::Value> {
    use std::time::Instant;

    use kan_edge::coordinator::metrics::percentile;
    use kan_edge::util::json::{obj, Value};

    let n = requests.clamp(100, 1000);
    let mut cfg = cfg.clone();
    cfg.rollout.ramp = vec![0.0];
    cfg.rollout.window_ms = 3_600_000;
    cfg.rollout.min_samples = usize::MAX;
    let (dir, server) = spawn_bench_server(&cfg, "rollout")?;
    let mut client = KanClient::connect(server.addr)?;
    let mut lg = kan_edge::data::LoadGen::new(0x0110, 2);
    client.infer(&lg.next_vec())?; // load v1 live

    let mut measure = |client: &mut KanClient, n: usize| -> Result<(u64, u64)> {
        let mut lat = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            client.infer(&lg.next_vec())?;
            lat.push(t0.elapsed().as_micros() as u64);
        }
        lat.sort_unstable();
        Ok((percentile(&lat, 0.50), percentile(&lat, 0.99)))
    };
    let (off_p50, off_p99) = measure(&mut client, n)?;

    let ckpt = kan_edge::kan::checkpoint::synthetic_checkpoint_json("bench", 1);
    client.push_artifact("bench", Some(2), ckpt.as_bytes())?;
    client.rollout_start("bench@2", "bench@1")?;
    let (on_p50, on_p99) = measure(&mut client, n)?;

    let status = client.rollout_status(Some("bench"))?;
    let fraction = status
        .get("rollouts")
        .and_then(|r| r.get("bench"))
        .and_then(|ro| ro.get("fraction"))
        .and_then(|v| v.as_f64())
        .unwrap_or(-1.0);
    client.rollout_abort("bench")?;
    client.rollout_clear("bench")?;
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    let ratio = on_p99 as f64 / (off_p99 as f64).max(1.0);
    println!(
        "\nrollout canary phase: splitter at fraction {fraction} \
         ({n} single-row requests per mode)"
    );
    println!("{:<24} {:>10} {:>10}", "mode", "p50(us)", "p99(us)");
    println!("{:<24} {:>10} {:>10}", "no rollout", off_p50, off_p99);
    println!("{:<24} {:>10} {:>10}", "rollout @ fraction 0", on_p50, on_p99);
    println!("  split overhead: {ratio:.2}x p99 (target: ~1.0x)");
    Ok(obj(vec![
        ("requests", Value::Int(n as i64)),
        ("fraction", Value::Float(fraction)),
        ("off_p50_us", Value::Int(off_p50 as i64)),
        ("off_p99_us", Value::Int(off_p99 as i64)),
        ("on_p50_us", Value::Int(on_p50 as i64)),
        ("on_p99_us", Value::Int(on_p99 as i64)),
        ("p99_ratio", Value::Float(ratio)),
    ]))
}

/// Self-contained network benchmark: publish a tiny synthetic KAN into
/// a temp registry, serve it on an ephemeral port (digital backend),
/// and measure served throughput over one connection in three modes —
/// v1 JSON lines (one request in flight), v2 pipelined submit/poll,
/// and v2 whole-batch submit. The per-phase "mean batch" column is the
/// batch occupancy the *server* saw, showing that v2 lets a single
/// connection feed the dynamic batcher multi-row batches.
///
/// A fourth, mixed-tenant phase (skip with `--skip-mixed`; run alone
/// with `--mixed-only`) pits one whole-batch tenant against `--tenants`
/// single-row tenants under `fifo` vs `drr` admission and reports
/// singleton rejections, p50/p99, and the worst starvation window —
/// the end-to-end fairness comparison. `--json FILE` writes the full
/// machine-readable report (CI archives it for the perf trajectory).
fn bench_net_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::time::Instant;

    use kan_edge::util::json::{arr, obj, Value};

    let requests = args.get_usize("requests", 2000).max(1);
    let batch = args.get_usize("batch", 16).max(1);
    let mut window = args.get_usize("window", 32).max(1);
    let tenants = args.get_usize("tenants", 4).max(1);
    let mix_requests = args.get_usize("mix-requests", 200).max(1);
    let mix_batch = args.get_usize("mix-batch", 256).max(1);
    let mix_queue = args.get_usize("mix-queue", 64).max(4);
    let mixed_only = args.opts.contains_key("mixed-only");
    let skip_mixed = args.opts.contains_key("skip-mixed");
    let skip_hotpath = args.opts.contains_key("skip-hotpath");
    let skip_shadow = args.opts.contains_key("skip-shadow");
    let skip_trace = args.opts.contains_key("skip-trace");
    let skip_cluster = args.opts.contains_key("skip-cluster");
    let skip_rollout = args.opts.contains_key("skip-rollout");

    let mut phases: Vec<(String, f64, f64)> = Vec::new();
    if !mixed_only {
        let (dir, server) = spawn_bench_server(cfg, "modes")?;
        println!(
            "bench-net: {requests} requests per mode, digital backend, {}",
            server.addr
        );
        let features = vec![0.5f32, 0.5];
        // separate control connection: reads (requests, batches) deltas
        // between phases for the exact per-phase batch occupancy
        let mut probe = KanClient::connect(server.addr)?;
        let mut last = served_counts(&mut probe)?;

        // v1: JSON lines, the connection blocks until each reply arrives
        let t0 = Instant::now();
        {
            let conn = std::net::TcpStream::connect(server.addr)?;
            let mut w = conn.try_clone()?;
            let mut r = BufReader::new(conn);
            let mut line = String::new();
            for _ in 0..requests {
                w.write_all(b"{\"features\":[0.5,0.5]}\n")?;
                line.clear();
                r.read_line(&mut line)?;
            }
        }
        let v1_secs = t0.elapsed().as_secs_f64();
        let now = served_counts(&mut probe)?;
        phases.push(("v1 single-request".into(), v1_secs, mean_batch_delta(last, now)));
        last = now;

        // v2 pipelined: keep `window` requests in flight on one
        // connection. Clamp to the negotiated cap: beyond it the server
        // reader stops pulling frames, and submitting without polling
        // past that point would deadlock both directions once the socket
        // buffers fill.
        let mut client = KanClient::connect(server.addr)?;
        window = window.min(client.server_info().max_in_flight);
        let t0 = Instant::now();
        let (mut submitted, mut done) = (0usize, 0usize);
        while done < requests {
            while submitted < requests && submitted - done < window {
                client.submit(None, &features)?;
                submitted += 1;
            }
            let (_id, outcome) = client.poll()?;
            outcome?;
            done += 1;
        }
        let v2p_secs = t0.elapsed().as_secs_f64();
        let now = served_counts(&mut probe)?;
        phases.push((
            format!("v2 pipelined (w={window})"),
            v2p_secs,
            mean_batch_delta(last, now),
        ));
        last = now;

        // v2 batch submit: whole `rows` batches in one frame
        let t0 = Instant::now();
        let mut done = 0usize;
        while done < requests {
            let n = batch.min(requests - done);
            let rows: Vec<Vec<f32>> = vec![features.clone(); n];
            client.infer_batch(None, rows)?;
            done += n;
        }
        let v2b_secs = t0.elapsed().as_secs_f64();
        let now = served_counts(&mut probe)?;
        phases.push((
            format!("v2 batch (b={batch})"),
            v2b_secs,
            mean_batch_delta(last, now),
        ));

        println!(
            "{:<24} {:>9} {:>9} {:>11} {:>11}",
            "mode", "requests", "wall(s)", "req/s", "mean batch"
        );
        for (name, secs, mean) in &phases {
            println!(
                "{:<24} {:>9} {:>9.2} {:>11.0} {:>11.2}",
                name,
                requests,
                secs,
                requests as f64 / secs.max(1e-9),
                mean
            );
        }
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    // digital hot path: scalar reference vs planned engine, end to end
    let mut hotpath: Vec<(String, f64)> = Vec::new();
    if !mixed_only && !skip_hotpath {
        println!(
            "\ndigital hot path: scalar reference vs planned engine \
             ({requests} requests, batch {batch}, dims [17, 8, 14])"
        );
        for engine in [false, true] {
            let rps = run_hotpath_mode(cfg, engine, requests, batch)?;
            let name = if engine { "engine" } else { "reference" };
            println!("  {name:<10} {rps:>11.0} req/s");
            hotpath.push((name.to_string(), rps));
        }
        if let (Some(rf), Some(en)) = (hotpath.first(), hotpath.get(1)) {
            if rf.1 > 0.0 {
                println!(
                    "  engine speedup: {:.2}x (served; wire + batching included)",
                    en.1 / rf.1
                );
            }
        }
    }

    // digital-vs-ACIM served phase with online shadow divergence
    let mut shadow_report = kan_edge::util::json::Value::Null;
    if !mixed_only && !skip_shadow {
        shadow_report = run_shadow_phase(cfg, requests.min(400), batch)?;
    }

    // request-tracing overhead: sampling off vs the default 1-in-16 vs
    // trace-everything 1-in-1, under the same synchronous load. The
    // documented contract (docs/OBSERVABILITY.md): 1-in-1 tracing may
    // cost at most 2x the untraced p99.
    let mut tracing: Vec<(u64, u64, u64)> = Vec::new();
    if !mixed_only && !skip_trace {
        let n = requests.min(1000);
        println!("\nrequest-tracing overhead ({n} single-row requests per mode)");
        println!("{:<10} {:>10} {:>10}", "sampling", "p50(us)", "p99(us)");
        for every in [0u64, 16, 1] {
            let (p50, p99) = run_trace_mode(cfg, every, n)?;
            let name = match every {
                0 => "off".to_string(),
                e => format!("1-in-{e}"),
            };
            println!("{name:<10} {p50:>10} {p99:>10}");
            tracing.push((every, p50, p99));
        }
        if let (Some(off), Some(all)) = (tracing.first(), tracing.get(2)) {
            let ratio = all.2 as f64 / (off.2 as f64).max(1.0);
            println!(
                "  1-in-1 p99 overhead: {ratio:.2}x untraced \
                 (documented bound 2.0x)"
            );
            if ratio > 2.0 {
                println!(
                    "  WARNING: tracing overhead exceeds the documented 2.0x \
                     p99 bound"
                );
            }
        }
    }

    // routed-vs-direct cluster phase with an injected slow replica
    let mut cluster_report = kan_edge::util::json::Value::Null;
    if !mixed_only && !skip_cluster {
        cluster_report = run_cluster_phase(cfg, requests)?;
    }

    // rollout canary phase: split overhead at fraction 0
    let mut rollout_report = kan_edge::util::json::Value::Null;
    if !mixed_only && !skip_rollout {
        rollout_report = run_rollout_phase(cfg, requests)?;
    }

    let mut mixed: Vec<MixedPolicyReport> = Vec::new();
    if !skip_mixed {
        println!(
            "\nmixed-tenant: 1 batch tenant ({mix_batch} rows/submit) + \
             {tenants} singleton tenants x {mix_requests} requests, \
             queue_depth {mix_queue}"
        );
        for policy in ["fifo", "drr"] {
            mixed.push(run_mixed_policy(
                cfg,
                policy,
                tenants,
                mix_requests,
                mix_batch,
                mix_queue,
            )?);
        }
        println!(
            "{:<8} {:>9} {:>10} {:>10} {:>15} {:>13}",
            "policy", "rejects", "p50(us)", "p99(us)", "max-starve(us)", "batch rows/s"
        );
        for r in &mixed {
            println!(
                "{:<8} {:>9} {:>10} {:>10} {:>15} {:>13.0}",
                r.policy,
                r.rejections,
                r.p50_us,
                r.p99_us,
                r.max_starvation_us,
                r.batch_rows as f64 / r.wall_secs.max(1e-9),
            );
        }
        if let (Some(fifo), Some(drr)) =
            (mixed.first(), mixed.get(1))
        {
            if drr.rejections == 0 && fifo.rejections > 0 {
                println!(
                    "drr admitted every singleton (fifo rejected {}); \
                     singleton p99 {:.1}x lower under drr",
                    fifo.rejections,
                    fifo.p99_us as f64 / (drr.p99_us as f64).max(1.0),
                );
            }
        }
    }

    if let Some(path) = args.opts.get("json") {
        let phase_values: Vec<Value> = phases
            .iter()
            .map(|(name, secs, mean)| {
                obj(vec![
                    ("mode", Value::Str(name.clone())),
                    ("requests", Value::Int(requests as i64)),
                    ("wall_s", Value::Float(*secs)),
                    ("rps", Value::Float(requests as f64 / secs.max(1e-9))),
                    ("mean_batch", Value::Float(*mean)),
                ])
            })
            .collect();
        let hotpath_values: Vec<Value> = hotpath
            .iter()
            .map(|(mode, rps)| {
                obj(vec![
                    ("mode", Value::Str(mode.clone())),
                    ("rps", Value::Float(*rps)),
                ])
            })
            .collect();
        // the served hot-path phase always runs the synthetic checkpoint
        // (spawned servers get a fresh artifacts dir); record it so the
        // numbers are comparable across runs, mirroring BENCH_hotpath.json
        let hotpath_section = obj(vec![
            (
                "checkpoint",
                obj(vec![
                    ("source", Value::Str("synthetic".to_string())),
                    ("model", Value::Str("bench".to_string())),
                    (
                        "dims",
                        arr(vec![Value::Int(17), Value::Int(8), Value::Int(14)]),
                    ),
                    ("g", Value::Int(5)),
                    ("k", Value::Int(3)),
                    ("seed", Value::Str("0xB16".to_string())),
                ]),
            ),
            ("modes", arr(hotpath_values)),
        ]);
        let tracing_values: Vec<Value> = tracing
            .iter()
            .map(|(every, p50, p99)| {
                obj(vec![
                    ("sample_every", Value::Int(*every as i64)),
                    ("p50_us", Value::Int(*p50 as i64)),
                    ("p99_us", Value::Int(*p99 as i64)),
                ])
            })
            .collect();
        let report = obj(vec![
            ("phases", arr(phase_values)),
            ("hotpath", hotpath_section),
            ("shadow", shadow_report),
            ("tracing", arr(tracing_values)),
            ("cluster", cluster_report),
            ("rollout", rollout_report),
            (
                "mixed",
                obj(vec![
                    ("tenants", Value::Int(tenants as i64)),
                    ("ops_per_tenant", Value::Int(mix_requests as i64)),
                    ("batch_rows_per_submit", Value::Int(mix_batch as i64)),
                    ("queue_depth", Value::Int(mix_queue as i64)),
                    (
                        "policies",
                        arr(mixed.iter().map(|r| r.to_value()).collect()),
                    ),
                ]),
            ),
        ]);
        std::fs::write(path, report.to_string())?;
        println!("\nwrote JSON report to {path}");
    }
    Ok(())
}

/// `tune-engine`: run the batch-major engine autotune sweep standalone
/// and merge its report into the hot-path bench JSON, so a tuned config
/// measured on the target device lands in the same artifact CI archives
/// (`docs/PERFORMANCE.md`).
fn tune_engine_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    use kan_edge::util::json::{obj, Value};
    let dir = Path::new(&cfg.artifacts.dir);
    let model_name = args.get("model", "kan2");
    let batch = args.get_usize("batch", 64).max(1);
    let target_ms = args.get_usize("target-ms", 60).max(1) as u64;
    let json_path = args.get("json", "BENCH_hotpath.json");

    // artifact weights when present, the deterministic synthetic
    // fallback otherwise — same policy as benches/hotpath.rs, and the
    // source is recorded in the report for apples-to-apples trajectories
    let loaded = Manifest::load(dir).ok().and_then(|m| {
        m.models
            .get(&model_name)
            .and_then(|e| QuantKanModel::load(dir.join(&e.weights)).ok())
    });
    let (model, source) = match loaded {
        Some(m) => (m, "artifact"),
        None => {
            println!("(artifacts missing; tuning a synthetic {model_name}-shaped checkpoint)");
            let ckpt = kan_edge::kan::checkpoint::synthetic_kan_checkpoint(
                &model_name,
                &[17, 8, 14],
                5,
                3,
                0xCAFE,
            );
            (QuantKanModel::from_checkpoint(&ckpt), "synthetic")
        }
    };

    let report = kan_edge::kan::autotune(&model, batch, target_ms, &[])?;
    println!(
        "{:<8} {:<12} {:>12} {:>12}",
        "block", "threshold", "budget", "ns/op"
    );
    for o in &report.outcomes {
        let c = o.candidate;
        println!(
            "{:<8} {:<12} {:>12} {:>12.0}",
            c.block, c.group_threshold, c.fused_budget, o.ns_per_op
        );
    }
    println!(
        "best: block {} threshold {} budget {} — {:.2}x vs reference, {:.2}x vs default engine",
        report.best.candidate.block,
        report.best.candidate.group_threshold,
        report.best.candidate.fused_budget,
        report.speedup_vs_reference(),
        report.speedup_vs_default()
    );

    // merge into the existing bench report when one is present, so the
    // autotune section rides next to the hot-path numbers
    let mut root = std::fs::read_to_string(&json_path)
        .ok()
        .and_then(|t| Value::parse(&t).ok())
        .unwrap_or_else(|| obj(vec![("schema", Value::Int(2))]));
    if let Value::Object(map) = &mut root {
        map.insert("autotune".to_string(), report.to_value(source));
    }
    std::fs::write(&json_path, root.to_string())?;
    println!("wrote autotune section to {json_path}");
    Ok(())
}

fn eval(cfg: &AppConfig, model: &str, backend: &str) -> Result<()> {
    let dir = Path::new(&cfg.artifacts.dir);
    let manifest = Manifest::load(dir)?;
    let ds = Dataset::load(dir)?;
    let entry = manifest.models.get(model).ok_or_else(|| {
        kan_edge::Error::Artifact(format!("model '{model}' not in manifest"))
    })?;
    let acc = match (backend, entry.kind.as_str()) {
        (_, "mlp") => {
            kan_edge::baseline::MlpModel::load(dir.join(&entry.weights))?.accuracy(&ds)
        }
        ("digital", _) => {
            // the planned engine is the default digital path; it must be
            // argmax-identical to the scalar reference (`digital-ref`)
            let qk = QuantKanModel::load(dir.join(&entry.weights))?;
            match qk.compile(kan_edge::kan::EngineOptions::default()) {
                Ok(engine) => engine.accuracy(&ds),
                Err(e) => {
                    kan_edge::obs::log::warn(
                        "eval",
                        &format!("engine compile failed ({e}); using reference"),
                    );
                    qk.accuracy(&ds)
                }
            }
        }
        ("digital-ref", _) => {
            QuantKanModel::load(dir.join(&entry.weights))?.accuracy(&ds)
        }
        ("acim", _) => {
            let qk = QuantKanModel::load(dir.join(&entry.weights))?;
            build_acim_with_calib(&qk, cfg.hardware.acim, &ds, MappingStrategy::Sam)?
                .accuracy(&ds)
        }
        ("pjrt", _) => {
            let mut cfg2 = cfg.clone();
            cfg2.server.backend = BackendKind::Pjrt;
            let be = build_session(&cfg2, &manifest, model)?;
            eval_backend(be, &ds)
        }
        (other, _) => {
            return Err(kan_edge::Error::Config(format!("unknown backend '{other}'")))
        }
    };
    println!("{model} [{backend}] accuracy = {acc:.4}");
    Ok(())
}

fn eval_backend(be: Arc<dyn ExecutionSession>, ds: &Dataset) -> f64 {
    let rows: Vec<Vec<f32>> = ds.test_rows().map(|(r, _)| r.to_vec()).collect();
    let labels: Vec<u32> = ds.test_rows().map(|(_, y)| y).collect();
    let outs = be.infer_logits(rows).expect("inference failed");
    let correct = outs
        .iter()
        .zip(&labels)
        .filter(|(o, &y)| {
            kan_edge::kan::argmax(&o.iter().map(|&v| v as f64).collect::<Vec<_>>())
                == y as usize
        })
        .count();
    correct as f64 / labels.len().max(1) as f64
}

fn neurosim_cmd(cfg: &AppConfig, budget: &str) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts.dir)?;
    let constraints = match budget {
        "minimal" => HwConstraints::minimal(),
        "moderate" => HwConstraints::moderate(),
        "none" => HwConstraints::default(),
        _ => cfg.neurosim.constraints,
    };
    let out = search(
        &[17, 1, 14],
        &manifest.sweep,
        &cfg.neurosim.tm_modes,
        &constraints,
        &cfg.hardware.tech,
    )?;
    println!(
        "{:>4} {:>4} {:>9} {:>11} {:>11} {:>11} {:>8}",
        "G", "N", "acc", "area(mm2)", "energy(pJ)", "lat(ns)", "admit"
    );
    for c in &out.candidates {
        println!(
            "{:>4} {:>4} {:>9.4} {:>11.4} {:>11.1} {:>11.0} {:>8}",
            c.g,
            c.tm_n,
            c.accuracy,
            c.report.area_mm2,
            c.report.energy_pj,
            c.report.latency_ns,
            c.admitted
        );
    }
    match out.best {
        Some(b) => println!(
            "\nbest: G={} N={} acc={:.4} ({} params)",
            b.g, b.tm_n, b.accuracy, b.report.num_params
        ),
        None => println!("\nno admissible design point under this budget"),
    }
    Ok(())
}

fn quantize_cmd(g: u32, k: u32, n_bits: u32) -> Result<()> {
    let spec = AspSpec::build(g, k, n_bits, 0.0, 1.0)?;
    let lut = ShLut::build(&spec, n_bits);
    println!("ASP-KAN-HAQ geometry for G={g}, K={k}, n={n_bits}:");
    println!(
        "  LD = {} (L = {} levels/interval)",
        spec.ld,
        spec.levels_per_interval()
    );
    println!("  code range R = G*2^LD = {}", spec.range());
    println!("  basis functions G+K = {}", spec.num_basis());
    println!(
        "  SH-LUT: {} rows x {} cols = {} stored entries ({} full)",
        lut.hemi.len(),
        k + 1,
        lut.stored_entries(),
        lut.full_rows() * (k as usize + 1)
    );
    println!(
        "  decoders: ({}-bit global) + ({}-bit local) instead of one {n_bits}-bit",
        n_bits - spec.ld,
        spec.ld
    );
    Ok(())
}

fn print_inputgen(bits: u32, tech: &Tech) {
    println!(
        "{:<14} {:>10} {:>10} {:>9} {:>10} {:>8}",
        "generator", "area(um2)", "power(uW)", "lat(ns)", "margin(mV)", "FOM(x)"
    );
    let reports = fig11_comparison(bits, tech);
    let tm_fom = reports.last().unwrap().fom();
    for r in &reports {
        println!(
            "{:<14} {:>10.1} {:>10.1} {:>9.1} {:>10.1} {:>8.2}",
            r.name,
            r.area_um2,
            r.power_uw,
            r.latency_ns,
            r.noise_margin_v * 1e3,
            r.fom() / tm_fom
        );
    }
}

fn sam_cmd(cfg: &AppConfig, g: u32, array: usize) -> Result<()> {
    let dir = Path::new(&cfg.artifacts.dir);
    let ds = Dataset::load(dir)?;
    let path = dir.join(format!("sweep/kan_g{g}.weights.json"));
    let qk = QuantKanModel::load(&path)?;
    let sw_acc = qk.accuracy(&ds);
    let opts = AcimOptions {
        array: ArrayConfig { rows: array, ..cfg.hardware.acim.array },
        ..cfg.hardware.acim
    };
    let uni =
        build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Uniform)?.accuracy(&ds);
    let sam = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Sam)?.accuracy(&ds);
    println!("G={g}, array={array}:");
    println!("  software (quantized, ideal) accuracy: {sw_acc:.4}");
    println!(
        "  ACIM uniform mapping: {uni:.4} (degradation {:.4})",
        sw_acc - uni
    );
    println!(
        "  ACIM KAN-SAM mapping: {sam:.4} (degradation {:.4})",
        sw_acc - sam
    );
    if sw_acc - sam > 1e-9 {
        println!(
            "  degradation reduction: {:.2}x",
            (sw_acc - uni) / (sw_acc - sam)
        );
    }
    Ok(())
}

fn fig10_cmd(cfg: &AppConfig) -> Result<()> {
    let rows = fig10_sweep(&[8, 16, 32, 64], 3, 8, &cfg.hardware.tech)?;
    println!("{:>4} {:>12} {:>14}", "G", "area-red(x)", "energy-red(x)");
    for r in &rows {
        println!(
            "{:>4} {:>12.2} {:>14.2}",
            r.g, r.area_reduction, r.energy_reduction
        );
    }
    let n = rows.len() as f64;
    println!(
        "avg: area {:.2}x (paper 40.14x), energy {:.2}x (paper 5.59x)",
        rows.iter().map(|r| r.area_reduction).sum::<f64>() / n,
        rows.iter().map(|r| r.energy_reduction).sum::<f64>() / n
    );
    Ok(())
}

fn cost_cmd(cfg: &AppConfig, args: &Args) -> Result<()> {
    use kan_edge::neurosim::{estimate_kan, estimate_mlp, KanArch, MlpArch};
    let dims: Vec<usize> = args
        .get("dims", "17,1,14")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let kind = args.get("kind", "kan");
    let report = match kind.as_str() {
        "mlp" => estimate_mlp(&MlpArch::new(dims), &cfg.hardware.tech)?,
        _ => {
            let mut arch = KanArch::new(dims, args.get_u32("g", 5));
            arch.tm_n = args.get_u32("tm-n", 3);
            estimate_kan(&arch, &cfg.hardware.tech)?
        }
    };
    println!("{}", kan_edge::util::json::obj(vec![
        ("name", kan_edge::util::json::Value::Str(report.name.clone())),
        ("area_mm2", report.area_mm2.into()),
        ("energy_pj", report.energy_pj.into()),
        ("latency_ns", report.latency_ns.into()),
        ("num_params", report.num_params.into()),
    ]));
    Ok(())
}

/// `kan-edge lint`: run the repo-native static analyzer over the tree
/// rooted at `--root` (default: the current directory, falling back to
/// the nearest ancestor containing `rust/src`). Human findings go to
/// stdout; `--json FILE` additionally writes the machine report (CI
/// archives it). Exits 1 when any finding survives — the analyzer is a
/// gate, not a suggestion box.
fn lint_cmd(args: &Args) -> Result<()> {
    let root = match args.opts.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            // ascend from cwd to the first directory holding rust/src,
            // so `kan-edge lint` works from anywhere inside the repo
            let cwd = std::env::current_dir()?;
            let mut found = None;
            let mut probe = Some(cwd.as_path());
            while let Some(dir) = probe {
                if dir.join("rust").join("src").is_dir() {
                    found = Some(dir.to_path_buf());
                    break;
                }
                probe = dir.parent();
            }
            found.ok_or_else(|| {
                kan_edge::Error::Config(
                    "no rust/src in this or any parent directory; pass --root".into(),
                )
            })?
        }
    };
    if !root.join("rust").join("src").is_dir() {
        return Err(kan_edge::Error::Config(format!(
            "--root {} does not contain rust/src",
            root.display()
        )));
    }
    let out = kan_edge::analysis::run_lint(&root)?;
    if let Some(path) = args.opts.get("json") {
        let body = kan_edge::analysis::render_json(
            &out.findings,
            out.files_scanned,
            out.allows,
            out.allows_without_reason,
        );
        std::fs::write(path, body.to_string())?;
    }
    print!(
        "{}",
        kan_edge::analysis::render_human(&out.findings, out.files_scanned)
    );
    if !out.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn stats_cmd() -> Result<()> {
    println!("ACIM calibration statistics (synthetic 'measured-chip' tables,");
    println!("DESIGN.md section 4; regenerated from the resistive-ladder model):
");
    println!(
        "{:>6} {:>12} {:>12}  {}",
        "rows", "mean err", "sigma err", "attenuation by distance decile"
    );
    for s in kan_edge::acim::measured_table(0xCA11B) {
        let profile: Vec<String> =
            s.row_attenuation.iter().map(|a| format!("{a:.3}")).collect();
        println!(
            "{:>6} {:>12.5} {:>12.5}  [{}]",
            s.rows,
            s.mean_rel_error,
            s.sigma_rel_error,
            profile.join(", ")
        );
    }
    Ok(())
}

fn info_cmd(cfg: &AppConfig) -> Result<()> {
    let manifest = Manifest::load(&cfg.artifacts.dir)?;
    println!(
        "artifacts: {} (build {:.0}s)",
        cfg.artifacts.dir,
        manifest.build_seconds.unwrap_or(0.0)
    );
    println!(
        "dataset: {} features, {} classes, {}/{}/{} train/val/test",
        manifest.dataset.num_features,
        manifest.dataset.num_classes,
        manifest.dataset.train,
        manifest.dataset.val,
        manifest.dataset.test
    );
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let m = &manifest.models[name];
        println!(
            "  {name}: {:?} {} params, val {:.4}, test {:.4}",
            m.dims,
            m.num_params,
            m.val_acc,
            m.quant_test_acc.or(m.test_acc).unwrap_or(f64::NAN)
        );
    }
    println!(
        "sweep (Fig 12): G = {:?}",
        manifest.sweep.iter().map(|s| s.g).collect::<Vec<_>>()
    );
    Ok(())
}
