//! PJRT CPU engine: compile cache + executable wrapper.
//!
//! The XLA/PJRT dependency is gated behind the off-by-default `pjrt`
//! cargo feature so the crate builds in the offline image. Without the
//! feature a stub with the same API compiles in; every entry point
//! returns a clear [`Error::Runtime`] telling the caller to rebuild with
//! `--features pjrt`.

#[cfg(all(feature = "pjrt", feature = "xla"))]
pub use real::{PjrtEngine, PjrtExecutable};

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
pub use stub::{PjrtEngine, PjrtExecutable};

#[cfg(all(feature = "pjrt", feature = "xla"))]
mod real {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    use crate::error::{Error, Result};
    use crate::kan::checkpoint::Manifest;

    /// A compiled HLO module ready to run on the PJRT CPU client.
    pub struct PjrtExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// (batch, features) the module was lowered for.
        pub batch: usize,
        pub input_dim: usize,
        pub output_dim: usize,
    }

    impl PjrtExecutable {
        /// Execute on a row-major `[batch, input_dim]` buffer (padded by the
        /// caller if fewer than `batch` live rows). Returns `[batch, output_dim]`.
        pub fn run(&self, x: &[f32]) -> Result<Vec<f32>> {
            if x.len() != self.batch * self.input_dim {
                return Err(Error::Shape(format!(
                    "input len {} != {}x{}",
                    x.len(),
                    self.batch,
                    self.input_dim
                )));
            }
            let lit =
                xla::Literal::vec1(x).reshape(&[self.batch as i64, self.input_dim as i64])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // python lowers with return_tuple=True -> 1-tuple
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// PJRT CPU client with a path-keyed compile cache.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, Arc<PjrtExecutable>>>,
    }

    impl PjrtEngine {
        pub fn cpu() -> Result<Self> {
            Ok(Self {
                client: xla::PjRtClient::cpu()?,
                cache: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file (cached). Shapes must be supplied by
        /// the caller (they come from the manifest).
        pub fn load_hlo(
            &self,
            path: impl AsRef<Path>,
            batch: usize,
            input_dim: usize,
            output_dim: usize,
        ) -> Result<Arc<PjrtExecutable>> {
            let path = path.as_ref().to_path_buf();
            if let Some(hit) = self.cache.lock().unwrap().get(&path) {
                return Ok(hit.clone());
            }
            if !path.exists() {
                return Err(Error::Artifact(format!(
                    "HLO artifact {} missing; run `make artifacts`",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let wrapped = Arc::new(PjrtExecutable { exe, batch, input_dim, output_dim });
            self.cache.lock().unwrap().insert(path, wrapped.clone());
            Ok(wrapped)
        }

        /// Load a model variant from the artifact manifest at `dir`.
        pub fn load_model(
            &self,
            dir: impl AsRef<Path>,
            manifest: &Manifest,
            model: &str,
            batch: usize,
        ) -> Result<Arc<PjrtExecutable>> {
            let entry = manifest.models.get(model).ok_or_else(|| {
                Error::Artifact(format!("model '{model}' not in manifest"))
            })?;
            let file = entry.hlo.get(&batch).ok_or_else(|| {
                Error::Artifact(format!(
                    "model '{model}' has no batch-{batch} HLO (have: {:?})",
                    entry.hlo.keys().collect::<Vec<_>>()
                ))
            })?;
            let input_dim = entry.dims[0];
            let output_dim = *entry.dims.last().unwrap();
            self.load_hlo(dir.as_ref().join(file), batch, input_dim, output_dim)
        }
    }
}

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
mod stub {
    use std::path::Path;
    use std::sync::Arc;

    use crate::error::{Error, Result};
    use crate::kan::checkpoint::Manifest;

    const NO_PJRT: &str = "kan-edge was built without the `pjrt` feature; \
         rebuild with `cargo build --features pjrt` (requires the xla crate) \
         or use the `digital` / `acim` backends";

    fn unavailable<T>() -> Result<T> {
        Err(Error::Runtime(NO_PJRT.into()))
    }

    /// Stub of the compiled-HLO handle (never constructible without `pjrt`).
    pub struct PjrtExecutable {
        pub batch: usize,
        pub input_dim: usize,
        pub output_dim: usize,
    }

    impl PjrtExecutable {
        pub fn run(&self, _x: &[f32]) -> Result<Vec<f32>> {
            unavailable()
        }
    }

    /// Stub of the PJRT CPU client; `cpu()` fails with an actionable error.
    pub struct PjrtEngine {}

    impl PjrtEngine {
        pub fn cpu() -> Result<Self> {
            unavailable()
        }

        pub fn platform(&self) -> String {
            "unavailable (built without pjrt feature)".into()
        }

        pub fn load_hlo(
            &self,
            _path: impl AsRef<Path>,
            _batch: usize,
            _input_dim: usize,
            _output_dim: usize,
        ) -> Result<Arc<PjrtExecutable>> {
            unavailable()
        }

        pub fn load_model(
            &self,
            _dir: impl AsRef<Path>,
            _manifest: &Manifest,
            _model: &str,
            _batch: usize,
        ) -> Result<Arc<PjrtExecutable>> {
            unavailable()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(all(feature = "pjrt", feature = "xla"))]
    #[test]
    fn missing_artifact_is_a_clear_error() {
        let engine = PjrtEngine::cpu().unwrap();
        let err = engine
            .load_hlo("/nonexistent/model.hlo.txt", 1, 17, 14)
            .map(|_| ())
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }

    #[cfg(all(feature = "pjrt", feature = "xla"))]
    #[test]
    fn cpu_platform_reports_cpu() {
        let engine = PjrtEngine::cpu().unwrap();
        assert!(engine.platform().to_lowercase().contains("cpu"));
    }

    #[cfg(not(all(feature = "pjrt", feature = "xla")))]
    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtEngine::cpu().map(|_| ()).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unhelpful stub error: {err}");
    }
}
