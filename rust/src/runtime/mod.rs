//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. The python side lowers with `return_tuple=True`,
//! so outputs are unwrapped with `to_tuple1`.

pub mod engine;

pub use engine::{PjrtEngine, PjrtExecutable};
