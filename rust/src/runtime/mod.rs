//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. The python side lowers with `return_tuple=True`,
//! so outputs are unwrapped with `to_tuple1`.
//!
//! The XLA dependency is optional: build with `--features pjrt` for the
//! real execution path. Without it, [`PjrtEngine`] is a stub whose entry
//! points fail with an actionable runtime error, keeping the offline
//! `cargo build`/`cargo test` green (the `digital` and `acim` backends
//! are unaffected).

pub mod engine;

pub use engine::{PjrtEngine, PjrtExecutable};
